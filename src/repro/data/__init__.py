from repro.data.pipeline import DataPipeline, PipelineConfig  # noqa: F401
