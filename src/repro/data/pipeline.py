"""Deterministic, step-addressable, shardable synthetic data pipeline.

Fault-tolerance contract: batch(step, shard) is a pure function of
(seed, step, shard) — any step is replayable after restart, any shard is
recomputable on a replacement host, and straggler mitigation can hand a
slow host's shard to a fast one without coordination (see
runtime/straggler.py).  No state beyond the integer step needs
checkpointing.

The generator is a counter-mode threefry stream producing a Zipf-ish
token distribution (so losses move like text, not uniform noise), with
documents separated by BOS and label masking across the boundary.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    bos_id: int = 1


class DataPipeline:
    """Sharded view: this process materializes rows
    [shard * rows_per_shard, (shard+1) * rows_per_shard)."""

    def __init__(self, cfg: PipelineConfig, num_shards: int = 1,
                 shard: int = 0):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.num_shards = num_shards
        self.shard = shard
        self.rows = cfg.global_batch // num_shards

    def batch(self, step: int):
        """-> dict(tokens [rows, S] int32, labels [rows, S] int32)."""
        cfg = self.cfg
        row0 = self.shard * self.rows
        key = jax.random.fold_in(jax.random.key(cfg.seed), step)
        keys = jax.random.split(key, cfg.global_batch)[row0: row0 + self.rows]
        toks = jax.vmap(lambda k: self._row(k))(keys)
        labels = jnp.concatenate(
            [toks[:, 1:], jnp.full((self.rows, 1), -100, jnp.int32)], 1)
        # mask label at document boundaries (next token is a fresh BOS)
        labels = jnp.where(labels == cfg.bos_id, -100, labels)
        return {"tokens": toks, "labels": labels}

    def _row(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        # Zipf-ish marginal: token = floor(exp(u * log V)) spreads mass
        # log-uniformly over the vocab (rank-frequency ~ 1/rank).
        u = jax.random.uniform(k1, (cfg.seq_len,), jnp.float32)
        toks = jnp.exp(u * np.log(cfg.vocab_size - 2)).astype(jnp.int32) + 1
        # doc boundaries: geometric with mean mean_doc_len
        b = jax.random.uniform(k2, (cfg.seq_len,), jnp.float32)
        is_bos = b < (1.0 / cfg.mean_doc_len)
        toks = jnp.where(is_bos, cfg.bos_id, toks)
        return jnp.clip(toks, 0, cfg.vocab_size - 1)

    # -- elasticity ------------------------------------------------------
    def reshard(self, num_shards: int, shard: int) -> "DataPipeline":
        """Same global stream under a different shard decomposition —
        restoring a checkpoint onto a different mesh keeps data exact."""
        return DataPipeline(self.cfg, num_shards, shard)
