"""Production meshes (functions only — importing this module never
touches jax device state)."""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over forced host devices (tests / examples)."""
    return compat.make_mesh(shape, axes)
