"""Training launcher: data pipeline + train step + fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 50 --batch 4 --seq 64 --ckpt-dir /tmp/run1

Defaults run the reduced (smoke) config on the local devices; the same
flags drive the production mesh on a real pod (--mesh single|multi —
requires the matching device count).  Restart the same command after a
crash/preemption: it resumes from the newest committed checkpoint, on
the current mesh (elastic).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import compat, configs
from repro.core import api as mpix_api
from repro.data import DataPipeline, PipelineConfig
from repro.launch.mesh import make_production_mesh
from repro.runtime import FaultTolerantLoop, PreemptionSignal
from repro.train.step import (TrainOptions, init_train_state,
                              make_train_step)


def build(args):
    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if args.mesh == "local":
        n = jax.device_count()
        mesh = compat.make_mesh((n, 1), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        from repro.models.common import set_shard_mesh
        set_shard_mesh(mesh)
    opts = TrainOptions(
        dp_mode=args.dp_mode, dp_algorithm=args.dp_algorithm,
        grad_buckets=args.grad_buckets, moe_mode=args.moe_mode,
        ep_alltoall=args.ep_alltoall, ep_policy=args.select_policy,
        ep_transport=args.ep_transport, dp_transport=args.dp_transport,
        resilience=(None if args.resilience == "off"
                    else args.resilience),
        remat=not args.smoke,
        peak_lr=args.lr, warmup_steps=max(1, args.steps // 20),
        total_steps=args.steps)
    return cfg, mesh, opts


def mesh_topologies(mesh):
    """The topologies runtime collectives actually query on this mesh.

    A tuned-policy lookup keys on the topology of the *axis subset* a
    collective runs over (``api.topology_from_axes``), not the whole
    mesh: dp sync uses ("pod","data")/("data",), MoE EP uses
    ("pod","model")/("model",), the token rebuild uses ("model",).  So
    tune one topology per single non-DCN axis plus one per ("pod",
    axis) pair, deduped — a whole-mesh-only table would never be hit.
    """
    from repro.core.topology import Topology, flat_topology
    topos = {}
    names = [a for a in mesh.axis_names if a != "pod"]
    npods = mesh.shape.get("pod", 1) if "pod" in mesh.axis_names else 1
    for a in names:
        size = mesh.shape[a]
        if size > 1:
            t = flat_topology(size)
            topos[t.fingerprint()] = t
            if npods > 1:
                t = Topology(nranks=npods * size, ranks_per_pod=size)
                topos[t.fingerprint()] = t
    if not topos:
        t = flat_topology(mesh.devices.size)
        topos[t.fingerprint()] = t
    return list(topos.values())


def autotune_mesh(mesh, repeats: int = 3, full: bool = False,
                  probe: bool = False):
    """Tune (or heal) every topology this mesh's collectives query at
    trace time.

    A topology with no persisted table gets a full ``tuner.autotune``
    (measures every path — dense collectives, neighbor aggregate-vs-
    standard, partitioned chunking — and persists winners).  A topology
    that already has a table is *healed* instead (``tuner.heal_table``):
    guideline violations and cells missing newly registered algorithms
    trigger a scoped re-measure of only those cells and bump the table
    generation — untouched cells keep their timings.  ``full=True``
    forces a from-scratch re-tune of everything.

    ``probe=True`` runs the wire-measurement pass first
    (``core.linkprobe``): each topology's per-level alpha/beta is
    measured through the transports (ping-pong/injection probes) and
    the tables are keyed by the *measured* geometry — their
    fingerprints carry the fitted ``lm[...]`` link models instead of
    datasheet constants.
    """
    from repro.core import linkprobe, tuner
    tables = []
    for topo in mesh_topologies(mesh):
        if probe:
            measured = linkprobe.measured_topology(topo, repeats=repeats)
            print(f"probed links: {topo.fingerprint()} -> "
                  f"{tuner.substrate_fingerprint(measured)}")
            topo = measured
        table = (None if full else
                 tuner.load_table(tuner.substrate_fingerprint(topo)))
        if table is None:
            table = tuner.autotune(topo, repeats=repeats)
            print(f"autotuned {table.fingerprint} ({table.source}): "
                  f"{sorted(table.entries)}")
        else:
            healed = tuner.heal_table(table, topo, repeats=repeats)
            print(f"reused {table.fingerprint} ({table.source}, "
                  f"generation {table.generation}): "
                  f"{len(healed)} cell(s) repaired")
        for v in table.violations:
            print(f"  guideline violation: {v}")
        tables.append(table)
    return tables


def heal_daemons(mesh, heal_every: int):
    """One ``TuningDaemon`` per mesh topology, probing every
    ``heal_every`` steps — the online drift-healing heartbeat the
    training loop ticks from ``on_step``."""
    from repro.runtime import TuningDaemon
    return [TuningDaemon(topo, probe_every=heal_every)
            for topo in mesh_topologies(mesh)]


def make_elastic(mesh, policy: str):
    """(RankLossSignal, on_rank_loss) for ``FaultTolerantLoop``: on
    rank loss, re-derive the launcher's staged schedules (grad sync +
    EP dispatch) for the shrunk topology and swap them in place — the
    loop keeps stepping, no restart."""
    from repro.core import selector
    from repro.runtime import ElasticScheduleSet, RankLossSignal

    topo = max(mesh_topologies(mesh), key=lambda t: t.nranks)
    nbytes = 1 << 20
    entries = {}
    for name, coll in (("grad_sync", "allreduce"),
                       ("ep_dispatch", "alltoall")):
        algo = selector.select(coll, topo, nbytes, policy=policy)
        if algo == "xla":          # schedule sets hold IR plans only
            algo = selector.select(coll, topo, nbytes, policy="model")
        entries[name] = (coll, algo)
    schedules = ElasticScheduleSet(topo, entries)
    signal = RankLossSignal()

    def on_rank_loss(state, step, lost):
        in_range = [r for r in lost if r < schedules.topo.nranks]
        if not in_range or len(in_range) >= schedules.topo.nranks:
            print(f"rank loss {lost} outside schedule topology; "
                  f"no swap")
            return None
        rep = schedules.shrink(in_range)
        print(f"elastic swap @step {step}: lost {rep.lost_ranks}, "
              f"{rep.old_fingerprint} -> {rep.new_fingerprint}, "
              f"re-derived {len(rep.rederived)} schedule(s), evicted "
              f"{rep.invalidated} stale executor(s)", flush=True)
        return None                # state/step_fn unchanged: swap only

    return signal, on_rank_loss, schedules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--dp-mode", default="fsdp")
    ap.add_argument("--dp-algorithm", default="xla")
    ap.add_argument("--select-policy", default="model",
                    choices=["fixed", "model", "tuned"],
                    help="algorithm selection policy for algorithm="
                         "'auto' collectives (tuned reads the persisted "
                         "tuner table; see repro.core.tuner)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune this mesh before training (persists dense "
                         "+ neighbor + partitioned winners for "
                         "--select-policy tuned); an existing table is "
                         "healed in place — only guideline-violating "
                         "cells are re-measured")
    ap.add_argument("--autotune-full", action="store_true",
                    help="ignore any persisted table and re-measure "
                         "everything from scratch (implies --autotune)")
    ap.add_argument("--probe-links", action="store_true",
                    help="wire-measure per-level link models before "
                         "tuning (ping-pong/injection probes through "
                         "the transports); tuned tables key on the "
                         "measured geometry (lm[] fingerprints)")
    ap.add_argument("--heal-every", type=int, default=0,
                    help="re-probe the fabric every N steps and heal "
                         "tuned tables on drift — scoped: only cells "
                         "whose selection the drift can move are "
                         "re-measured (0 = off)")
    ap.add_argument("--elastic", action="store_true",
                    help="on rank loss (RankLossSignal), re-derive the "
                         "staged schedules for the shrunk topology and "
                         "swap executors in place instead of exiting")
    ap.add_argument("--grad-buckets", type=int, default=1)
    ap.add_argument("--moe-mode", default="dropless")
    ap.add_argument("--ep-alltoall", default="xla")
    ap.add_argument("--ep-transport", default="shardmap",
                    choices=["shardmap", "pallas", "auto"],
                    help="substrate for schedule-backed EP collectives: "
                         "one ppermute per round (shardmap), the whole "
                         "schedule as a single device kernel (pallas), "
                         "or the tuner's per-size choice (auto)")
    ap.add_argument("--dp-transport", default="shardmap",
                    choices=["shardmap", "pallas", "auto"],
                    help="substrate for explicit-mode gradient sync "
                         "(same choices as --ep-transport)")
    ap.add_argument("--resilience", default="off",
                    choices=["off", "canary", "full"],
                    help="chaos-resilient collectives: arm the recovery "
                         "ladder (retry + transport fallback + "
                         "algorithm refit + xla) for EP dispatch and "
                         "explicit-mode grad sync; canary/full set the "
                         "host-level verification mode")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    mpix_api.set_default_policy(args.select_policy)
    cfg, mesh, opts = build(args)
    if args.autotune or args.autotune_full:
        autotune_mesh(mesh, full=args.autotune_full,
                      probe=args.probe_links)
    daemons = heal_daemons(mesh, args.heal_every) if args.heal_every \
        else []
    pipe = DataPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))
    with compat.set_mesh(mesh):
        step_fn = jax.jit(make_train_step(cfg, mesh, opts))
        state = init_train_state(jax.random.key(0), cfg, opts)

        losses = []
        t_last = [time.time()]

        def one_step(state, step):
            batch = pipe.batch(step)
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.log_every == 0:
                dt = (time.time() - t_last[0]) / args.log_every
                t_last[0] = time.time()
                print(f"step {step+1:5d}  loss {losses[-1]:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"{dt*1e3:.0f} ms/step", flush=True)
            return state

        def on_step(step, state):
            for d in daemons:
                rep = d.tick(step)
                if rep is not None and rep.healed:
                    print(f"drift healed @step {step}: levels "
                          f"{rep.drifted_levels}, re-measured "
                          f"{len(rep.retuned_cells)}/{rep.total_cells} "
                          f"cell(s), generation {rep.generation}",
                          flush=True)

        if args.ckpt_dir:
            rank_loss = on_rank_loss = None
            if args.elastic:
                rank_loss, on_rank_loss, _ = make_elastic(
                    mesh, args.select_policy)
            loop = FaultTolerantLoop(args.ckpt_dir,
                                     ckpt_every=args.ckpt_every,
                                     preemption=PreemptionSignal(True),
                                     rank_loss=rank_loss,
                                     on_rank_loss=on_rank_loss)
            state, start = loop.resume_or_init(state)
            if start:
                print(f"resumed from step {start}")
            state, stopped = loop.run(state, one_step,
                                      start_step=start,
                                      num_steps=args.steps - start,
                                      on_step=on_step if daemons
                                      else None)
        else:
            for s in range(args.steps):
                state = one_step(state, s)
                on_step(s + 1, state)

    if losses:
        print(f"final loss {np.mean(losses[-5:]):.4f} "
              f"(first {np.mean(losses[:5]):.4f})")
    else:
        print("nothing to do (already past --steps; checkpoint is "
              "complete)")
    return losses


if __name__ == "__main__":
    main()
