"""ShapeDtypeStruct stand-ins for every model input — the dry-run's
weak-type-correct, shardable, zero-allocation inputs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.models import model as M


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: str, shape_name: str):
    """Returns (kind, spec_tree) for the (arch x shape) cell.

    train:   {"tokens","labels"[, "encoder_frames"][, "vision_embeds"]}
    prefill: same minus labels
    decode:  {"cache": <cache tree>, "tokens": [B,1]
              [, "cross_src": encoder output]}
    """
    cfg = get_config(arch)
    sp = SHAPES[shape_name]
    B, S = sp.global_batch, sp.seq_len

    def extras():
        kw = {}
        if cfg.encoder is not None:
            kw["encoder_frames"] = sds(
                (B, cfg.encoder.n_frames, cfg.encoder.d_model),
                jnp.bfloat16)
        if cfg.vision_prefix:
            kw["vision_embeds"] = sds((B, cfg.vision_prefix, cfg.d_model),
                                      jnp.bfloat16)
        return kw

    if sp.kind == "train":
        return "train", dict(tokens=sds((B, S), jnp.int32),
                             labels=sds((B, S), jnp.int32), **extras())
    if sp.kind == "prefill":
        return "prefill", dict(tokens=sds((B, S), jnp.int32), **extras())
    assert sp.kind == "decode"
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    out = {"cache": cache, "tokens": sds((B, 1), jnp.int32)}
    if cfg.encoder is not None:
        out["cross_src"] = sds((B, cfg.encoder.n_frames,
                                cfg.encoder.d_model), jnp.bfloat16)
    return "decode", out


def state_shapes(cfg, opts):
    """Train-state ShapeDtypeStructs (eval_shape — no allocation)."""
    from repro.train.step import init_train_state
    return jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), cfg, opts))
