import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell
with ShapeDtypeStruct inputs (no allocation), print memory_analysis()
and cost_analysis(), and extract the collective schedule for §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --json out.json
"""
import argparse
import json
import re
import sys
import time

import jax

from repro import compat
from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, runnable
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as SPECS
from repro.launch.hlo_analysis import analyse_hlo

# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
             "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
             "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def collective_bytes(hlo: str) -> dict:
    """Per-device wire bytes by collective kind, from the compiled HLO.

    Uses result shapes + group size G with standard wire-cost factors:
      all-gather         (G-1)/G * result      (received)
      all-reduce         2*(G-1)/G * result    (ring rs+ag)
      reduce-scatter     (G-1)/G * result * G  (= (G-1) * result sent)
      all-to-all         (G-1)/G * result
      collective-permute 1.0    * result
    """
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "count": 0}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "-done" in line:
            continue
        shape_s, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_s)
        if nbytes == 0:
            continue
        g = None
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                g = len([x for x in gl.group(1).split(",") if x.strip()])
        g = g or 2
        if kind == "all-gather":
            wire = nbytes * (g - 1) / g
        elif kind == "all-reduce":
            wire = 2 * nbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = nbytes * (g - 1)
        elif kind == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:
            wire = float(nbytes)
        out[kind] += wire
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items()
                       if k not in ("count", "total"))
    return out


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               train_overrides: dict | None = None, hint_level: int = 1):
    """Lower + compile one cell; returns (lowered, compiled, meta)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train import sharding
    from repro.train.step import TrainOptions, make_train_step
    from repro.serve.step import (ServeOptions, make_prefill_step,
                                  jit_decode_step)

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models.common import set_shard_mesh
    set_shard_mesh(mesh, level=hint_level)
    kind, ins = SPECS.input_specs(arch, shape_name)
    d_axes = sharding.data_axes(mesh)
    to_sh = lambda spec_tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

    with compat.set_mesh(mesh):
        if kind == "train":
            opts = TrainOptions(**(train_overrides or {}))
            from repro.train.step import state_specs
            state = SPECS.state_shapes(cfg, opts)
            sspec = state_specs(state, cfg, mesh, opts)
            bspec = jax.tree.map(lambda _: P(d_axes), ins)
            step = make_train_step(cfg, mesh, opts)
            jitted = jax.jit(step, in_shardings=(to_sh(sspec),
                                                 to_sh(bspec)),
                             out_shardings=(to_sh(sspec), None))
            lowered = jitted.lower(state, ins)
        elif kind == "prefill":
            from repro.models.model import init_params
            sopts = ServeOptions(use_kernel=(train_overrides or {}).get(
                "use_kernel", False))
            params = jax.eval_shape(
                lambda: init_params(jax.random.key(0), cfg))
            pspec = sharding.param_specs(params, cfg, mesh)
            bspec = jax.tree.map(lambda _: P(d_axes), ins)
            pre = make_prefill_step(cfg, mesh, sopts)
            vshard = ("model" if cfg.vocab_size % mesh.shape["model"] == 0
                      else None)          # whisper's 51865 is odd
            jitted = jax.jit(pre, in_shardings=(to_sh(pspec),
                                                to_sh(bspec)),
                             out_shardings=NamedSharding(
                                 mesh, P(d_axes, None, vshard)))
            lowered = jitted.lower(params, ins)
        else:  # decode
            long = shape_name.startswith("long")
            sopts = ServeOptions(long_context=long)
            from repro.models.model import init_params
            params = jax.eval_shape(
                lambda: init_params(jax.random.key(0), cfg))
            jitted, _ = jit_decode_step(cfg, mesh, sopts, params,
                                        ins["cache"])
            args = [params, ins["cache"], ins["tokens"]]
            if "cross_src" in ins:
                args.append(ins["cross_src"])
            lowered = jitted.lower(*args)

        compiled = lowered.compile()
    return lowered, compiled, {"kind": kind, "mesh": mesh}


def analyse(arch: str, shape_name: str, *, multi_pod: bool,
            train_overrides=None, verbose=True, hint_level: int = 1):
    t0 = time.time()
    lowered, compiled, meta = lower_cell(
        arch, shape_name, multi_pod=multi_pod,
        train_overrides=train_overrides, hint_level=hint_level)
    t1 = time.time()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    walk = analyse_hlo(hlo)      # trip-count-corrected per-device costs
    n_dev = 512 if multi_pod else 256
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": meta["kind"],
        "compile_s": round(t1 - t0, 1),
        "flops_per_device": walk["flops"],
        "hbm_bytes_per_device": walk["hbm_bytes"],
        "collectives": {**walk["coll"], "count": walk["coll_count"],
                        "total": walk["coll_total"]},
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes),
        },
        "n_devices": n_dev,
    }
    if verbose:
        coll = res["collectives"]
        print(f"[{arch} x {shape_name} x {res['mesh']}] "
              f"kind={meta['kind']} compile={res['compile_s']}s")
        print(f"  flops/dev={walk['flops']:.3e}  "
              f"hbm bytes/dev={walk['hbm_bytes']:.3e}")
        print(f"  args={mem.argument_size_in_bytes/2**30:.2f}GiB  "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB  "
              f"out={mem.output_size_in_bytes/2**30:.2f}GiB")
        print(f"  collective wire bytes/dev={coll['total']:.3e} "
              f"({coll['count']:.0f} ops: "
              + ", ".join(f"{k}={v:.2e}" for k, v in coll.items()
                          if k not in ('count', 'total') and v) + ")")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cells", default=None,
                    help="comma list of arch:shape pairs")
    ap.add_argument("--json", default=None)
    ap.add_argument("--dp-mode", default="fsdp")
    ap.add_argument("--moe-mode", default="mpix_ep")
    ap.add_argument("--ep-alltoall", default="xla")
    ap.add_argument("--remat", default="true")
    ap.add_argument("--hint-level", type=int, default=1)
    ap.add_argument("--use-kernel", action="store_true",
                    help="kernel path; on CPU lowers HBM-equivalent "
                         "surrogates (REPRO_KERNEL_SURROGATE)")
    ap.add_argument("--ep-capacity", type=float, default=1.25)
    args = ap.parse_args(argv)

    if args.use_kernel:
        os.environ["REPRO_KERNEL_SURROGATE"] = "1"
    overrides = {"dp_mode": args.dp_mode, "moe_mode": args.moe_mode,
                 "ep_alltoall": args.ep_alltoall,
                 "remat": args.remat.lower() == "true",
                 "use_kernel": args.use_kernel,
                 "ep_capacity": args.ep_capacity}

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    elif args.cells:
        cells = [tuple(c.split(":")) for c in args.cells.split(",")]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results, failures = [], []
    for a, s in cells:
        if not runnable(a, s):
            print(f"[{a} x {s}] SKIP (documented: sub-quadratic only)")
            results.append({"arch": a, "shape": s, "skip": True})
            continue
        for mp in meshes:
            try:
                results.append(analyse(a, s, multi_pod=mp,
                                       train_overrides=overrides,
                                       hint_level=args.hint_level))
            except Exception as e:  # noqa: BLE001 — report and continue
                print(f"[{a} x {s} x {'multi' if mp else 'single'}] "
                      f"FAILED: {type(e).__name__}: {e}")
                failures.append((a, s, mp, str(e)[:500]))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"results": results,
                       "failures": failures}, f, indent=1)
    print(f"\n{len(results)} cells analysed, {len(failures)} failures")
    if failures:
        for f_ in failures:
            print("  FAIL:", f_[0], f_[1], "multi" if f_[2] else "single")
        sys.exit(1)


if __name__ == "__main__":
    main()
