"""Serving launcher: batched prefill + greedy decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat, configs
from repro.core import api as mpix_api
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.serve.step import ServeOptions, make_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--select-policy", default="model",
                    choices=["fixed", "model", "tuned"],
                    help="algorithm selection policy for algorithm="
                         "'auto' collectives (tuned reads the persisted "
                         "tuner table; see repro.core.tuner)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune this mesh before serving (persists "
                         "winners for --select-policy tuned); an "
                         "existing table is healed in place — only "
                         "guideline-violating cells are re-measured")
    ap.add_argument("--autotune-full", action="store_true",
                    help="ignore any persisted table and re-measure "
                         "everything from scratch (implies --autotune)")
    ap.add_argument("--probe-links", action="store_true",
                    help="wire-measure per-level link models before "
                         "tuning; tables key on measured geometry "
                         "(lm[] fingerprints)")
    ap.add_argument("--heal-interval", type=float, default=0.0,
                    help="run the drift-healing tuner daemon in the "
                         "background every N seconds while serving "
                         "(0 = off); heals are scoped to drifted cells")
    ap.add_argument("--ep-alltoall", default="xla",
                    help="mpix algorithm for the explicit EP dispatch "
                         "(only used when --ep-transport is set)")
    ap.add_argument("--ep-transport", default=None,
                    choices=["shardmap", "pallas", "auto"],
                    help="enable explicit expert-parallel prefill "
                         "dispatch on this substrate: one ppermute per "
                         "round (shardmap), the whole schedule as a "
                         "single device kernel (pallas), or the tuner's "
                         "per-size choice (auto)")
    ap.add_argument("--resilience", default="off",
                    choices=["off", "canary", "full"],
                    help="chaos-resilient EP dispatch collectives: arm "
                         "the recovery ladder; canary/full set the "
                         "host-level verification mode")
    args = ap.parse_args(argv)

    mpix_api.set_default_policy(args.select_policy)
    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if args.mesh == "local":
        n = jax.device_count()
        mesh = compat.make_mesh((n, 1), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    if args.autotune or args.autotune_full:
        from repro.launch.train import autotune_mesh
        autotune_mesh(mesh, full=args.autotune_full,
                      probe=args.probe_links)
    daemons = []
    if args.heal_interval > 0:
        from repro.launch.train import heal_daemons
        daemons = heal_daemons(mesh, 1)
        for d in daemons:
            d.start(interval_s=args.heal_interval)

    max_len = args.prompt_len + args.gen
    with compat.set_mesh(mesh):
        params = M.init_params(jax.random.key(0), cfg)
        prompts = jax.random.randint(
            jax.random.key(1), (args.batch, args.prompt_len), 2,
            cfg.vocab_size)
        cross = None
        if cfg.encoder is not None:
            frames = jax.random.normal(
                jax.random.key(2),
                (args.batch, cfg.encoder.n_frames, cfg.encoder.d_model),
                jnp.bfloat16)
            cross = M.encode(params, cfg, frames)

        cache = M.init_cache(cfg, args.batch, max_len)
        ep_options = None
        if args.ep_transport is not None:
            from repro.train.moe_dispatch import EPOptions
            ep_options = EPOptions(alltoall=args.ep_alltoall,
                                   transport=args.ep_transport,
                                   policy=args.select_policy)
        opts = ServeOptions(ep_options=ep_options,
                            resilience=(None if args.resilience == "off"
                                        else args.resilience))
        decode = jax.jit(make_decode_step(cfg, mesh, opts))

        # prefill token-by-token through the decode step (keeps one
        # compiled program; the batched-prefill path is exercised by the
        # dry-run and benches)
        t0 = time.time()
        tok = prompts[:, :1]
        outs = []
        for i in range(max_len - 1):
            a = (params, cache, tok) if cfg.encoder is None else \
                (params, cache, tok, cross)
            nxt, cache = decode(*a)
            if i + 1 < args.prompt_len:
                tok = prompts[:, i + 1: i + 2]      # teacher-forced
            else:
                tok = nxt
                outs.append(np.asarray(nxt)[:, 0])
        dt = time.time() - t0
    for d in daemons:
        d.stop()
        healed = sum(1 for r in d.reports if r.healed)
        if healed:
            print(f"tuner daemon: {len(d.reports)} probe pass(es), "
                  f"{healed} heal(s) on {d.topo.fingerprint()}")
    gen = np.stack(outs, 1)
    print(f"generated {gen.shape} in {dt:.2f}s "
          f"({(max_len - 1) * args.batch / dt:.1f} tok/s)")
    print(gen[:, :12])
    return gen


if __name__ == "__main__":
    main()
