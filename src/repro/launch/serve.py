"""Serving launcher: batched prefill + greedy decode with a KV cache,
and the continuous-batching traffic-simulator path.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --smoke --batch 4 --prompt-len 32 --gen 16

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --smoke --continuous --arrival-rate 6 --tenants 3 --requests 48
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat, configs
from repro.core import api as mpix_api
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.serve.step import ServeOptions, jit_decode_step


def _run_continuous(args, cfg) -> dict:
    """Continuous batching: drive the engine through a seeded Poisson
    multi-tenant trace; KV blocks move prefill-pool -> decode-pool via
    ragged neighbor plans on ``--kv-transport`` (resilience ladder when
    ``--resilience`` is armed)."""
    from repro.serve.engine import ContinuousBatchingEngine, EngineConfig
    from repro.serve.traffic import poisson_workload, run_workload

    resilience = None
    if args.resilience != "off":
        # lead the ladder with the requested substrate; keep the walk on
        # host rungs so per-batch plans never pay a device compile
        lead = args.kv_transport if args.kv_transport != "reference" \
            else "sim"
        ladder = tuple(dict.fromkeys((lead, "sim", "reference")))
        resilience = {"verify": args.resilience, "ladder": ladder,
                      "backoff_s": 1e-4}
    ecfg = EngineConfig(
        blocks_per_rank=args.kv_blocks,
        block_feat=(getattr(cfg, "head_dim", None) or 16),
        transport=args.kv_transport,
        resilience=resilience,
        policy=args.select_policy)
    engine = ContinuousBatchingEngine(ecfg)
    trace = poisson_workload(args.seed, arrival_rate=args.arrival_rate,
                             tenants=args.tenants,
                             n_requests=args.requests,
                             max_prompt=args.kv_blocks
                             * ecfg.block_tokens // 2)
    t0 = time.time()
    metrics = run_workload(engine, trace)
    dt = time.time() - t0
    kv = metrics["kv_transfer"]
    print(f"continuous: {metrics['completed']}/{metrics['submitted']} "
          f"requests over {args.tenants} tenants in "
          f"{metrics['steps']} steps ({dt:.2f}s), "
          f"{metrics['tokens']} tokens "
          f"({metrics['tokens_per_step']} tok/step, "
          f"{metrics['tokens_per_s']} tok/s)")
    print(f"ttft: mean {metrics['ttft_steps']['mean']} steps, "
          f"p99 {metrics['ttft_steps']['p99']}; "
          f"preemptions {metrics['preemptions']}")
    print(f"kv-transfer: {kv['plans']} plans, {kv['blocks']} blocks, "
          f"{kv['bytes']}B ({kv['dcn_bytes']}B dcn / "
          f"{kv['ici_bytes']}B ici) via {kv['plan_names']}, "
          f"{kv['wall_s']}s wall")
    if metrics["degradations"]:
        print(f"resilience: {metrics['degradations']} degradation "
              f"report(s) collected")
    return metrics


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--select-policy", default="model",
                    choices=["fixed", "model", "tuned"],
                    help="algorithm selection policy for algorithm="
                         "'auto' collectives (tuned reads the persisted "
                         "tuner table; see repro.core.tuner)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune this mesh before serving (persists "
                         "winners for --select-policy tuned); an "
                         "existing table is healed in place — only "
                         "guideline-violating cells are re-measured")
    ap.add_argument("--autotune-full", action="store_true",
                    help="ignore any persisted table and re-measure "
                         "everything from scratch (implies --autotune)")
    ap.add_argument("--probe-links", action="store_true",
                    help="wire-measure per-level link models before "
                         "tuning; tables key on measured geometry "
                         "(lm[] fingerprints)")
    ap.add_argument("--heal-interval", type=float, default=0.0,
                    help="run the drift-healing tuner daemon in the "
                         "background every N seconds while serving "
                         "(0 = off); heals are scoped to drifted cells")
    ap.add_argument("--ep-alltoall", default="xla",
                    help="mpix algorithm for the explicit EP dispatch "
                         "(only used when --ep-transport is set)")
    ap.add_argument("--ep-transport", default=None,
                    choices=["shardmap", "pallas", "auto"],
                    help="enable explicit expert-parallel prefill "
                         "dispatch on this substrate: one ppermute per "
                         "round (shardmap), the whole schedule as a "
                         "single device kernel (pallas), or the tuner's "
                         "per-size choice (auto)")
    ap.add_argument("--resilience", default="off",
                    choices=["off", "canary", "full"],
                    help="arm the chaos-recovery ladder on the serve "
                         "collectives: EP dispatch (needs "
                         "--ep-transport) and/or continuous-mode KV "
                         "transfers (--continuous); canary/full set "
                         "the host-level verification mode")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching mode: drive the "
                         "disaggregated prefill/decode engine through "
                         "a seeded Poisson multi-tenant trace; KV "
                         "blocks move between pools via ragged "
                         "neighbor plans")
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="continuous mode: mean requests/sec of the "
                         "Poisson arrival process")
    ap.add_argument("--tenants", type=int, default=2,
                    help="continuous mode: tenant count of the bursty "
                         "traffic mix (each tenant has its own "
                         "prompt/gen length skew)")
    ap.add_argument("--requests", type=int, default=32,
                    help="continuous mode: trace length")
    ap.add_argument("--kv-transport", default="sim",
                    choices=["sim", "reference", "shardmap", "pallas"],
                    help="continuous mode: substrate executing the KV "
                         "block-transfer schedules (shardmap needs one "
                         "device per engine rank)")
    ap.add_argument("--kv-blocks", type=int, default=32,
                    help="continuous mode: KV blocks per engine rank")
    ap.add_argument("--seed", type=int, default=0,
                    help="continuous mode: trace seed")
    args = ap.parse_args(argv)

    # ---- argument validation (fail loudly, never deep in the loop) ----
    if args.gen < 1:
        ap.error(f"--gen must be >= 1 (got {args.gen}): generating "
                 f"zero tokens leaves nothing to stack or serve")
    if args.prompt_len < 1:
        ap.error(f"--prompt-len must be >= 1 (got {args.prompt_len})")
    if args.batch < 1:
        ap.error(f"--batch must be >= 1 (got {args.batch})")
    if args.continuous:
        if args.arrival_rate <= 0:
            ap.error(f"--arrival-rate must be > 0 "
                     f"(got {args.arrival_rate})")
        if args.tenants < 1:
            ap.error(f"--tenants must be >= 1 (got {args.tenants})")
        if args.requests < 1:
            ap.error(f"--requests must be >= 1 (got {args.requests})")
    if args.resilience != "off" and args.ep_transport is None \
            and not args.continuous:
        # resilience only threads through the EP dispatch and the KV
        # transfer collectives; without either armed it silently
        # protected nothing — fail loudly instead (satellite bugfix)
        raise SystemExit(
            f"--resilience {args.resilience} has nothing to protect: "
            f"the single-shot decode path runs no mpix collectives. "
            f"Arm a protected path with --ep-transport "
            f"shardmap|pallas|auto (EP dispatch) or --continuous "
            f"(KV-cache transfers), or drop --resilience.")

    mpix_api.set_default_policy(args.select_policy)
    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if args.mesh == "local":
        n = jax.device_count()
        mesh = compat.make_mesh((n, 1), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    if args.autotune or args.autotune_full:
        from repro.launch.train import autotune_mesh
        autotune_mesh(mesh, full=args.autotune_full,
                      probe=args.probe_links)
    daemons = []
    if args.heal_interval > 0:
        from repro.launch.train import heal_daemons
        daemons = heal_daemons(mesh, 1)
        for d in daemons:
            d.start(interval_s=args.heal_interval)

    # daemons must stop even when the serve body raises (leak fix):
    # same pattern train's FaultTolerantLoop uses for signal handlers
    try:
        max_len = args.prompt_len + args.gen
        with compat.set_mesh(mesh):
            if args.continuous:
                return _run_continuous(args, cfg)

            params = M.init_params(jax.random.key(0), cfg)
            prompts = jax.random.randint(
                jax.random.key(1), (args.batch, args.prompt_len), 2,
                cfg.vocab_size)
            cross = None
            if cfg.encoder is not None:
                frames = jax.random.normal(
                    jax.random.key(2),
                    (args.batch, cfg.encoder.n_frames,
                     cfg.encoder.d_model),
                    jnp.bfloat16)
                cross = M.encode(params, cfg, frames)

            cache = M.init_cache(cfg, args.batch, max_len)
            ep_options = None
            if args.ep_transport is not None:
                from repro.train.moe_dispatch import EPOptions
                ep_options = EPOptions(alltoall=args.ep_alltoall,
                                       transport=args.ep_transport,
                                       policy=args.select_policy)
            opts = ServeOptions(
                ep_options=ep_options,
                resilience=(None if args.resilience == "off"
                            else args.resilience))
            # jit through jit_decode_step so params/cache carry their
            # NamedShardings — a bare jax.jit silently replicated the
            # cache on multi-device meshes (satellite bugfix)
            decode, (pspec, cspec) = jit_decode_step(
                cfg, mesh, opts, params, cache)

            # prefill token-by-token through the decode step (keeps one
            # compiled program; the batched-prefill path is exercised by
            # the dry-run and benches)
            t0 = time.time()
            tok = prompts[:, :1]
            outs = []
            for i in range(max_len - 1):
                a = (params, cache, tok) if cfg.encoder is None else \
                    (params, cache, tok, cross)
                nxt, cache = decode(*a)
                if i + 1 < args.prompt_len:
                    tok = prompts[:, i + 1: i + 2]      # teacher-forced
                else:
                    tok = nxt
                    outs.append(np.asarray(nxt)[:, 0])
            dt = time.time() - t0
    finally:
        for d in daemons:
            d.stop()
            healed = sum(1 for r in d.reports if r.healed)
            if healed:
                print(f"tuner daemon: {len(d.reports)} probe pass(es), "
                      f"{healed} heal(s) on {d.topo.fingerprint()}")
    gen = (np.stack(outs, 1) if outs
           else np.zeros((args.batch, 0), np.int32))
    print(f"generated {gen.shape} in {dt:.2f}s "
          f"({(max_len - 1) * args.batch / dt:.1f} tok/s)")
    print(gen[:, :12])
    return gen


if __name__ == "__main__":
    main()
