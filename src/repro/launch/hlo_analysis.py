"""HLO-text analysis: FLOPs, HBM bytes and collective wire bytes with
*while-loop trip-count multiplication*.

``compiled.cost_analysis()`` counts a while body once; every assigned
arch scans its layer stack, so XLA's own numbers understate compute by
the layer count.  This walker parses the optimized HLO, builds a
per-computation symbol table, and accumulates

  * flops           — dot/convolution ops (2 * prod(result) * K),
  * hbm_bytes       — operand+result bytes of scheduled ops (fusion
                      boundaries = actual HBM round-trips),
  * collectives     — per-kind wire bytes with group-size factors,

multiplying nested computations by their call-site trip counts
(``backend_config={"known_trip_count":{"n":...}}``).
"""
from __future__ import annotations

import dataclasses
import re

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")


def _parse_op_line(line: str):
    """-> (name, result_type, op_kind) or None.  Handles tuple result
    types containing ``/*index=N*/`` comments by balancing parens."""
    m = _NAME_RE.match(line)
    if m is None:
        return None
    name = m.group(1)
    i = m.end()
    if i >= len(line):
        return None
    if line[i] == "(":          # tuple type: scan to balanced close
        depth, j = 1, i + 1
        while j < len(line) and depth:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
            j += 1
        rtype = line[i:j]
        rest = line[j:].lstrip()
    else:
        j = line.find(" ", i)
        if j < 0:
            return None
        rtype = line[i:j]
        rest = line[j:].lstrip()
    km = re.match(r"([\w\-]+)\(", rest)
    if km is None:
        return None
    return name, rtype, km.group(1)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
             "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# data-movement-free ops excluded from HBM byte accounting
_NO_BYTES = {"tuple", "get-tuple-element", "bitcast", "parameter",
             "constant", "after-all", "add-dependency", "while",
             "conditional", "call"}

# Ops a TPU compiler would fuse into neighbours: the CPU backend leaves
# them standalone, which would inflate the memory roofline term.  They
# are skipped from byte accounting under tpu_projection (default).
_FUSABLE = {"add", "subtract", "multiply", "divide", "power", "tanh",
            "exponential", "log", "negate", "abs", "maximum", "minimum",
            "compare", "select", "and", "or", "not", "xor", "convert",
            "broadcast", "iota", "reshape", "rsqrt", "sqrt", "floor",
            "ceil", "round-nearest-afz", "round-nearest-even", "clamp",
            "sign", "cosine", "sine", "atan2", "remainder", "exponential-minus-one",
            "log-plus-one", "shift-left", "shift-right-logical",
            "shift-right-arithmetic", "is-finite", "popcnt", "clz",
            "logistic", "cbrt", "reduce-precision"}


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def _shape_dims(s: str) -> list[list[int]]:
    return [[int(d) for d in dims.split(",") if d]
            for _, dims in _SHAPE_RE.findall(s)]


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    rtype: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symbols: dict          # name -> result type string


def parse_module(hlo: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith("//"):
            continue
        if line.endswith("{") and "->" in line:
            m = _COMP_RE.match(line.strip().removesuffix("{").strip())
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                # parameters: "name: type" pairs in the header
                for pm in re.finditer(r"([\w.\-]+):\s*([\w\[\]{},]+)",
                                      m.group(2)):
                    cur.symbols[pm.group(1)] = pm.group(2)
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        om = _parse_op_line(line)
        if om:
            name, rtype, kind = om
            cur.ops.append(Op(name, kind, rtype, line))
            cur.symbols[name] = rtype
    return comps


def _operands(op: Op):
    """Operand names inside the op's argument parens."""
    start = op.line.index(op.kind + "(") + len(op.kind) + 1
    depth, end = 1, start
    while end < len(op.line) and depth:
        if op.line[end] == "(":
            depth += 1
        elif op.line[end] == ")":
            depth -= 1
        end += 1
    return _OPERAND_RE.findall(op.line[start:end - 1])


def _dot_flops(op: Op, comp: Computation) -> float:
    dims = _shape_dims(op.rtype)
    out_elems = 1
    for d in (dims[0] if dims else []):
        out_elems *= d
    k = 1
    m = _LHS_C_RE.search(op.line)
    if m:
        ops_ = _operands(op)
        if ops_:
            lhs_t = comp.symbols.get(ops_[0])
            if lhs_t:
                lhs_dims = _shape_dims(lhs_t)
                if lhs_dims:
                    for idx in (int(x) for x in m.group(1).split(",")
                                if x):
                        if idx < len(lhs_dims[0]):
                            k *= lhs_dims[0][idx]
    return 2.0 * out_elems * k


def _coll_wire(op: Op) -> tuple[str, float]:
    nbytes = _shape_bytes(op.rtype)
    g = None
    m = _GROUPS_IOTA_RE.search(op.line)
    if m:
        g = int(m.group(2))
    else:
        m2 = _GROUPS_LIST_RE.search(op.line)
        if m2:
            g = len([x for x in m2.group(1).split(",") if x.strip()])
    g = g or 2
    kind = op.kind.removesuffix("-start")
    if kind == "all-gather":
        wire = nbytes * (g - 1) / g
    elif kind == "all-reduce":
        wire = 2 * nbytes * (g - 1) / g
    elif kind == "reduce-scatter":
        wire = nbytes * (g - 1)
    elif kind == "all-to-all":
        wire = nbytes * (g - 1) / g
    else:                       # collective-permute
        wire = float(nbytes)
    return kind, wire


def analyse_hlo(hlo: str, entry: str | None = None,
                tpu_projection: bool = True) -> dict:
    comps = parse_module(hlo)
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
        entry = m.group(1) if m else next(iter(comps))
    memo: dict[str, dict] = {}

    def comp_cost(name: str) -> dict:
        if name in memo:
            return memo[name]
        memo[name] = z = {"flops": 0.0, "hbm_bytes": 0.0,
                          "coll": {k: 0.0 for k in COLLECTIVE_KINDS},
                          "coll_count": 0.0}
        comp = comps.get(name)
        if comp is None:
            return z
        for op in comp.ops:
            kind = op.kind
            if kind in ("dot", "convolution"):
                z["flops"] += _dot_flops(op, comp)
            ck = kind.removesuffix("-start")
            if ck in COLLECTIVE_KINDS and not kind.endswith("-done"):
                k2, wire = _coll_wire(op)
                z["coll"][k2] += wire
                z["coll_count"] += 1
            # nested computations
            if kind == "fusion" or kind == "map":
                cm = _CALLS_RE.search(op.line) or _TO_APPLY_RE.search(
                    op.line)
                if cm:
                    sub = comp_cost(cm.group(1))
                    z["flops"] += sub["flops"]
                    for k3 in COLLECTIVE_KINDS:
                        z["coll"][k3] += sub["coll"][k3]
                    z["coll_count"] += sub["coll_count"]
            elif kind == "call":
                cm = _TO_APPLY_RE.search(op.line)
                if cm:
                    _acc(z, comp_cost(cm.group(1)), 1.0)
            elif kind == "while":
                bm, cm2 = _BODY_RE.search(op.line), _COND_RE.search(
                    op.line)
                tm = _TRIP_RE.search(op.line)
                trips = float(tm.group(1)) if tm else 1.0
                if bm:
                    _acc(z, comp_cost(bm.group(1)), trips)
                if cm2:
                    _acc(z, comp_cost(cm2.group(1)), trips + 1)
            elif kind == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"(?:true|false)_computation=%?([\w.\-]+))", op.line)
                names = []
                for a, b in branches:
                    if a:
                        names += _OPERAND_RE.findall(a)
                    if b:
                        names.append(b)
                if names:
                    worst = max((comp_cost(n) for n in names),
                                key=lambda c: c["flops"] + c["hbm_bytes"])
                    _acc(z, worst, 1.0)
            # HBM bytes: scheduled ops only (operands + result)
            if kind not in _NO_BYTES and not (
                    tpu_projection and kind in _FUSABLE):
                b = _shape_bytes(op.rtype)
                for o in _operands(op):
                    t = comp.symbols.get(o)
                    if t:
                        b += _shape_bytes(t)
                z["hbm_bytes"] += b
        return z

    def _acc(z, sub, mult):
        z["flops"] += sub["flops"] * mult
        z["hbm_bytes"] += sub["hbm_bytes"] * mult
        for k in COLLECTIVE_KINDS:
            z["coll"][k] += sub["coll"][k] * mult
        z["coll_count"] += sub["coll_count"] * mult

    total = comp_cost(entry)
    total = dict(total)
    total["coll_total"] = sum(total["coll"].values())
    return total
