"""Gated MLP (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys

ACT = {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}


def init(key, d_model: int, d_ff: int, gated: bool = True) -> dict:
    ks = split_keys(key, ["gate", "up", "down"])
    p = {"w_up": dense_init(ks["up"], (d_model, d_ff)),
         "w_down": dense_init(ks["down"], (d_ff, d_model))}
    if gated:
        p["w_gate"] = dense_init(ks["gate"], (d_model, d_ff))
    return p


def forward(p, x, act: str = "silu"):
    if "w_gate" in p:
        return (ACT[act](x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return ACT[act](x @ p["w_up"]) @ p["w_down"]
