"""LM assembly: embed -> prefix blocks -> scan(periods) -> suffix ->
final norm -> logits.  Covers all assigned families (dense / MoE / SSM /
hybrid / enc-dec / VLM backbone) from one definition.

The homogeneous middle of every stack runs as ``lax.scan`` over
parameters stacked on a leading ``n_periods`` axis — HLO size stays
bounded for the 61/72-layer configs and the FSDP partitioner sees one
big sharded array per weight.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import attention, blocks, mlp
from repro.models.common import dense_init, rmsnorm, softcap, split_keys
from repro.models.config import EncoderConfig, ModelConfig


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> dict:
    ks = split_keys(key, ["embed", "prefix", "periods", "suffix", "head",
                          "encoder"])
    p: dict = {
        "embed": dense_init(ks["embed"], (cfg.vocab_size, cfg.d_model),
                            scale=1.0),
        "final_norm": (jnp.zeros if cfg.gemma_norm else jnp.ones)(
            (cfg.d_model,), jnp.bfloat16),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks["head"], (cfg.d_model, cfg.vocab_size))
    if cfg.prefix:
        pk = jax.random.split(ks["prefix"], len(cfg.prefix))
        p["prefix"] = [blocks.init_block(k, s, cfg)
                       for k, s in zip(pk, cfg.prefix)]
    if cfg.n_periods:
        def one_period(k):
            kk = jax.random.split(k, len(cfg.period))
            return {f"b{i}": blocks.init_block(kk[i], s, cfg)
                    for i, s in enumerate(cfg.period)}
        period_keys = jax.random.split(ks["periods"], cfg.n_periods)
        per = [one_period(k) for k in period_keys]
        p["periods"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    if cfg.suffix:
        sk = jax.random.split(ks["suffix"], len(cfg.suffix))
        p["suffix"] = [blocks.init_block(k, s, cfg)
                       for k, s in zip(sk, cfg.suffix)]
    if cfg.encoder is not None:
        p["encoder"] = init_encoder(ks["encoder"], cfg)
    return p


def init_encoder(key, cfg: ModelConfig) -> dict:
    enc = cfg.encoder
    ks = jax.random.split(key, enc.n_layers + 1)
    enc_attn = dataclasses.replace(
        cfg.attn, causal=False, n_heads=enc.n_heads, n_kv_heads=enc.n_heads,
        head_dim=enc.d_model // enc.n_heads)
    enc_cfg = dataclasses.replace(
        cfg, d_model=enc.d_model, d_ff=enc.d_ff, attn=enc_attn)
    spec = blocks.BlockSpec(mixer="attn", ff="mlp")
    return {"layers": [blocks.init_block(k, spec, enc_cfg)
                       for k in ks[:-1]],
            "final_norm": jnp.ones((enc.d_model,), jnp.bfloat16)}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _positions_for(cfg: ModelConfig, B, S):
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    if cfg.attn is not None and cfg.attn.mrope_sections is not None:
        return jnp.broadcast_to(pos[None], (3, 1, S))
    return pos


def encode(p, cfg: ModelConfig, frames):
    """Whisper encoder on precomputed (stubbed conv-frontend) frames."""
    enc = cfg.encoder
    enc_attn = dataclasses.replace(
        cfg.attn, causal=False, n_heads=enc.n_heads, n_kv_heads=enc.n_heads,
        head_dim=enc.d_model // enc.n_heads)
    enc_cfg = dataclasses.replace(cfg, d_model=enc.d_model, d_ff=enc.d_ff,
                                  attn=enc_attn)
    spec = blocks.BlockSpec(mixer="attn", ff="mlp")
    x = frames
    pos = jnp.arange(frames.shape[1], dtype=jnp.int32)[None, :]
    for lp in p["encoder"]["layers"]:
        x = blocks.forward(lp, spec, enc_cfg, x, positions=pos)
    return rmsnorm(x, p["encoder"]["final_norm"], cfg.norm_eps)


def embed_tokens(p, cfg: ModelConfig, tokens, vision_embeds=None):
    x = p["embed"][tokens]
    if cfg.gemma_norm:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.vision_prefix and vision_embeds is not None:
        n_vis = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype),
                             x[:, n_vis:]], axis=1)
    return x


def forward(p, cfg: ModelConfig, tokens, *, positions=None,
            vision_embeds=None, encoder_frames=None, use_kernel=False,
            moe_dispatch=None, remat=False):
    """tokens [B, S] -> logits [B, S, V]."""
    B, S = tokens.shape
    x = embed_tokens(p, cfg, tokens, vision_embeds)
    if positions is None:
        positions = _positions_for(cfg, B, S)
    cross_src = (encode(p, cfg, encoder_frames)
                 if cfg.encoder is not None else None)
    kw = dict(positions=positions, cross_src=cross_src,
              use_kernel=use_kernel, moe_dispatch=moe_dispatch)

    for lp, spec in zip(p.get("prefix", []), cfg.prefix):
        x = blocks.forward(lp, spec, cfg, x, **kw)

    if cfg.n_periods:
        def body(x, period_p):
            for i, spec in enumerate(cfg.period):
                x = blocks.forward(period_p[f"b{i}"], spec, cfg, x, **kw)
            return x, None
        if remat:   # recompute period activations in the backward pass
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, p["periods"])

    for lp, spec in zip(p.get("suffix", []), cfg.suffix):
        x = blocks.forward(lp, spec, cfg, x, **kw)

    x = rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=cfg.gemma_norm)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = x @ head
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits


def lm_loss(p, cfg: ModelConfig, tokens, labels, *, reduction="mean",
            **kw):
    """Next-token cross-entropy; labels < 0 are masked.

    reduction="mean": scalar mean over live tokens.
    reduction="sum_count": (sum, live_count) — what data-parallel shards
    exchange so the global mean is exact under uneven masking."""
    logits = forward(p, cfg, tokens, **kw).astype(jnp.float32)
    mask = labels >= 0
    lbl = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lbl[..., None], -1)[..., 0]
    nll = (logz - gold) * mask
    if reduction == "sum_count":
        return nll.sum(), mask.sum()
    return nll.sum() / jnp.maximum(mask.sum(), 1)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    c: dict = {}
    if cfg.prefix:
        c["prefix"] = [blocks.init_cache(s, cfg, batch, max_len)
                       for s in cfg.prefix]
    if cfg.n_periods:
        one = {f"b{i}": blocks.init_cache(s, cfg, batch, max_len)
               for i, s in enumerate(cfg.period)}
        c["periods"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape).copy()
            if hasattr(x, "shape") else x, one)
    if cfg.suffix:
        c["suffix"] = [blocks.init_cache(s, cfg, batch, max_len)
                       for s in cfg.suffix]
    return c


def decode_step(p, cfg: ModelConfig, cache, tokens, *, cross_src=None):
    """tokens [B, 1] -> (logits [B, 1, V], cache')."""
    x = embed_tokens(p, cfg, tokens)
    new_cache: dict = {}
    if cfg.prefix:
        new_cache["prefix"] = []
        for lp, spec, lc in zip(p["prefix"], cfg.prefix, cache["prefix"]):
            x, lc = blocks.decode(lp, spec, cfg, x, lc, cross_src=cross_src)
            new_cache["prefix"].append(lc)
    if cfg.n_periods:
        def body(x, scanned):
            period_p, period_c = scanned
            for i, spec in enumerate(cfg.period):
                x, period_c[f"b{i}"] = blocks.decode(
                    period_p[f"b{i}"], spec, cfg, x, period_c[f"b{i}"],
                    cross_src=cross_src)
            return x, period_c
        x, pc = jax.lax.scan(body, x, (p["periods"], cache["periods"]))
        new_cache["periods"] = pc
    if cfg.suffix:
        new_cache["suffix"] = []
        for lp, spec, lc in zip(p["suffix"], cfg.suffix, cache["suffix"]):
            x, lc = blocks.decode(lp, spec, cfg, x, lc, cross_src=cross_src)
            new_cache["suffix"].append(lc)
    x = rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=cfg.gemma_norm)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = x @ head
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits, new_cache


# ---------------------------------------------------------------------------
# parameter counting
# ---------------------------------------------------------------------------


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))
    total = sum(x.size for x in jax.tree.leaves(shapes))
    if active_only and cfg.moe is not None:
        # discount routed experts to the activated fraction
        def expert_size(tree):
            n = 0
            for k in ("w_gate", "w_up", "w_down"):
                if k in tree:
                    n += tree[k].size
            return n
        moe_total = 0
        for sub in ("prefix", "suffix"):
            for b, spec in zip(shapes.get(sub, []), getattr(cfg, sub)):
                if spec.ff == "moe":
                    moe_total += expert_size(b["moe"])
        if cfg.n_periods and "periods" in shapes:
            for i, spec in enumerate(cfg.period):
                if spec.ff == "moe":
                    moe_total += expert_size(shapes["periods"][f"b{i}"]["moe"])
        frac = 1.0 - cfg.moe.top_k / cfg.moe.n_experts
        total -= int(moe_total * frac)
    return total
