"""Multi-head latent attention (DeepSeek-V2/V3).

Queries and KV are projected through low-rank bottlenecks; the rope part
of the key is shared across heads (computed from the input, not the
latent).  Cache stores only the compressed latent + rope key: decode
memory per token is kv_lora_rank + qk_rope_head_dim — the MLA win.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, rmsnorm, split_keys
from repro.models.config import MLAConfig


def init(key, cfg: MLAConfig, d_model: int) -> dict:
    ks = split_keys(key, ["dq", "uq", "dkv", "uk", "uv", "kr", "o"])
    H = cfg.n_heads
    return {
        "w_dq": dense_init(ks["dq"], (d_model, cfg.q_lora_rank)),
        "q_norm": jnp.ones((cfg.q_lora_rank,), jnp.bfloat16),
        "w_uq": dense_init(ks["uq"], (cfg.q_lora_rank, H * cfg.qk_head_dim)),
        "w_dkv": dense_init(ks["dkv"], (d_model, cfg.kv_lora_rank)),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.bfloat16),
        "w_uk": dense_init(ks["uk"],
                           (cfg.kv_lora_rank, H * cfg.qk_nope_head_dim)),
        "w_uv": dense_init(ks["uv"], (cfg.kv_lora_rank, H * cfg.v_head_dim)),
        "w_kr": dense_init(ks["kr"], (d_model, cfg.qk_rope_head_dim)),
        "wo": dense_init(ks["o"], (H * cfg.v_head_dim, d_model)),
    }


def _latents(p, cfg: MLAConfig, x, positions, eps):
    B, S, _ = x.shape
    H = cfg.n_heads
    q = rmsnorm(x @ p["w_dq"], p["q_norm"], eps) @ p["w_uq"]
    q = q.reshape(B, S, H, cfg.qk_head_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = rmsnorm(x @ p["w_dkv"], p["kv_norm"], eps)        # [B,S,r]
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)                     # [B,S,1,dr]
    if S > 1:
        from repro.models.common import shard_hint
        ckv = shard_hint(ckv, "kv_full")    # SP: latents span the seq
        k_rope = shard_hint(k_rope, "kv_full")
    return q_nope, q_rope, ckv, k_rope


def _attend(p, cfg: MLAConfig, q_nope, q_rope, ckv, k_rope, mask,
            kv=None):
    B, Sq, H, _ = q_nope.shape
    Sk = ckv.shape[1]
    if kv is None:
        k_nope = (ckv @ p["w_uk"]).reshape(B, Sk, H, cfg.qk_nope_head_dim)
        v = (ckv @ p["w_uv"]).reshape(B, Sk, H, cfg.v_head_dim)
    else:
        k_nope, v = kv
    scale = cfg.qk_head_dim ** -0.5
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32))
              + jnp.einsum("bqhd,bkod->bhqk", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    from repro.models.common import shard_hint
    logits = shard_hint(logits, "attn_logits")
    logits = jnp.where(mask[:, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, -1) @ p["wo"]


CHUNK_THRESHOLD = 8192
CHUNK_Q = 512


def forward(p, cfg: MLAConfig, x, *, positions, eps=1e-6,
            use_kernel=False, **_):
    B, S, _ = x.shape
    q_nope, q_rope, ckv, k_rope = _latents(p, cfg, x, positions, eps)
    if use_kernel:
        import os
        if os.environ.get("REPRO_KERNEL_SURROGATE") == "1" \
                and jax.default_backend() == "cpu":
            # flash-MLA HBM signature (dry-run only): q + latent streams
            # in, context out; no [Sq, Sk] scores in HBM.
            H = cfg.n_heads
            mix = (ckv.astype(jnp.float32) @ p["w_uv"]) \
                .reshape(B, S, H, cfg.v_head_dim)
            out = (q_nope.astype(jnp.float32).sum(-1, keepdims=True)
                   + q_rope.astype(jnp.float32).sum(-1, keepdims=True)
                   + k_rope.astype(jnp.float32).sum((-1, -2))[..., None,
                                                              None]
                   + mix)
            return out.reshape(B, S, -1).astype(x.dtype) @ p["wo"]
        # real TPU path: flash kernel on up-projected heads (a fused
        # latent-space MLA kernel is future work, see DESIGN.md)
        from repro.kernels.attention import ops as attn_ops
        k_nope = (ckv @ p["w_uk"]).reshape(B, S, cfg.n_heads,
                                           cfg.qk_nope_head_dim)
        v = (ckv @ p["w_uv"]).reshape(B, S, cfg.n_heads, cfg.v_head_dim)
        q = jnp.concatenate([q_nope, q_rope], -1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, q_rope.shape)], -1)
        vp = jnp.concatenate(
            [v, jnp.zeros(v.shape[:-1] + (q.shape[-1] - v.shape[-1],),
                          v.dtype)], -1)
        out = attn_ops.flash_attention(q, k, vp, True, None, None,
                                       cfg.qk_head_dim ** -0.5)
        return out[..., : cfg.v_head_dim].reshape(B, S, -1) @ p["wo"]
    if S <= CHUNK_THRESHOLD:
        mask = jnp.broadcast_to(
            jnp.tril(jnp.ones((S, S), bool)), (B, S, S))
        return _attend(p, cfg, q_nope, q_rope, ckv, k_rope, mask)
    # q-chunked path: peak O(B*H*bq*S) score memory (32k prefill)
    c = CHUNK_Q
    assert S % c == 0, (S, c)
    nq = S // c
    qs = jnp.moveaxis(q_nope.reshape(B, nq, c, *q_nope.shape[2:]), 1, 0)
    qr = jnp.moveaxis(q_rope.reshape(B, nq, c, *q_rope.shape[2:]), 1, 0)
    kpos = jnp.arange(S)
    H = cfg.n_heads
    k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, cfg.qk_nope_head_dim)
    v = (ckv @ p["w_uv"]).reshape(B, S, H, cfg.v_head_dim)

    def body(_, inp):
        i, qn_c, qr_c = inp
        qpos = i * c + jnp.arange(c)
        mask = jnp.broadcast_to((kpos[None, :] <= qpos[:, None]),
                                (B, c, S))
        return None, _attend(p, cfg, qn_c, qr_c, ckv, k_rope, mask,
                             kv=(k_nope, v))

    _, out = jax.lax.scan(body, None, (jnp.arange(nq), qs, qr))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, -1)


def init_cache(cfg: MLAConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_len, 1, cfg.qk_rope_head_dim), dtype),
            "len": jnp.zeros((), jnp.int32)}


def decode_step(p, cfg: MLAConfig, x, cache, *, eps=1e-6, **_):
    B = x.shape[0]
    t = cache["len"]
    positions = jnp.full((B, 1), t, jnp.int32)
    q_nope, q_rope, ckv, k_rope = _latents(p, cfg, x, positions, eps)
    c2 = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, t, axis=1)
    r2 = jax.lax.dynamic_update_slice_in_dim(cache["kr"], k_rope, t, axis=1)
    S = c2.shape[1]
    mask = jnp.broadcast_to((jnp.arange(S) <= t)[None, None, :], (B, 1, S))
    y = _attend(p, cfg, q_nope, q_rope, c2, r2, mask)
    return y, {"ckv": c2, "kr": r2, "len": t + 1}
