"""Multi-head attention: GQA/MQA, sliding window, logit softcap, qk_norm,
M-RoPE, cross-attention, KV-cache decode.

The score/softmax/value core routes through ``repro.kernels.attention.ops``
(Pallas flash kernel on TPU, jnp reference otherwise); everything around
it (projections, rope, cache) is plain jnp so XLA fuses it with the
surrounding block.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import (apply_mrope, apply_rope, attn_mask,
                                 dense_init, rmsnorm, shard_hint, softcap,
                                 split_keys)
from repro.models.config import AttnConfig


def init(key, cfg: AttnConfig, d_model: int) -> dict:
    H, K, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, ["q", "k", "v", "o", "qn", "kn"])
    p = {
        "wq": dense_init(ks["q"], (d_model, H * D)),
        "wk": dense_init(ks["k"], (d_model, K * D)),
        "wv": dense_init(ks["v"], (d_model, K * D)),
        "wo": dense_init(ks["o"], (H * D, d_model)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((D,), jnp.bfloat16)
        p["k_norm"] = jnp.ones((D,), jnp.bfloat16)
    return p


def _project_qkv(p, cfg: AttnConfig, x, kv_src=None, *, positions=None,
                 eps=1e-6):
    """Returns q [B,Sq,H,D], k,v [B,Sk,K,D] with rope + qk_norm applied."""
    B, S, _ = x.shape
    H, K, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kv_in = x if kv_src is None else kv_src
    q = (x @ p["wq"]).reshape(B, S, H, D)
    k = (kv_in @ p["wk"]).reshape(B, kv_in.shape[1], K, D)
    v = (kv_in @ p["wv"]).reshape(B, kv_in.shape[1], K, D)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], eps)
        k = rmsnorm(k, p["k_norm"], eps)
    if not cfg.cross and cfg.use_rope:  # cross-attn keys carry no rope
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    if k.shape[1] > 1:
        k = shard_hint(k, "kv_full")   # SP: keys gather the sequence
        v = shard_hint(v, "kv_full")
    return q, k, v


def core_attention(q, k, v, mask, *, cap=None, scale=None):
    """Reference core; [B,S,H,D] layout. Kernel-accelerated path lives in
    repro.kernels.attention (selected by the caller via use_kernel)."""
    H, K = q.shape[2], k.shape[2]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    if H != K:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = shard_hint(logits, "attn_logits")
    if cap is not None:
        logits = softcap(logits, cap)
    logits = jnp.where(mask[:, None] if mask.ndim == 3 else mask,
                       logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
    return out


CHUNK_THRESHOLD = 8192     # beyond this, q is processed in chunks
# 2048 amortizes the per-chunk k/v re-read + reduction passes; [B, H,
# 2048, Sk] f32 sharded over (data, model-on-Sq) stays ~1.3 GiB/device
# at the 32k cells (perf iteration 2, EXPERIMENTS.md §Perf)
CHUNK_Q = 2048


def _chunked_core(q, k, v, mpos, *, causal, window, cap, scale=None,
                  chunk=CHUNK_Q):
    """Q-chunked attention: full [bq, Sk] score rows per step, scanned
    over q chunks — peak memory O(B*H*bq*Sk) instead of O(B*H*S^2).
    The jnp analogue of the flash kernel's tiling, used where the Pallas
    path is off (CPU dry-run / non-TPU backends)."""
    B, S, H, D = q.shape
    nq = -(-S // chunk)
    pad = nq * chunk - S
    if pad:
        q = jnp.concatenate(
            [q, jnp.zeros((B, pad) + q.shape[2:], q.dtype)], axis=1)
        mpos = jnp.concatenate(
            [mpos, jnp.full(mpos.shape[:-1] + (pad,), -1, mpos.dtype)],
            axis=-1)
    qs = jnp.moveaxis(q.reshape(B, nq, chunk, H, D), 1, 0)
    qp = jnp.moveaxis(
        jnp.broadcast_to(mpos, (B, mpos.shape[-1]))
        .reshape(B, nq, chunk), 1, 0)
    kpos = jnp.broadcast_to(mpos[..., :1] * 0 + jnp.arange(k.shape[1]),
                            (B, k.shape[1]))

    def body(_, inp):
        qc, qpc = inp
        m = attn_mask(qpc, kpos, causal=causal, window=window)
        m &= (qpc >= 0)[..., None]
        return None, core_attention(qc, k, v, m, cap=cap, scale=scale)

    _, out = jax.lax.scan(body, None, (qs, qp))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * chunk, H, D)
    return out[:, :S]


def forward(p, cfg: AttnConfig, x, *, positions, window=None,
            kv_src=None, eps=1e-6, use_kernel=False):
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, kv_src, positions=positions, eps=eps)
    win = window if window is not None else cfg.window
    if cfg.cross:
        mask = jnp.ones((B, S, k.shape[1]), bool)
        out = core_attention(q, k, v, mask, cap=cfg.softcap)
    elif use_kernel and cfg.causal:
        from repro.kernels.attention import ops as attn_ops
        out = attn_ops.flash_attention(q, k, v, causal=True, window=win,
                                       softcap=cfg.softcap)
    else:
        # M-RoPE carries 3 position streams; masking uses the time stream
        mpos = positions[0] if cfg.mrope_sections is not None else positions
        if S > CHUNK_THRESHOLD:
            out = _chunked_core(q, k, v, mpos, causal=cfg.causal,
                                window=win, cap=cfg.softcap)
        else:
            mask = attn_mask(mpos, mpos, causal=cfg.causal, window=win)
            if mask.ndim == 2:
                mask = jnp.broadcast_to(mask, (B,) + mask.shape)
            else:
                mask = jnp.broadcast_to(mask, (B,) + mask.shape[1:])
            out = core_attention(q, k, v, mask, cap=cfg.softcap)
    return out.reshape(B, S, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    K, D = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, max_len, K, D), dtype),
            "v": jnp.zeros((batch, max_len, K, D), dtype),
            "len": jnp.zeros((), jnp.int32)}


def decode_step(p, cfg: AttnConfig, x, cache, *, window=None, eps=1e-6):
    """One-token decode: x [B, 1, d]; returns (y [B, 1, d], cache')."""
    B = x.shape[0]
    t = cache["len"]
    positions = jnp.full((B, 1), t, jnp.int32)
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions[None], (3, B, 1))
    q, k, v = _project_qkv(p, cfg, x, positions=positions, eps=eps)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, t, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, t, axis=1)
    S = ck.shape[1]
    kpos = jnp.arange(S)[None, :]
    win = window if window is not None else cfg.window
    mask = (kpos <= t)
    if win is not None:
        mask &= kpos > t - win
    mask = jnp.broadcast_to(mask[:, None, :], (B, 1, S))
    out = core_attention(q, ck, cv, mask, cap=cfg.softcap)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"k": ck, "v": cv, "len": t + 1}
