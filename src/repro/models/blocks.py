"""Residual block assembly: (norm -> mixer -> [norm] -> residual) +
(norm -> ff -> [norm] -> residual), with optional cross-attention sublayer.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models import attention, mamba, mla, mlp, moe, rwkv
from repro.models.common import dense_init, rmsnorm, split_keys
from repro.models.config import BlockSpec, ModelConfig


def _norm_scale(cfg: ModelConfig, d):
    # gemma parameterizes rmsnorm as (1 + w) with w ~ 0; others as w ~ 1
    return (jnp.zeros if cfg.gemma_norm else jnp.ones)((d,), jnp.bfloat16)


def init_block(key, spec: BlockSpec, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = split_keys(key, ["mixer", "cross", "ff"])
    p = {"norm_mixer": _norm_scale(cfg, d)}
    if spec.mixer == "attn":
        p["attn"] = attention.init(ks["mixer"], cfg.attn, d)
    elif spec.mixer == "mla":
        p["mla"] = mla.init(ks["mixer"], cfg.mla, d)
    elif spec.mixer == "rwkv":
        p["rwkv"] = rwkv.init(ks["mixer"], cfg.rwkv, d)
    elif spec.mixer == "mamba":
        p["mamba"] = mamba.init(ks["mixer"], cfg.mamba, d)
    if spec.cross:
        p["norm_cross"] = _norm_scale(cfg, d)
        cross_cfg = dataclasses.replace(cfg.attn, cross=True, causal=False)
        p["cross"] = attention.init(ks["cross"], cross_cfg, d)
    if cfg.post_block_norm:
        p["norm_mixer_post"] = _norm_scale(cfg, d)
    if spec.ff != "none":
        p["norm_ff"] = _norm_scale(cfg, d)
        if cfg.post_block_norm:
            p["norm_ff_post"] = _norm_scale(cfg, d)
    if spec.ff == "mlp":
        p["mlp"] = mlp.init(ks["ff"], d, cfg.d_ff, cfg.gated_mlp)
    elif spec.ff == "moe":
        p["moe"] = moe.init(ks["ff"], cfg.moe, d)
    elif spec.ff == "cmix":
        p["cmix"] = rwkv.channel_mix_init(ks["ff"], d, cfg.d_ff)
    return p


def _norm(cfg, x, w):
    return rmsnorm(x, w, cfg.norm_eps, gemma_style=cfg.gemma_norm)


def forward(p, spec: BlockSpec, cfg: ModelConfig, x, *, positions,
            cross_src=None, use_kernel=False, moe_dispatch=None):
    """Full-sequence block; x [B, S, d]."""
    from repro.models.common import shard_hint
    if spec.mixer in ("attn", "mla"):
        # level-2 hint: sequence-parallel residual stream (no-op unless
        # the launcher enabled it)
        x = shard_hint(x, "residual")
    h = _norm(cfg, x, p["norm_mixer"])
    if spec.mixer == "attn":
        h = attention.forward(p["attn"], cfg.attn, h, positions=positions,
                              window=spec.window, eps=cfg.norm_eps,
                              use_kernel=use_kernel)
    elif spec.mixer == "mla":
        h = mla.forward(p["mla"], cfg.mla, h, positions=positions,
                        eps=cfg.norm_eps, use_kernel=use_kernel)
    elif spec.mixer == "rwkv":
        h = rwkv.time_mix(p["rwkv"], cfg.rwkv, h, use_kernel=use_kernel)
    elif spec.mixer == "mamba":
        h = mamba.forward(p["mamba"], cfg.mamba, h, eps=cfg.norm_eps,
                          use_kernel=use_kernel)
    else:
        h = jnp.zeros_like(h)
    if cfg.post_block_norm:
        h = _norm(cfg, h, p["norm_mixer_post"])
    x = x + h
    if spec.cross:
        h = _norm(cfg, x, p["norm_cross"])
        h = attention.forward(
            p["cross"], dataclasses.replace(cfg.attn, cross=True,
                                            causal=False),
            h, positions=positions, kv_src=cross_src, eps=cfg.norm_eps)
        x = x + h
    if spec.ff == "none":
        return x
    h = _norm(cfg, x, p["norm_ff"])
    if spec.ff == "mlp":
        h = mlp.forward(p["mlp"], h, cfg.mlp_act)
    elif spec.ff == "moe":
        if moe_dispatch is None:
            h = moe.forward(p["moe"], cfg.moe, h, cfg.mlp_act)
        else:
            h = moe_dispatch(p["moe"], cfg.moe, h)
    elif spec.ff == "cmix":
        h = rwkv.channel_mix(p["cmix"], h)
    if cfg.post_block_norm:
        h = _norm(cfg, h, p["norm_ff_post"])
    return x + h


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(spec: BlockSpec, cfg: ModelConfig, batch: int, max_len: int):
    c = {}
    if spec.mixer == "attn":
        c["attn"] = attention.init_cache(cfg.attn, batch, max_len)
    elif spec.mixer == "mla":
        c["mla"] = mla.init_cache(cfg.mla, batch, max_len)
    elif spec.mixer == "rwkv":
        c["rwkv"] = rwkv.init_state(cfg.rwkv, batch, cfg.d_model)
    elif spec.mixer == "mamba":
        c["mamba"] = mamba.init_state(cfg.mamba, batch, cfg.d_model)
    if spec.ff == "cmix":
        c["cmix"] = {"x_cm": jnp.zeros((batch, cfg.d_model), jnp.bfloat16)}
    return c


def decode(p, spec: BlockSpec, cfg: ModelConfig, x, cache, *,
           cross_src=None):
    """One-token decode; x [B, 1, d]."""
    h = _norm(cfg, x, p["norm_mixer"])
    if spec.mixer == "attn":
        h, cache["attn"] = attention.decode_step(
            p["attn"], cfg.attn, h, cache["attn"], window=spec.window,
            eps=cfg.norm_eps)
    elif spec.mixer == "mla":
        h, cache["mla"] = mla.decode_step(p["mla"], cfg.mla, h,
                                          cache["mla"], eps=cfg.norm_eps)
    elif spec.mixer == "rwkv":
        h, cache["rwkv"] = rwkv.decode_time_mix(p["rwkv"], cfg.rwkv, h,
                                                cache["rwkv"])
    elif spec.mixer == "mamba":
        h, cache["mamba"] = mamba.decode_step(p["mamba"], cfg.mamba, h,
                                              cache["mamba"],
                                              eps=cfg.norm_eps)
    else:
        h = jnp.zeros_like(h)
    if cfg.post_block_norm:
        h = _norm(cfg, h, p["norm_mixer_post"])
    x = x + h
    if spec.cross:
        h = _norm(cfg, x, p["norm_cross"])
        h = attention.forward(
            p["cross"], dataclasses.replace(cfg.attn, cross=True,
                                            causal=False),
            h, positions=jnp.zeros((x.shape[0], 1), jnp.int32),
            kv_src=cross_src, eps=cfg.norm_eps)
        x = x + h
    if spec.ff == "none":
        return x, cache
    h = _norm(cfg, x, p["norm_ff"])
    if spec.ff == "mlp":
        h = mlp.forward(p["mlp"], h, cfg.mlp_act)
    elif spec.ff == "moe":
        # capacity dispatch: dense-dispatch FLOPs scale with E, absurd
        # for one-token decode over 256 experts
        h = moe.forward_dropless(p["moe"], cfg.moe, h, cfg.mlp_act,
                                 capacity_factor=2.0)
    elif spec.ff == "cmix":
        h, cache["cmix"] = rwkv.decode_channel_mix(p["cmix"], h,
                                                   cache["cmix"])
    if cfg.post_block_norm:
        h = _norm(cfg, h, p["norm_ff_post"])
    return x + h, cache
