"""RWKV-6 (Finch): data-dependent-decay linear attention (arXiv:2404.05892).

Time-mix (wkv6) per head of size N:
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
with per-channel decay w_t = exp(-exp(w0 + lora_w(x))) — the Finch
novelty (data-dependent w).  Token-shift interpolations are likewise
data-dependent through small LoRAs.

The recurrence reference here is an O(T) ``lax.scan``; the TPU hot path
is the chunked Pallas kernel in ``repro.kernels.wkv6`` (selected via
``use_kernel``).  Decode carries S as an O(1) state — this is why
rwkv6-3b runs the 500k-token cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys
from repro.models.config import RWKVConfig


def init(key, cfg: RWKVConfig, d_model: int) -> dict:
    ks = split_keys(key, ["r", "k", "v", "w", "g", "o", "lw", "lg",
                          "mu", "u", "w0", "ln", "cr", "ck", "cv"])
    n_heads = d_model // cfg.head_dim
    p = {
        # time-mix projections
        "wr": dense_init(ks["r"], (d_model, d_model)),
        "wk": dense_init(ks["k"], (d_model, d_model)),
        "wv": dense_init(ks["v"], (d_model, d_model)),
        "wg": dense_init(ks["g"], (d_model, d_model)),
        "wo": dense_init(ks["o"], (d_model, d_model)),
        # data-dependent decay lora: d -> L -> d
        "w_lora_a": dense_init(ks["lw"], (d_model, cfg.decay_lora)),
        "w_lora_b": dense_init(ks["w0"], (cfg.decay_lora, d_model),
                               scale=0.01),
        "w0": jnp.full((d_model,), -6.0, jnp.float32),   # slow decay init
        # token-shift mixing coefficients (static part; 5 streams r,k,v,w,g)
        "mu": jax.random.uniform(ks["mu"], (5, d_model), jnp.float32),
        # per-channel bonus
        "u": (jax.random.normal(ks["u"], (d_model,), jnp.float32) * 0.1),
        # group-norm per head after wkv
        "ln_w": jnp.ones((d_model,), jnp.float32),
        "ln_b": jnp.zeros((d_model,), jnp.float32),
    }
    assert n_heads * cfg.head_dim == d_model
    return p


def channel_mix_init(key, d_model: int, d_ff: int) -> dict:
    ks = split_keys(key, ["r", "k", "v", "mu"])
    return {"wr": dense_init(ks["r"], (d_model, d_model)),
            "wk": dense_init(ks["k"], (d_model, d_ff)),
            "wv": dense_init(ks["v"], (d_ff, d_model)),
            "mu": jax.random.uniform(ks["mu"], (2, d_model), jnp.float32)}


def _token_shift(x, last=None):
    """shifted[t] = x[t-1]; position 0 gets ``last`` (decode carry) or 0."""
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def wkv_scan(r, k, v, w, u, s0=None):
    """Reference recurrence.  r,k,v,w: [B, T, H, N]; u: [H, N].
    Returns y [B, T, H, N] and final state [B, H, N, N]."""
    B, T, H, N = r.shape
    s = (jnp.zeros((B, H, N, N), jnp.float32) if s0 is None
         else s0.astype(jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp                                 # [B, H, N]
        kv = kt[..., :, None] * vt[..., None, :]             # [B,H,N,N]
        y = jnp.einsum("bhn,bhnm->bhm", rt,
                       s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = (jnp.moveaxis(r, 1, 0).astype(jnp.float32),
          jnp.moveaxis(k, 1, 0).astype(jnp.float32),
          jnp.moveaxis(v, 1, 0).astype(jnp.float32),
          jnp.moveaxis(w, 1, 0).astype(jnp.float32))
    s, ys = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(ys, 0, 1), s


def _mix_streams(p, cfg, x, shifted):
    xx = shifted - x
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + xx * mu[i] for i in range(5))
    d = x.shape[-1]
    H, N = d // cfg.head_dim, cfg.head_dim
    shp = x.shape[:-1] + (H, N)
    r = (xr @ p["wr"]).reshape(shp)
    k = (xk @ p["wk"]).reshape(shp)
    v = (xv @ p["wv"]).reshape(shp)
    g = jax.nn.silu(xg @ p["wg"])
    w = jnp.exp(-jnp.exp(
        p["w0"].astype(jnp.float32)
        + ((xw @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)))
    w = w.reshape(shp)
    return r, k, v, w, g


def _group_norm(y, p, eps=1e-5):
    """Per-head layernorm of the wkv output."""
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + eps)
    flat = y.reshape(y.shape[:-2] + (-1,))
    return flat * p["ln_w"] + p["ln_b"]


def time_mix(p, cfg: RWKVConfig, x, *, use_kernel=False):
    """Full-sequence time-mix: x [B, T, d] -> [B, T, d]."""
    B, T, d = x.shape
    H, N = d // cfg.head_dim, cfg.head_dim
    r, k, v, w, g = _mix_streams(p, cfg, x, _token_shift(x))
    u = p["u"].reshape(H, N)
    if use_kernel:
        from repro.kernels.wkv6 import ops as wkv_ops
        y = wkv_ops.wkv6(r, k, v, w, u)
    else:
        y, _ = wkv_scan(r, k, v, w, u)
    y = _group_norm(y, p).astype(x.dtype) * g
    return y @ p["wo"]


def channel_mix(p, x, last=None):
    shifted = _token_shift(x, last)
    xx = shifted - x
    mu = p["mu"].astype(x.dtype)
    xk, xr = x + xx * mu[0], x + xx * mu[1]
    r = jax.nn.sigmoid(xr @ p["wr"])
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return r * (k @ p["wv"])


# ---------------------------------------------------------------------------
# decode (O(1) state)
# ---------------------------------------------------------------------------


def init_state(cfg: RWKVConfig, batch: int, d_model: int):
    H, N = d_model // cfg.head_dim, cfg.head_dim
    return {"s": jnp.zeros((batch, H, N, N), jnp.float32),
            "x_tm": jnp.zeros((batch, d_model), jnp.bfloat16),
            "x_cm": jnp.zeros((batch, d_model), jnp.bfloat16)}


def decode_time_mix(p, cfg: RWKVConfig, x, state):
    """x: [B, 1, d]; O(1) per-token state update."""
    B, _, d = x.shape
    H, N = d // cfg.head_dim, cfg.head_dim
    r, k, v, w, g = _mix_streams(p, cfg, x, state["x_tm"][:, None])
    u = p["u"].reshape(H, N)
    y, s = wkv_scan(r, k, v, w, u, s0=state["s"])
    y = _group_norm(y, p).astype(x.dtype) * g
    state = dict(state, s=s, x_tm=x[:, 0].astype(state["x_tm"].dtype))
    return y @ p["wo"], state


def decode_channel_mix(p, x, state):
    y = channel_mix(p, x, last=state["x_cm"].astype(x.dtype))
    return y, dict(state, x_cm=x[:, 0].astype(state["x_cm"].dtype))
