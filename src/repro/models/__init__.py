from repro.models.config import (  # noqa: F401
    AttnConfig, MLAConfig, MambaConfig, ModelConfig, MoEConfig,
    RWKVConfig, BlockSpec,
)
from repro.models.model import init_params, forward, lm_loss  # noqa: F401
