"""Shared model primitives: norms, rotary embeddings, masks, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm(x, scale, eps=1e-6, *, gemma_style=False):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if gemma_style \
        else scale.astype(jnp.float32)
    return (y * w).astype(x.dtype)


def softcap(x, cap):
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)      # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, D/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Qwen2-VL multimodal RoPE: three position streams (t, h, w) rotate
    disjoint frequency sections.  positions3: [3, ..., S]."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)      # [D/2]
    # section s uses positions3[s] for its slice of freq indices
    sec = np.concatenate([[0], np.cumsum(np.asarray(sections))])
    assert sec[-1] == d // 2, (sections, d)
    which = np.zeros(d // 2, np.int32)
    for i in range(len(sections)):
        which[sec[i]: sec[i + 1]] = i
    # gather per-frequency position stream: [..., S, D/2]
    p = jnp.moveaxis(positions3, 0, -1).astype(jnp.float32)     # [..., S, 3]
    p = p[..., which]                                            # [..., S, D/2]
    ang = p * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def attn_mask(q_pos, k_pos, *, causal=True, window=None):
    """Boolean [..., Sq, Sk] mask; True = attend.  ``window`` counts how
    far back attention reaches (gemma2 local layers)."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        m &= k <= q
    if window is not None:
        m &= k > q - window
    return m


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# sharding hints (set by launchers; no-ops outside a production mesh)
# ---------------------------------------------------------------------------

_HINT_MESH = None
_HINT_LEVEL = 1


def set_shard_mesh(mesh, level: int = 1):
    """Launchers register the mesh so model internals can place sharding
    constraints.  level 1 (baseline): context-parallel attention logits
    only.  level 2 (+SP): the residual stream itself is sequence-sharded
    over "model" between blocks, so norms/projections/MLP run on the
    sequence shard and only attention's k/v gather the full sequence —
    Megatron sequence parallelism generalized to this mesh.  None
    disables."""
    global _HINT_MESH, _HINT_LEVEL
    _HINT_MESH = mesh
    _HINT_LEVEL = level


def shard_hint(x, role: str):
    """Constraint for big intermediates.  'attn_logits': [B, H, Sq, Sk]
    — none of the assigned archs have H divisible by the 16-wide model
    axis, so attention compute is sharded over the *query sequence*
    (context parallelism) instead; batch rides the data axes."""
    mesh = _HINT_MESH
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    names = mesh.axis_names
    model_n = mesh.shape["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    bn = 1
    for a in batch_axes:
        bn *= mesh.shape[a]
    if role == "attn_logits":
        B, H, Sq, Sk = x.shape
        spec = [batch_axes if B % bn == 0 else None, None, None, None]
        if H % model_n == 0:
            spec[1] = "model"
        elif Sq % model_n == 0:
            spec[2] = "model"
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))
    if role == "residual" and _HINT_LEVEL >= 2:
        B, S, d = x.shape
        if S % model_n or B % bn:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(batch_axes, "model", None)))
    if role == "kv_full" and _HINT_LEVEL >= 2:
        # k/v must carry the whole sequence: gather over "model"
        B = x.shape[0]
        spec = [batch_axes if B % bn == 0 else None] \
            + [None] * (x.ndim - 1)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))
    return x


def dense_init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(jnp.bfloat16)


def split_keys(key, names):
    ks = jax.random.split(key, len(names))
    return dict(zip(names, ks))
