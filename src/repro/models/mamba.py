"""Mamba-1 selective SSM (Jamba's attention-free mixer).

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t        (per channel)
    y_t = C_t . h_t + D * x_t
with input-dependent dt, B, C (the selectivity).  Sequence processing is
a chunked ``lax.scan`` (memory-bounded); decode carries (conv window,
h) as O(1) state — this is why jamba runs the 500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm, split_keys
from repro.models.config import MambaConfig


def _dt_rank(cfg: MambaConfig, d_model: int) -> int:
    return cfg.dt_rank or -(-d_model // 16)


def init(key, cfg: MambaConfig, d_model: int) -> dict:
    d_inner = cfg.expand * d_model
    R = _dt_rank(cfg, d_model)
    ks = split_keys(key, ["in", "conv", "xp", "dtp", "out", "dt"])
    A = jnp.broadcast_to(jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32),
                         (d_inner, cfg.d_state))
    return {
        "w_in": dense_init(ks["in"], (d_model, 2 * d_inner)),
        "conv_w": dense_init(ks["conv"], (cfg.d_conv, d_inner), scale=0.5),
        "conv_b": jnp.zeros((d_inner,), jnp.bfloat16),
        "w_x": dense_init(ks["xp"], (d_inner, R + 2 * cfg.d_state)),
        "w_dt": dense_init(ks["dtp"], (R, d_inner), scale=R ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks["dt"], (d_inner,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_inner,), jnp.float32),
        # jamba's inner norms on dt/B/C
        "dt_norm": jnp.ones((R,), jnp.bfloat16),
        "b_norm": jnp.ones((cfg.d_state,), jnp.bfloat16),
        "c_norm": jnp.ones((cfg.d_state,), jnp.bfloat16),
        "w_out": dense_init(ks["out"], (d_inner, d_model)),
    }


def _conv(x, w, b, carry=None):
    """Depthwise causal conv1d; x [B,T,Di], w [K,Di].  ``carry`` is the
    last K-1 inputs from the previous segment (decode)."""
    K = w.shape[0]
    pad = (jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
           if carry is None else carry)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b), xp[:, -(K - 1):]


def _ssm_inputs(p, cfg: MambaConfig, xc, eps=1e-6):
    R = p["w_dt"].shape[0]
    proj = xc @ p["w_x"]
    dt, B, C = jnp.split(proj, [R, R + cfg.d_state], axis=-1)
    dt = rmsnorm(dt, p["dt_norm"], eps)
    B = rmsnorm(B, p["b_norm"], eps).astype(jnp.float32)
    C = rmsnorm(C, p["c_norm"], eps).astype(jnp.float32)
    dt = jax.nn.softplus((dt @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"])                       # [B,T,Di]
    A = -jnp.exp(p["A_log"])                                   # [Di,S]
    dA = jnp.exp(dt[..., None] * A)                            # [B,T,Di,S]
    dBx = (dt * xc.astype(jnp.float32))[..., None] * B[..., None, :]
    return dA, dBx, C


def forward(p, cfg: MambaConfig, x, *, eps=1e-6, use_kernel=False, **_):
    """x: [B, T, d] -> [B, T, d] (full sequence).

    The selective-scan inputs (dt, B, C -> dA, dBx) are computed *inside*
    the scan step from the small projections: materializing dA/dBx over
    the full sequence is [B, T, d_inner, d_state] floats — tens of TB at
    jamba scale — where the on-the-fly form streams only [B, T, d_inner]
    activations (EXPERIMENTS.md §Perf, jamba iteration 1)."""
    Bsz, T, d = x.shape
    d_inner = cfg.expand * d
    R = p["w_dt"].shape[0]
    xz = x @ p["w_in"]
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _conv(xc, p["conv_w"], p["conv_b"])
    proj = xc @ p["w_x"]                        # [B, T, R + 2*d_state]
    A = -jnp.exp(p["A_log"])                    # [Di, S]
    h0 = jnp.zeros((Bsz, d_inner, cfg.d_state), jnp.float32)

    if use_kernel:
        # Pallas selective-scan: state + per-step temporaries in VMEM;
        # HBM sees the xc/dt/B/C streams once (kernels/mamba_scan)
        from repro.kernels.mamba_scan import ops as ssm_ops
        S_ = cfg.d_state
        dts = rmsnorm(proj[..., :R], p["dt_norm"], eps)
        dts = jax.nn.softplus((dts @ p["w_dt"]).astype(jnp.float32)
                              + p["dt_bias"]).astype(jnp.bfloat16)
        Bc = rmsnorm(proj[..., R: R + S_], p["b_norm"], eps)
        Cc = rmsnorm(proj[..., R + S_:], p["c_norm"], eps)
        y = ssm_ops.selective_scan(xc, dts, Bc, Cc, A, p["D"])
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
        return y @ p["w_out"]

    def step(h, inp):
        xc_t, pr_t = inp                        # [B, Di], [B, R+2S]
        dt = rmsnorm(pr_t[:, :R], p["dt_norm"], eps)
        Bc = rmsnorm(pr_t[:, R: R + cfg.d_state], p["b_norm"],
                     eps).astype(jnp.float32)
        Cc = rmsnorm(pr_t[:, R + cfg.d_state:], p["c_norm"],
                     eps).astype(jnp.float32)
        dt = jax.nn.softplus((dt @ p["w_dt"]).astype(jnp.float32)
                             + p["dt_bias"])                   # [B, Di]
        dA = jnp.exp(dt[..., None] * A)                        # [B,Di,S]
        dBx = (dt * xc_t.astype(jnp.float32))[..., None] \
            * Bc[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bds,bs->bd", h, Cc)
        return h, y

    # chunk-remat: AD through a plain scan stacks h for every step —
    # [T, B, Di, S] f32, tens of TB at jamba scale.  Saving h only at
    # chunk boundaries and recomputing inside the chunk caps the stack
    # at [T/L, B, Di, S] (EXPERIMENTS.md §Perf, jamba iteration 2).
    L = 64
    while T % L:
        L //= 2
    nC = T // L

    def chunk_fn(h, inp):
        return jax.lax.scan(step, h, inp)

    chunk_fn = jax.checkpoint(chunk_fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    xs = (jnp.moveaxis(xc, 1, 0).reshape(nC, L, Bsz, d_inner),
          jnp.moveaxis(proj, 1, 0).reshape(nC, L, Bsz, proj.shape[-1]))
    _, ys = jax.lax.scan(chunk_fn, h0, xs)
    y = jnp.moveaxis(ys.reshape(T, Bsz, d_inner), 0, 1)        # [B,T,Di]
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["w_out"]


def init_state(cfg: MambaConfig, batch: int, d_model: int):
    d_inner = cfg.expand * d_model
    return {"h": jnp.zeros((batch, d_inner, cfg.d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.d_conv - 1, d_inner),
                              jnp.bfloat16)}


def decode_step(p, cfg: MambaConfig, x, state, eps=1e-6):
    """x: [B, 1, d]; O(1) state update."""
    xz = x @ p["w_in"]
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, conv_carry = _conv(xc, p["conv_w"], p["conv_b"],
                           carry=state["conv"].astype(xc.dtype))
    dA, dBx, C = _ssm_inputs(p, cfg, xc, eps)
    h = dA[:, 0] * state["h"] + dBx[:, 0]
    y = jnp.einsum("bds,bs->bd", h, C[:, 0])[:, None]
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["w_out"], {"h": h, "conv": conv_carry.astype(jnp.bfloat16)}
