"""Model configuration: one dataclass family covers every assigned arch.

A model is a stack of *blocks* described by ``BlockSpec``s.  Stacks are
expressed as ``prefix + period * n_periods + suffix`` so that the long
homogeneous middle compiles as one ``lax.scan`` over stacked parameters
(bounded HLO for the 61/72-layer configs) while heterogeneous patterns
(gemma2's local/global alternation, jamba's 1-attn-per-8 interleave,
deepseek's dense prefix) stay exact.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: int | None = None          # sliding-window size (None = full)
    softcap: float | None = None       # attention logit soft-capping
    qk_norm: bool = False              # rmsnorm on q/k heads (qwen3)
    mrope_sections: tuple[int, ...] | None = None   # qwen2-vl M-RoPE
    causal: bool = True                # False for encoder self-attention
    cross: bool = False                # cross-attention (whisper decoder)
    use_rope: bool = True              # jamba attention is position-free


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int
    n_heads: int
    rope_theta: float = 10000.0

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                       # per-expert ffn hidden dim
    n_shared: int = 0                   # shared (always-on) experts
    router: Literal["softmax", "sigmoid"] = "softmax"
    route_scale: float = 1.0
    norm_topk: bool = True              # renormalize top-k weights


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64                  # wkv head size (finch)
    decay_lora: int = 64
    mix_lora: int = 32


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None          # default ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One residual block: a mixer + a feed-forward."""
    mixer: Literal["attn", "mla", "rwkv", "mamba", "none"]
    ff: Literal["mlp", "moe", "cmix", "none"]
    # gemma2-style per-block attention window override (None = cfg default)
    window: int | None = None
    # whisper decoder: additional cross-attention sublayer after the mixer
    cross: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab_size: int
    d_ff: int
    # stack structure
    prefix: tuple[BlockSpec, ...]
    period: tuple[BlockSpec, ...]
    n_periods: int
    suffix: tuple[BlockSpec, ...] = ()
    # sub-configs (present when the stack uses the mixer/ff)
    attn: AttnConfig | None = None
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    rwkv: RWKVConfig | None = None
    mamba: MambaConfig | None = None
    # misc
    mlp_act: Literal["silu", "gelu"] = "silu"
    gated_mlp: bool = True                   # whisper uses plain fc-act-fc
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    final_softcap: float | None = None       # gemma2 final-logit softcap
    gemma_norm: bool = False                 # (1 + scale) rmsnorm + embed scaling
    post_block_norm: bool = False            # gemma2 post-attn/ffn norms
    # encoder (whisper): an encoder stack consuming precomputed frames
    encoder: "EncoderConfig | None" = None
    # vlm: number of leading positions fed by precomputed patch embeds
    vision_prefix: int = 0

    @property
    def n_layers(self) -> int:
        return (len(self.prefix) + len(self.period) * self.n_periods
                + len(self.suffix))

    def blocks(self) -> list[BlockSpec]:
        return (list(self.prefix) + list(self.period) * self.n_periods
                + list(self.suffix))

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        from repro.models.model import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_frames: int = 1500          # whisper: fixed post-conv frame count
