"""Mixture-of-experts feed-forward.

Routing follows the arch configs: softmax top-k (jamba/moonshot) or
sigmoid with normalized top-k scores (deepseek-v3), plus optional shared
experts that see every token (deepseek: 1 shared + 256 routed).

Two compute paths:
  * ``forward`` — einsum-dense dispatch: every expert multiplies every
    token, masked by routing weights.  Exact, simple, ideal for smoke
    tests and small expert counts.
  * ``forward_dropless`` — capacity-bounded gather dispatch used by the
    distributed train step (tokens sorted to experts, EP alltoall handled
    one level up in train/).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import mlp
from repro.models.common import dense_init, split_keys
from repro.models.config import MoEConfig


def init(key, cfg: MoEConfig, d_model: int) -> dict:
    ks = split_keys(key, ["router", "experts", "shared"])
    ek = jax.random.split(ks["experts"], 3)
    p = {
        "router": dense_init(ks["router"], (d_model, cfg.n_experts),
                             scale=d_model ** -0.5).astype(jnp.float32),
        # stacked experts: [E, ...]
        "w_gate": _stack(ek[0], cfg.n_experts, d_model, cfg.d_expert),
        "w_up": _stack(ek[1], cfg.n_experts, d_model, cfg.d_expert),
        "w_down": _stack(ek[2], cfg.n_experts, cfg.d_expert, d_model,
                         transpose=True),
    }
    if cfg.router == "sigmoid":
        p["router_bias"] = jnp.zeros((cfg.n_experts,), jnp.float32)
    if cfg.n_shared:
        p["shared"] = mlp.init(ks["shared"], d_model,
                               cfg.d_expert * cfg.n_shared)
    return p


def _stack(key, e, a, b, transpose=False):
    shape = (e, b, a) if transpose else (e, a, b)
    w = dense_init(key, shape)
    return jnp.swapaxes(w, 1, 2) if transpose else w


def route(p, cfg: MoEConfig, x):
    """x: [T, d] -> (weights [T, k], idx [T, k], probs [T, E])."""
    logits = x.astype(jnp.float32) @ p["router"]
    if cfg.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"]          # bias only biases selection
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        sel = scores
    _, idx = jax.lax.top_k(sel, cfg.top_k)
    w = jnp.take_along_axis(scores, idx, axis=-1)
    if cfg.norm_topk:
        w = w / (w.sum(-1, keepdims=True) + 1e-20)
    return (w * cfg.route_scale).astype(x.dtype), idx, scores


def forward(p, cfg: MoEConfig, x, act: str = "silu"):
    """Dense-dispatch MoE: x [B, S, d] -> [B, S, d]."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    w, idx, _ = route(p, cfg, xt)                  # [T,k], [T,k]
    onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=x.dtype)   # [T,k,E]
    cw = jnp.einsum("tk,tke->te", w, onehot)       # [T, E] combine weights
    h = jnp.einsum("td,edf->tef", xt, p["w_gate"])
    u = jnp.einsum("td,edf->tef", xt, p["w_up"])
    h = mlp.ACT[act](h) * u
    y = jnp.einsum("tef,efd->ted", h, p["w_down"])
    out = jnp.einsum("ted,te->td", y, cw)
    if cfg.n_shared:
        out = out + mlp.forward(p["shared"], xt, act)
    return out.reshape(B, S, d)


def forward_dropless(p, cfg: MoEConfig, x, act: str = "silu",
                     capacity_factor: float = 1.25):
    """Capacity-bounded gather dispatch: tokens are bucketed per expert
    (static capacity C = ceil(T * k / E * factor)); overflow drops.
    This is the single-device form of the EP dispatch in train/."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    w, idx, _ = route(p, cfg, xt)
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(T * K / E * capacity_factor))
    flat_e = idx.reshape(-1)                                 # [T*K]
    # position of each (token, slot) within its expert bucket
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # [T*K, E]
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    pos = jnp.take_along_axis(pos, flat_e[:, None], 1)[:, 0]  # [T*K]
    keep = pos < C
    dest = jnp.where(keep, flat_e * C + pos, E * C)          # drop slot
    buckets = jnp.zeros((E * C + 1, d), xt.dtype)
    src = jnp.repeat(xt, K, axis=0)
    buckets = buckets.at[dest].set(src)
    be = buckets[: E * C].reshape(E, C, d)
    h = mlp.ACT[act](jnp.einsum("ecd,edf->ecf", be, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", be, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    flat_y = jnp.concatenate([ye.reshape(E * C, d),
                              jnp.zeros((1, d), xt.dtype)])
    gathered = flat_y[dest].reshape(T, K, d)
    out = jnp.einsum("tkd,tk->td", gathered, w)
    if cfg.n_shared:
        out = out + mlp.forward(p["shared"], xt, act)
    return out.reshape(B, S, d)


def aux_loss(cfg: MoEConfig, probs, idx):
    """Switch-style load-balance loss over router probs [T,E], idx [T,k]."""
    E = cfg.n_experts
    load = jax.nn.one_hot(idx, E).sum((0, 1)) / idx.shape[0]  # frac routed
    imp = probs.mean(0)
    return E * jnp.sum(load * imp)
