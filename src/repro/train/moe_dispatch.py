"""Expert-parallel MoE dispatch through the MPIX layer (paper §2.1+§2.2).

Experts are sharded over the EP axes (("pod","model") when the expert
count divides, else ("model",)); tokens travel to their experts through
``mpix_alltoall`` with a *selectable algorithm* — on the multi-pod mesh
the ``hierarchical`` algorithm aggregates everything headed to a remote
pod inside the source pod first (one DCN bundle per pod-pair stripe),
which is exactly the paper's locality-aware optimization applied to MoE
traffic.

Layout contract inside the shard_map:
  x        [B_local, S, d]   batch sharded over (pod, data); replicated
                             over model — each model rank takes its
                             1/M slice of the tokens.
  experts  [E_local, d, f]   E sharded over the EP axes.
  router   [d, E]            replicated.

Dispatch is capacity-based (static shapes; overflow drops, standard for
TPU MoE): per-source capacity C = ceil(T_slice * k / E * factor).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import api as mpix
from repro.core.transport import _flat_rank
from repro.models import mlp, moe
from repro.models.config import MoEConfig

from repro import compat


@dataclasses.dataclass(frozen=True)
class EPOptions:
    alltoall: str = "xla"           # mpix algorithm for dispatch/return
    allgather: str = "xla"          # rebuild of the token slice
    capacity_factor: float = 1.25
    policy: str | None = None       # selection policy for "auto" algos
                                    # (None = process default; "tuned"
                                    # reads tuner.autotune's table)


def ep_axes_for(cfg_moe: MoEConfig, mesh) -> tuple[str, ...]:
    names = mesh.axis_names
    if "pod" in names:
        n = mesh.shape["pod"] * mesh.shape["model"]
        if cfg_moe.n_experts % n == 0:
            return ("pod", "model")
    return ("model",)


def make_moe_dispatch(mesh, opts: EPOptions, act: str = "silu"):
    """Returns a callable (p, cfg, x) -> y pluggable into model.forward.

    Must be called from inside the auto-sharded jit: drops into a
    shard_map over the mesh for the dispatch, computes shared experts in
    the auto region.
    """

    def dispatch(p, cfg: MoEConfig, x):
        ep = ep_axes_for(cfg, mesh)
        # batch rows stay sharded over every data-carrying axis; when
        # "pod" is also an EP axis the pod boundary separates *sources*
        # inside one EP group (each source dispatches its own tokens)
        d_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        xs_spec = P(d_axes)                          # batch dim sharding

        rp = {k: p[k] for k in ("router", "router_bias") if k in p}
        body = functools.partial(_dispatch_body, cfg=cfg, ep=ep,
                                 opts=opts, act=act)
        shard = compat.shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), rp),   # router params
                      P(ep, None, None),         # w_gate  [E, d, f]
                      P(ep, None, None),         # w_up
                      P(ep, None, None),         # w_down  [E, f, d]
                      xs_spec),                  # x [B, S, d]
            out_specs=xs_spec, check_vma=False)
        out = shard(rp, p["w_gate"], p["w_up"], p["w_down"], x)
        if cfg.n_shared:
            out = out + mlp.forward(p["shared"], x, act)
        return out

    return dispatch


def _dispatch_body(rp, w_gate, w_up, w_down, x, *, cfg: MoEConfig,
                   ep, opts: EPOptions, act):
    B, S, d = x.shape
    M = compat.axis_size("model")
    m = jax.lax.axis_index("model")
    N_ep = 1
    for a in ep:
        N_ep *= compat.axis_size(a)
    E, K = cfg.n_experts, cfg.top_k
    E_loc = E // N_ep
    T_total = B * S
    assert T_total % M == 0, (T_total, M)
    T = T_total // M

    # my 1/M token slice (tokens are replicated over the model axis)
    xt = x.reshape(T_total, d)
    xs = jax.lax.dynamic_slice_in_dim(xt, m * T, T, axis=0)

    w, idx, _ = moe.route(rp, cfg, xs)                        # [T,k]
    C = max(1, int(T * K / E * opts.capacity_factor))

    # bucket (token, slot) pairs into per-expert capacity slots
    flat_e = idx.reshape(-1)                                  # [T*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                              flat_e[:, None], 1)[:, 0]
    keep = pos < C
    dest = jnp.where(keep, flat_e * C + pos, E * C)
    buckets = jnp.zeros((E * C + 1, d), x.dtype)
    buckets = buckets.at[dest].set(jnp.repeat(xs, K, axis=0))

    # ship buckets to expert owners (expert e lives on rank e // E_loc)
    send = buckets[: E * C]                                   # [E*C, d]
    recv = mpix.mpix_alltoall(send, ep, algorithm=opts.alltoall,
                              policy=opts.policy)
    tok = recv.reshape(N_ep, E_loc, C, d).transpose(1, 0, 2, 3) \
              .reshape(E_loc, N_ep * C, d)

    h = mlp.ACT[act](jnp.einsum("ecd,edf->ecf", tok, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", tok, w_up)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)                # [E_loc,NC,d]

    back = ye.reshape(E_loc, N_ep, C, d).transpose(1, 0, 2, 3) \
             .reshape(N_ep * E_loc * C, d)
    ret = mpix.mpix_alltoall(back, ep, algorithm=opts.alltoall,
                             policy=opts.policy)

    gathered = jnp.concatenate([ret, jnp.zeros((1, d), x.dtype)])[dest]
    out_slice = jnp.einsum("tkd,tk->td", gathered.reshape(T, K, d), w)

    # rebuild the full token set across the model axis
    out = mpix.mpix_allgather(out_slice, "model",
                              algorithm=opts.allgather,
                              policy=opts.policy)
    return out.reshape(B, S, d)
