"""Expert-parallel MoE dispatch through the MPIX layer (paper §2.1+§2.2).

Experts are sharded over the EP axes (("pod","model") when the expert
count divides, else ("model",)); tokens travel to their experts through
``mpix_alltoall`` with a *selectable algorithm* — on the multi-pod mesh
the ``hierarchical`` algorithm aggregates everything headed to a remote
pod inside the source pod first (one DCN bundle per pod-pair stripe),
which is exactly the paper's locality-aware optimization applied to MoE
traffic.

Layout contract inside the shard_map:
  x        [B_local, S, d]   batch sharded over (pod, data); replicated
                             over model — each model rank takes its
                             1/M slice of the tokens.
  experts  [E_local, d, f]   E sharded over the EP axes.
  router   [d, E]            replicated.

Dispatch is capacity-based (static shapes; overflow drops, standard for
TPU MoE): per-source capacity C = ceil(T_slice * k / E * factor).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import api as mpix
from repro.core.transport import _flat_rank
from repro.models import mlp, moe
from repro.models.config import MoEConfig

from repro import compat


@dataclasses.dataclass(frozen=True)
class EPOptions:
    alltoall: str = "xla"           # mpix algorithm for dispatch/return
    allgather: str = "xla"          # rebuild of the token slice
    capacity_factor: float = 1.25
    policy: str | None = None       # selection policy for "auto" algos
                                    # (None = process default; "tuned"
                                    # reads tuner.autotune's table)
    overlap_chunks: int | None = None
    # pipelined dispatch (MPIPCL partitioned comm): the dispatch
    # alltoall runs in capacity chunks, each chunk's expert MLP
    # overlapping the next chunk's transfer.  None = off (monolithic),
    # 0 = auto (tuner prices the software pipeline against the expert
    # FLOPs per chunk), >= 2 = explicit chunk count (clamped to the
    # largest divisor of the capacity C).  Bit-exact either way.
    transport: str = "shardmap"
    # substrate for the schedule-backed collectives: "shardmap" (one
    # ppermute per compiled round), "pallas" (the whole schedule as one
    # device-side kernel — core.pallas_lowering), or "auto" (tuner's
    # per-size-bucket choice).  Ignored by "xla" algorithms.
    resilience: object = None
    # chaos-resilient execution for the dispatch collectives: None/False
    # = off, True/"canary"/"full"/dict/ResilienceOptions arm the api
    # recovery ladder (retry + transport fallback + algorithm refit +
    # xla) — see core.resilient.resolve_resilience.


def ep_axes_for(cfg_moe: MoEConfig, mesh) -> tuple[str, ...]:
    names = mesh.axis_names
    if "pod" in names:
        n = mesh.shape["pod"] * mesh.shape["model"]
        if cfg_moe.n_experts % n == 0:
            return ("pod", "model")
    return ("model",)


def make_moe_dispatch(mesh, opts: EPOptions, act: str = "silu"):
    """Returns a callable (p, cfg, x) -> y pluggable into model.forward.

    Must be called from inside the auto-sharded jit: drops into a
    shard_map over the mesh for the dispatch, computes shared experts in
    the auto region.
    """

    def dispatch(p, cfg: MoEConfig, x):
        ep = ep_axes_for(cfg, mesh)
        # batch rows stay sharded over every data-carrying axis; when
        # "pod" is also an EP axis the pod boundary separates *sources*
        # inside one EP group (each source dispatches its own tokens)
        d_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        xs_spec = P(d_axes)                          # batch dim sharding

        rp = {k: p[k] for k in ("router", "router_bias") if k in p}
        body = functools.partial(_dispatch_body, cfg=cfg, ep=ep,
                                 opts=opts, act=act)
        shard = compat.shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), rp),   # router params
                      P(ep, None, None),         # w_gate  [E, d, f]
                      P(ep, None, None),         # w_up
                      P(ep, None, None),         # w_down  [E, f, d]
                      xs_spec),                  # x [B, S, d]
            out_specs=xs_spec, check_vma=False)
        out = shard(rp, p["w_gate"], p["w_up"], p["w_down"], x)
        if cfg.n_shared:
            out = out + mlp.forward(p["shared"], x, act)
        return out

    return dispatch


def _overlap_chunks(opts: EPOptions, *, cfg: MoEConfig, ep, E_loc: int,
                    N_ep: int, C: int, d: int, f: int,
                    itemsize: int) -> int:
    """Resolve ``EPOptions.overlap_chunks`` to an effective chunk count
    (a divisor of the capacity C; < 2 means run the monolithic path)."""
    ov = opts.overlap_chunks
    if ov is None:
        return 1
    if ov < 0:
        raise ValueError(
            f"EPOptions.overlap_chunks must be None (off), 0 (auto) or "
            f">= 1, got {ov}")
    if ov == 0:
        from repro.core import tuner
        from repro.core.topology import PEAK_FLOPS_BF16
        # 3 einsums x 2*rows*d*f flops over the full dispatch
        compute_s = (6.0 * E_loc * (N_ep * C) * d * f
                     / PEAK_FLOPS_BF16)
        topo = mpix.topology_from_axes(ep)
        ov = tuner.select_overlap_chunks(
            topo, cfg.n_experts * C * d * itemsize, compute_s,
            policy=opts.policy or mpix.get_default_policy())
    ov = min(ov, C)
    while ov > 1 and C % ov:
        ov -= 1
    return ov


def _dispatch_overlapped(send, w_gate, w_up, w_down, *, chunks: int,
                         ep, opts: EPOptions, act, N_ep: int,
                         E_loc: int, C: int, d: int):
    """Pipelined dispatch: the alltoall ships capacity chunks and each
    arriving chunk immediately feeds the expert MLPs while the next
    chunk is in flight (receive-side early-bird, MPIPCL §2.3).

    The send buffer is reordered capacity-major within each destination
    block so a row chunk is capacity slice ``i`` of EVERY local expert
    — a full-width einsum's worth of work per chunk.  Chunk results
    accumulate into the same [E_loc, N_ep, C, d] layout the monolithic
    path produces; per-row MLPs contract only over ``d``, so chunking
    is exact (not merely close)."""
    Cc = C // chunks
    x_cm = (send.reshape(N_ep, E_loc, C, d)
            .transpose(0, 2, 1, 3).reshape(N_ep * C * E_loc, d))
    acc = jnp.zeros((E_loc, N_ep, C, d),
                    jnp.promote_types(send.dtype, w_down.dtype))

    def consume(acc, y_c, i):
        tok_c = (y_c.reshape(N_ep, Cc, E_loc, d)
                 .transpose(2, 0, 1, 3).reshape(E_loc, N_ep * Cc, d))
        h = mlp.ACT[act](jnp.einsum("ecd,edf->ecf", tok_c, w_gate))
        h = h * jnp.einsum("ecd,edf->ecf", tok_c, w_up)
        ye_c = jnp.einsum("ecf,efd->ecd", h, w_down)
        return jax.lax.dynamic_update_slice_in_dim(
            acc, ye_c.reshape(E_loc, N_ep, Cc, d).astype(acc.dtype),
            i * Cc, axis=2)

    return mpix.mpix_alltoall_overlap(
        x_cm, ep, consume, acc, chunks=chunks,
        algorithm=opts.alltoall, policy=opts.policy,
        transport=opts.transport, resilience=opts.resilience)


def _dispatch_body(rp, w_gate, w_up, w_down, x, *, cfg: MoEConfig,
                   ep, opts: EPOptions, act):
    B, S, d = x.shape
    M = compat.axis_size("model")
    m = jax.lax.axis_index("model")
    N_ep = 1
    for a in ep:
        N_ep *= compat.axis_size(a)
    E, K = cfg.n_experts, cfg.top_k
    E_loc = E // N_ep
    T_total = B * S
    assert T_total % M == 0, (T_total, M)
    T = T_total // M

    # my 1/M token slice (tokens are replicated over the model axis)
    xt = x.reshape(T_total, d)
    xs = jax.lax.dynamic_slice_in_dim(xt, m * T, T, axis=0)

    w, idx, _ = moe.route(rp, cfg, xs)                        # [T,k]
    C = max(1, int(T * K / E * opts.capacity_factor))

    # bucket (token, slot) pairs into per-expert capacity slots
    flat_e = idx.reshape(-1)                                  # [T*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                              flat_e[:, None], 1)[:, 0]
    keep = pos < C
    dest = jnp.where(keep, flat_e * C + pos, E * C)
    buckets = jnp.zeros((E * C + 1, d), x.dtype)
    buckets = buckets.at[dest].set(jnp.repeat(xs, K, axis=0))

    # ship buckets to expert owners (expert e lives on rank e // E_loc)
    send = buckets[: E * C]                                   # [E*C, d]
    k_ov = _overlap_chunks(opts, cfg=cfg, ep=ep, E_loc=E_loc,
                           N_ep=N_ep, C=C, d=d, f=w_gate.shape[2],
                           itemsize=x.dtype.itemsize)
    if k_ov >= 2:
        ye4 = _dispatch_overlapped(send, w_gate, w_up, w_down,
                                   chunks=k_ov, ep=ep, opts=opts,
                                   act=act, N_ep=N_ep, E_loc=E_loc,
                                   C=C, d=d)
    else:
        recv = mpix.mpix_alltoall(send, ep, algorithm=opts.alltoall,
                                  policy=opts.policy,
                                  transport=opts.transport,
                                  resilience=opts.resilience)
        tok = recv.reshape(N_ep, E_loc, C, d).transpose(1, 0, 2, 3) \
                  .reshape(E_loc, N_ep * C, d)

        h = mlp.ACT[act](jnp.einsum("ecd,edf->ecf", tok, w_gate))
        h = h * jnp.einsum("ecd,edf->ecf", tok, w_up)
        ye = jnp.einsum("ecf,efd->ecd", h, w_down)            # [E_loc,NC,d]
        ye4 = ye.reshape(E_loc, N_ep, C, d)

    back = ye4.transpose(1, 0, 2, 3).reshape(N_ep * E_loc * C, d)
    ret = mpix.mpix_alltoall(back, ep, algorithm=opts.alltoall,
                             policy=opts.policy,
                             transport=opts.transport,
                             resilience=opts.resilience)

    gathered = jnp.concatenate([ret, jnp.zeros((1, d), x.dtype)])[dest]
    out_slice = jnp.einsum("tkd,tk->td", gathered.reshape(T, K, d), w)

    # rebuild the full token set across the model axis
    out = mpix.mpix_allgather(out_slice, "model",
                              algorithm=opts.allgather,
                              policy=opts.policy,
                              transport=opts.transport,
                              resilience=opts.resilience)
    return out.reshape(B, S, d)
