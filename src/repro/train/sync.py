"""Explicit DP gradient synchronization through the MPIX layer.

The ``fsdp`` train mode leaves gradient reduction to the XLA partitioner
(the "system MPI" substrate).  This module is the paper-faithful
*explicit* path: parameters replicated over the data axes, the gradient
all-reduce issued by us with a publicly selectable algorithm —
``xla | ring_rs_ag | recursive_halving_doubling | hierarchical`` — plus
two distributed-optimization extensions:

  * bucketing (``buckets > 1``): the gradient pytree is flattened into
    independent buckets so XLA can overlap bucket k's collective with
    bucket k+1's producer (partitioned-communication pillar, §2.3);
  * DCN compression (``compress_dcn``): hierarchical sync where the
    intra-pod reduce runs in bf16/f32 over ICI and only the inter-pod
    hop is int8-quantized with error feedback (heterogeneous-path
    pillar, §2.4 — spend precision where the wire is slow).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import api as mpix
from repro.optim.compress import compress_int8, decompress_int8

from repro import compat


def _flatten(tree):
    leaves, tdef = jax.tree.flatten(tree)
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    return flat, (tdef, [l.shape for l in leaves],
                  [l.dtype for l in leaves], sizes)


def _unflatten(flat, meta):
    tdef, shapes, dtypes, sizes = meta
    out, off = [], 0
    for shp, dt, sz in zip(shapes, dtypes, sizes):
        out.append(flat[off: off + sz].reshape(shp).astype(dt))
        off += sz
    return jax.tree.unflatten(tdef, out)


def dp_allreduce(grads, axis_names, *, algorithm="xla", buckets=1,
                 denom=None, transport="shardmap", resilience=None):
    """Sum-allreduce a gradient pytree over ``axis_names`` (call inside
    shard_map), divided by ``denom`` (scalar; e.g. the psum'd live-token
    count so per-shard sum-losses combine into an exact global mean).
    ``transport`` selects the substrate for schedule-backed algorithms
    ("shardmap" | "pallas" | "auto"; ignored by "xla").  ``resilience``
    arms the api recovery ladder for each bucket's collective."""
    names = (axis_names,) if isinstance(axis_names, str) \
        else tuple(axis_names)
    if denom is None:
        denom = 1
        for a in names:
            denom *= compat.axis_size(a)
    flat, meta = _flatten(grads)
    per = -(-flat.size // max(1, buckets))
    pad = per * max(1, buckets) - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    parts = flat.reshape(max(1, buckets), per)
    done = [mpix.mpix_allreduce(parts[i], names, algorithm=algorithm,
                                transport=transport,
                                resilience=resilience)
            for i in range(parts.shape[0])]
    flat = jnp.concatenate(done)[: sum(meta[3])] / denom
    return _unflatten(flat, meta)


# dp_algorithm (allreduce registry) -> its (reduce_scatter, allgather)
# halves, so the overlap path accepts the same names as dp_allreduce
_RS_AG = {
    "ring_rs_ag": ("ring", "ring"),
    "recursive_halving_doubling": ("recursive_halving",
                                   "recursive_doubling"),
}


def dp_allreduce_overlap(grads, axis_names, *, algorithm="xla",
                         chunks=2, denom=None, max_norm=None,
                         transport="shardmap", resilience=None):
    """Pipelined DP sync fused with gradient clipping: reduce-scatter
    chunks, per-shard norm/clip compute between the halves, allgather
    chunks — the optimizer-side compute runs on 1/N of the data while
    other chunks are on the wire (compute-comm overlap on the grad
    path), and chunk k's allgather can overlap chunk k+1's
    reduce-scatter.

    Returns ``(grads, gnorm)`` — bitwise the same *averaging* as
    ``dp_allreduce`` and the same clip rule as
    ``optim.clip_by_global_norm`` (scale = min(1, max_norm/(gnorm +
    1e-9))), but the global norm is computed from the scattered shards:
    the shards partition the reduced vector, so the psum of per-shard
    square-norms is the EXACT global square-norm (no cross terms), one
    scalar crossing the wire instead of a second full pass.  With
    ``max_norm=None`` no clip is applied (gnorm still returned)."""
    names = (axis_names,) if isinstance(axis_names, str) \
        else tuple(axis_names)
    if chunks < 1:
        raise ValueError(
            f"dp_allreduce_overlap: chunks must be >= 1, got {chunks}")
    n = 1
    for a in names:
        n *= compat.axis_size(a)
    if denom is None:
        denom = n
    flat, meta = _flatten(grads)
    total = flat.size
    # each chunk pads to a multiple of n so the scatter dim divides
    per = -(-(-(-total // chunks)) // n) * n
    pad = per * chunks - total
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    parts = flat.reshape(chunks, per)
    rs_alg, ag_alg = _RS_AG.get(algorithm, (algorithm, algorithm))
    shards = []
    gsq = jnp.float32(0)
    for i in range(chunks):
        sh = mpix.mpix_reduce_scatter(parts[i], names,
                                      algorithm=rs_alg,
                                      transport=transport,
                                      resilience=resilience) / denom
        gsq = gsq + jnp.sum(jnp.square(sh))
        shards.append(sh)
    gnorm = jnp.sqrt(jax.lax.psum(gsq, names))
    if max_norm is not None:
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
        shards = [sh * scale for sh in shards]
    outs = [mpix.mpix_allgather(sh, names, algorithm=ag_alg,
                                transport=transport,
                                resilience=resilience)
            for sh in shards]
    flat = jnp.concatenate(outs)[: total]
    return _unflatten(flat, meta), gnorm


def dp_allreduce_compressed(grads, residual, *, intra_algorithm="xla",
                            denom=None, resilience=None):
    """Hierarchical DP sync with int8 + error feedback on the DCN hop.

    Call inside shard_map over ("pod", "data").  Steps:
      1. intra-pod sum over "data" (full precision, ICI),
      2. int8-quantize (grad + EF residual), exchange over "pod"
         (ppermute ring), dequantize-accumulate,
      3. new residual = what quantization lost this step,
      4. divide by ``denom`` (global live-token count).
    Returns (synced grads, new residual).
    """
    Q = compat.axis_size("pod")
    if denom is None:
        denom = Q * compat.axis_size("data")
    flat, meta = _flatten(grads)
    flat = mpix.mpix_allreduce(flat, "data", algorithm=intra_algorithm,
                               resilience=resilience)
    if residual is None:
        res_flat = jnp.zeros_like(flat)
    else:
        res_flat, _ = _flatten(residual)
    x = flat + res_flat
    q, s = compress_int8(x)
    sent = decompress_int8(q, s, x.shape, jnp.float32)
    new_res = x - sent
    # ring exchange of the quantized payload across pods
    acc = sent
    perm = [(i, (i + 1) % Q) for i in range(Q)]
    qc, sc = q, s
    for _ in range(Q - 1):
        qc = jax.lax.ppermute(qc, "pod", perm)
        sc = jax.lax.ppermute(sc, "pod", perm)
        acc = acc + decompress_int8(qc, sc, x.shape, jnp.float32)
    out = acc / denom
    return _unflatten(out, meta), _unflatten(new_res, meta)
