from repro.train.sharding import param_specs, batch_specs, data_axes  # noqa: F401
from repro.train.step import TrainOptions, make_train_step, init_train_state  # noqa: F401
