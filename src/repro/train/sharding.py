"""Sharding rules: FSDP + TP (+ EP) over the production meshes.

Axis convention (launch/mesh.py):
    single pod : ("data", "model")              = (16, 16)
    multi-pod  : ("pod", "data", "model")       = (2, 16, 16)

Rules (MaxText-style, by parameter role):
  * embedding [V, d]        -> (model, fsdp)       vocab-sharded
  * attn/mlp weights [.., a, b] -> contracting dim over fsdp, output dim
    over model (Megatron TP), stacked period dim replicated
  * MoE experts [.., E, a, b]  -> E over model (expert parallelism),
    a over fsdp
  * norms / biases / small vectors -> replicated
  * optimizer moments inherit their parameter's spec

``fsdp`` = ("pod", "data") on the multi-pod mesh, ("data",) on one pod:
parameter storage is fully sharded across every chip; the partitioner
inserts per-layer all-gathers (the xla-substrate path the MPIX layer
layers on).  Dims that don't divide fall back to replication (whisper's
odd 51865 vocab).
"""
from __future__ import annotations

import re

from jax.sharding import PartitionSpec as P

import jax
import numpy as np

_KEY_RE = re.compile(r"\['?([\w]+)'?\]")


def _leaf_name(path: str) -> str:
    """Last dict key in a tree_util keystr path."""
    keys = _KEY_RE.findall(path)
    return keys[-1] if keys else path


def data_axes(mesh) -> tuple[str, ...]:
    """Axes carrying the global batch (pod + data when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _fsdp_axes(mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _divisible(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def param_specs(params, cfg, mesh):
    """Pytree of PartitionSpec matching ``params`` (dicts/lists of
    arrays)."""
    fsdp = _fsdp_axes(mesh)
    fsdp_n = _axis_size(mesh, fsdp)
    model_n = mesh.shape["model"]

    def spec_for(path: str, x) -> P:
        shape = x.shape
        nd = len(shape)
        name = _leaf_name(path)
        # norms, biases, scalars, small vectors -> replicated
        if nd <= 1 or x.size < 1 << 16:
            return P()
        # stacked-period leading axis is never sharded
        lead = 1 if "periods" in path else 0
        if name == "embed" or name == "lm_head":
            vdim, ddim = (0, 1) if name == "embed" else (1, 0)
            spec = [None] * nd
            if _divisible(shape[vdim], model_n):
                spec[vdim] = "model"
            if _divisible(shape[ddim], fsdp_n):
                spec[ddim] = fsdp
            return P(*spec)
        if nd - lead < 2:
            # stacked vector (periods norm scales etc.)
            return P()
        # expert-stacked weights: [.., E, a, b] with E == n_experts.
        # EP storage: experts over ("pod","model") when they divide (the
        # dispatch alltoall then crosses the DCN and the hierarchical
        # algorithm's locality aggregation applies), else "model".
        if cfg.moe is not None and nd - lead == 3 \
                and shape[lead] == cfg.moe.n_experts:
            spec = [None] * nd
            ep = ("pod", "model") if "pod" in mesh.axis_names else ("model",)
            if not _divisible(shape[lead], _axis_size(mesh, ep)):
                ep = ("model",)
            if _divisible(shape[lead], _axis_size(mesh, ep)):
                spec[lead] = ep if len(ep) > 1 else "model"
            data_only = tuple(a for a in mesh.axis_names if a == "data")
            if _divisible(shape[lead + 1], _axis_size(mesh, data_only)):
                spec[lead + 1] = "data"
            return P(*spec)
        # generic matmul weight [.., a, b]: contracting over fsdp,
        # output over model (Megatron column parallel; works for row
        # parallel too since XLA re-shards as needed)
        spec = [None] * nd
        a_dim, b_dim = nd - 2, nd - 1
        if _divisible(shape[b_dim], model_n):
            spec[b_dim] = "model"
        if _divisible(shape[a_dim], fsdp_n):
            spec[a_dim] = fsdp
        return P(*spec)

    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    specs = [spec_for(jax.tree_util.keystr(kp), v) for kp, v in flat]
    return jax.tree_util.tree_unflatten(tdef, specs)


def batch_specs(mesh):
    """Token batches: rows over (pod, data); sequence replicated."""
    return P(data_axes(mesh))


def cache_specs(cache, cfg, mesh, *, long_context: bool):
    """KV caches: batch over the data axes and *sequence over model*
    (sequence-parallel KV — kv-head counts rarely divide the model axis,
    sequence always does; the partitioner turns the softmax over the
    sharded length into partial-softmax + psum).  Long-context (batch 1)
    shards the sequence over every axis."""
    d_axes = data_axes(mesh)
    all_axes = tuple(mesh.axis_names)

    def spec_for(path, x):
        nd = len(x.shape)
        name = _leaf_name(path)
        if nd == 0:
            return P()
        lead = 1 if "periods" in path else 0
        if name in ("k", "v"):        # [.., B, S, K, D]
            spec = [None] * nd
            if long_context:
                if _divisible(x.shape[lead + 1], _axis_size(mesh, all_axes)):
                    spec[lead + 1] = all_axes     # SP over every chip
                else:
                    spec[lead + 1] = d_axes
            else:
                spec[lead] = d_axes
                if _divisible(x.shape[lead + 1], mesh.shape["model"]):
                    spec[lead + 1] = "model"
            return P(*spec)
        if name in ("ckv", "kr"):     # MLA latent [.., B, S, r]
            spec = [None] * nd
            if long_context:
                spec[lead + 1] = d_axes
            else:
                spec[lead] = d_axes
                if _divisible(x.shape[lead + 1], mesh.shape["model"]):
                    spec[lead + 1] = "model"
            return P(*spec)
        if name == "s":               # rwkv state [.., B, H, N, N]
            spec = [None] * nd
            if _divisible(x.shape[lead + 1], mesh.shape["model"]):
                spec[lead + 1] = "model"
            if not long_context:
                spec[lead] = d_axes
            return P(*spec)
        if name == "h":               # mamba state [.., B, Di, S]
            spec = [None] * nd
            if _divisible(x.shape[lead + 1], mesh.shape["model"]):
                spec[lead + 1] = "model"
            if not long_context:
                spec[lead] = d_axes
            return P(*spec)
        if name == "conv":            # [.., B, K-1, Di]
            spec = [None] * nd
            if _divisible(x.shape[lead + 2], mesh.shape["model"]):
                spec[lead + 2] = "model"
            if not long_context:
                spec[lead] = d_axes
            return P(*spec)
        if name in ("x_tm", "x_cm"):  # [.., B, d]
            spec = [None] * nd
            if not long_context:
                spec[lead] = d_axes
            return P(*spec)
        return P()

    flat, tdef = jax.tree_util.tree_flatten_with_path(cache)
    specs = [spec_for(jax.tree_util.keystr(kp), v) for kp, v in flat]
    return jax.tree_util.tree_unflatten(tdef, specs)
