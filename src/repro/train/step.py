"""Train-step factory: wires the model zoo, the optimizer and the MPIX
communication layer into one jitted step per (arch, mesh, options).

Two DP modes (the paper's layering made operational):
  * ``fsdp``     — parameters FSDP-sharded (sharding.py), gradient
                   reduction left to the XLA partitioner: the "system
                   MPI" substrate.  Required for the 100B+ archs.
  * ``explicit`` — parameters replicated over the data axes; gradients
                   synchronized by *our* collectives inside shard_map
                   with a selectable algorithm + bucketing + optional
                   DCN int8 compression.  The paper-faithful path.

MoE modes: ``dropless`` (XLA-sharded gather dispatch) or ``mpix_ep``
(explicit expert-parallel alltoall through repro.core).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.models import model as M
from repro.models import moe as moe_mod
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import cosine_schedule
from repro.train import sharding
from repro.train.moe_dispatch import EPOptions, make_moe_dispatch
from repro.train import sync


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    dp_mode: str = "fsdp"              # "fsdp" | "explicit"
    dp_algorithm: str = "xla"          # explicit mode collective
    grad_buckets: int = 1
    compress_dcn: bool = False         # explicit+multi-pod only
    moe_mode: str = "dropless"         # "dense" | "dropless" | "mpix_ep"
    ep_alltoall: str = "xla"
    ep_capacity: float = 1.25
    ep_policy: str | None = None       # selection policy for EP "auto"
                                       # collectives (None = process
                                       # default set by the launcher)
    ep_overlap_chunks: int | None = None   # EPOptions.overlap_chunks:
                                       # pipelined MoE dispatch (None =
                                       # off, 0 = tuner-priced auto)
    ep_transport: str = "shardmap"     # EP collective substrate:
                                       # "shardmap" | "pallas" | "auto"
    dp_transport: str = "shardmap"     # explicit-mode grad-sync
                                       # substrate (same choices)
    overlap_grad_chunks: int = 0       # explicit mode: > 0 pipelines
                                       # grad sync as reduce-scatter /
                                       # clip-on-shards / allgather in
                                       # this many chunks (0 = off)
    resilience: object = None          # chaos-resilient collectives:
                                       # None/False off; True/"canary"/
                                       # "full"/dict arms the api
                                       # recovery ladder for EP dispatch
                                       # and explicit-mode grad sync
    remat: bool = True
    use_kernel: bool = False           # Pallas attention/wkv path
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    max_grad_norm: float = 1.0
    weight_decay: float = 0.1


def _loss_fn(cfg, opts: TrainOptions, moe_dispatch, reduction="mean"):
    def loss(params, batch):
        kw = {}
        if cfg.encoder is not None:
            kw["encoder_frames"] = batch["encoder_frames"]
        if cfg.vision_prefix:
            kw["vision_embeds"] = batch["vision_embeds"]
        return M.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                         use_kernel=opts.use_kernel, remat=opts.remat,
                         moe_dispatch=moe_dispatch, reduction=reduction,
                         **kw)
    return loss


def init_train_state(key, cfg, opts: TrainOptions | None = None):
    params = M.init_params(key, cfg)
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if opts is not None and opts.compress_dcn:
        state["ef_residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def state_specs(state, cfg, mesh, opts: TrainOptions):
    """PartitionSpec tree for the train state under the chosen mode."""
    if opts.dp_mode == "explicit":
        return jax.tree.map(lambda _: P(), state)
    pspecs = sharding.param_specs(state["params"], cfg, mesh)
    out = {"params": pspecs,
           "opt": {"mu": pspecs, "nu": pspecs, "count": P()},
           "step": P()}
    if "ef_residual" in state:
        out["ef_residual"] = pspecs
    return out


def make_train_step(cfg, mesh, opts: TrainOptions) -> Callable:
    """Returns jitted ``step(state, batch) -> (state, metrics)``."""
    moe_dispatch = None
    if opts.moe_mode == "mpix_ep" and cfg.moe is not None:
        moe_dispatch = make_moe_dispatch(
            mesh, EPOptions(alltoall=opts.ep_alltoall,
                            capacity_factor=opts.ep_capacity,
                            policy=opts.ep_policy,
                            overlap_chunks=opts.ep_overlap_chunks,
                            transport=opts.ep_transport,
                            resilience=opts.resilience),
            cfg.mlp_act)
    elif opts.moe_mode == "dropless" and cfg.moe is not None:
        moe_dispatch = lambda p, c, x: moe_mod.forward_dropless(
            p, c, x, cfg.mlp_act)
    loss = _loss_fn(cfg, opts, moe_dispatch)

    def opt_apply(state, grads, gnorm=None):
        lr = cosine_schedule(state["step"], peak_lr=opts.peak_lr,
                             warmup_steps=opts.warmup_steps,
                             total_steps=opts.total_steps)
        if gnorm is None:
            grads, gnorm = clip_by_global_norm(grads, opts.max_grad_norm)
        params, opt = adamw_update(state["params"], grads, state["opt"],
                                   lr=lr, weight_decay=opts.weight_decay)
        return params, opt, gnorm, lr

    d_axes = sharding.data_axes(mesh)

    if opts.dp_mode == "fsdp":
        def step(state, batch):
            lval, grads = jax.value_and_grad(loss)(state["params"], batch)
            params, opt, gnorm, lr = opt_apply(state, grads)
            new = dict(state, params=params, opt=opt,
                       step=state["step"] + 1)
            return new, {"loss": lval, "grad_norm": gnorm, "lr": lr}
        return step

    # ---- explicit mode: replicated params, manual DP sync --------------
    # Per-shard losses are SUMS over live tokens; shards exchange
    # (grad-sum, token-count) so the combined update equals the exact
    # global-mean gradient even under uneven label masking.
    sum_loss = _loss_fn(cfg, opts, moe_dispatch, reduction="sum_count")

    # pipelined grad sync (reduce-scatter / clip-on-shards / allgather):
    # the clip norm is computed on the scattered shards so the optimizer
    # prologue overlaps the allgather.  Compression owns the DCN hop, so
    # the two paths are mutually exclusive.
    overlap = (opts.overlap_grad_chunks > 0
               and not (opts.compress_dcn and "pod" in mesh.axis_names))

    def step(state, batch):
        def body(params, residual, batch):
            def local(p):
                s, c = sum_loss(p, batch)
                return s, c
            (lsum, cnt), grads = jax.value_and_grad(
                local, has_aux=True)(params)
            cnt_g = jax.lax.psum(cnt, d_axes)
            denom = jnp.maximum(cnt_g, 1).astype(jnp.float32)
            gnorm = None
            if opts.compress_dcn and "pod" in mesh.axis_names:
                grads, residual = sync.dp_allreduce_compressed(
                    grads, residual, intra_algorithm=opts.dp_algorithm,
                    denom=denom, resilience=opts.resilience)
            elif overlap:
                grads, gnorm = sync.dp_allreduce_overlap(
                    grads, d_axes, algorithm=opts.dp_algorithm,
                    chunks=opts.overlap_grad_chunks, denom=denom,
                    max_norm=opts.max_grad_norm,
                    transport=opts.dp_transport,
                    resilience=opts.resilience)
            else:
                grads = sync.dp_allreduce(
                    grads, d_axes, algorithm=opts.dp_algorithm,
                    buckets=opts.grad_buckets, denom=denom,
                    transport=opts.dp_transport,
                    resilience=opts.resilience)
            lval = jax.lax.psum(lsum, d_axes) / denom
            return lval, grads, residual, gnorm

        residual = state.get("ef_residual")
        shard = compat.shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), state["params"]),
                      (jax.tree.map(lambda _: P(), residual)
                       if residual is not None else None),
                      jax.tree.map(lambda _: P(d_axes), batch)),
            out_specs=(P(),
                       jax.tree.map(lambda _: P(), state["params"]),
                       (jax.tree.map(lambda _: P(), residual)
                        if residual is not None else None),
                       P() if overlap else None),
            check_vma=False)
        lval, grads, residual, gnorm = shard(state["params"], residual,
                                             batch)
        params, opt, gnorm, lr = opt_apply(state, grads, gnorm=gnorm)
        new = dict(state, params=params, opt=opt, step=state["step"] + 1)
        if residual is not None:
            new["ef_residual"] = residual
        return new, {"loss": lval, "grad_norm": gnorm, "lr": lr}

    return step


def jit_train_step(cfg, mesh, opts: TrainOptions, state, batch_spec_tree):
    """jit with explicit in/out shardings for the dry-run and launchers."""
    step = make_train_step(cfg, mesh, opts)
    sspec = state_specs(state, cfg, mesh, opts)
    to_sh = lambda spec: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P))
    return jax.jit(step,
                   in_shardings=(to_sh(sspec), to_sh(batch_spec_tree)),
                   out_shardings=(to_sh(sspec), None)), sspec
