"""Pure-jnp oracle for the selective-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(xc, dt, Bc, Cc, A, D, h0=None):
    """xc, dt: [B, T, Di]; Bc, Cc: [B, T, S]; A: [Di, S]; D: [Di].
    Returns y [B, T, Di] f32 and final h [B, Di, S]."""
    B_, T, Di = xc.shape
    S = Bc.shape[-1]
    h = (jnp.zeros((B_, Di, S), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[..., None].astype(jnp.float32) * A)
        dBx = (dt_t * x_t).astype(jnp.float32)[..., None] \
            * b_t.astype(jnp.float32)[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bds,bs->bd", h, c_t.astype(jnp.float32))
        return h, y + D * x_t.astype(jnp.float32)

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xc, dt, Bc, Cc))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h
