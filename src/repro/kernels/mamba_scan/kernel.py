"""Pallas TPU selective-scan (Mamba-1) kernel.

Same shape of argument as the wkv6 kernel: the recurrence is sequential
in T but the per-step temporaries (dA, dBx — [Di, S] floats each) never
need to exist in HBM.  Grid = (B * Di-blocks, T-chunks) with the chunk
axis sequential; the [bdi, S] f32 state lives in VMEM scratch across
chunks, inputs stream one [bt, bdi] / [bt, S] tile per step, and only y
is written back.  HBM traffic drops from O(T * Di * S) to O(T * (Di + S))
— the memory-roofline fix for the jamba train cells (§Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xc_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_scr, *,
            bt):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    xc = xc_ref[0].astype(jnp.float32)        # [bt, bdi]
    dt = dt_ref[0].astype(jnp.float32)
    bmat = b_ref[0].astype(jnp.float32)       # [bt, S]
    cmat = c_ref[0].astype(jnp.float32)
    A = a_ref[...].astype(jnp.float32)        # [bdi, S]
    D = d_ref[...].astype(jnp.float32)        # [bdi]

    def step(i, carry):
        h, y = carry
        dA = jnp.exp(dt[i][:, None] * A)                    # [bdi, S]
        dBx = (dt[i] * xc[i])[:, None] * bmat[i][None, :]
        h = dA * h + dBx
        yt = h @ cmat[i] + D * xc[i]                        # [bdi]
        y = jax.lax.dynamic_update_index_in_dim(y, yt, i, 0)
        return h, y

    h0 = h_scr[...]
    y0 = jnp.zeros((bt, xc.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, bt, step, (h0, y0))
    h_scr[...] = h
    y_ref[0, ...] = y.astype(y_ref.dtype)


def selective_scan_bdt(xc, dt, bmat, cmat, A, D, *, block_t=64,
                       block_di=None, interpret=False):
    """xc, dt: [B, T, Di]; bmat, cmat: [B, T, S]; A: [Di, S]; D: [Di].
    Returns y [B, T, Di] f32."""
    B_, T, Di = xc.shape
    S = bmat.shape[-1]
    bt = min(block_t, T)
    assert T % bt == 0
    bdi = block_di or min(Di, 512)
    while Di % bdi:
        bdi //= 2
    n_di = Di // bdi
    grid = (B_ * n_di, T // bt)
    kern = functools.partial(_kernel, bt=bt)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bdi),
                         lambda i, t, n=n_di: (i // n, t, i % n)),
            pl.BlockSpec((1, bt, bdi),
                         lambda i, t, n=n_di: (i // n, t, i % n)),
            pl.BlockSpec((1, bt, S),
                         lambda i, t, n=n_di: (i // n, t, 0)),
            pl.BlockSpec((1, bt, S),
                         lambda i, t, n=n_di: (i // n, t, 0)),
            pl.BlockSpec((bdi, S), lambda i, t, n=n_di: (i % n, 0)),
            pl.BlockSpec((bdi,), lambda i, t, n=n_di: (i % n,)),
        ],
        out_specs=pl.BlockSpec((1, bt, bdi),
                               lambda i, t, n=n_di: (i // n, t, i % n)),
        out_shape=jax.ShapeDtypeStruct((B_, T, Di), jnp.float32),
        scratch_shapes=[_vmem((bdi, S), jnp.float32)],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(xc, dt, bmat, cmat, A, D)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _tpu_params():
    from repro.kernels.compat import tpu_compiler_params
    return tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
