"""jit'd public wrapper for the selective-scan kernel (custom VJP via
reference recompute; interpret mode on CPU).

REPRO_KERNEL_SURROGATE=1 (set only by the dry-run) swaps the kernel for
an HBM-traffic-equivalent stand-in — reads every input once, writes the
output once, no recurrence internals — so the CPU dry-run measures the
kernel path's memory signature without lowering Pallas to CPU.  Values
are wrong; the dry-run never executes, only compiles.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.kernel import selective_scan_bdt
from repro.kernels.mamba_scan.ref import selective_scan_ref


def _on_cpu():
    return jax.default_backend() == "cpu"


def _surrogate(xc, dt, bmat, cmat, A, D):
    red = (bmat.astype(jnp.float32).sum(-1, keepdims=True)
           + cmat.astype(jnp.float32).sum(-1, keepdims=True))
    return (xc.astype(jnp.float32) * dt.astype(jnp.float32) + red) \
        * (A.sum() + D)


def selective_scan(xc, dt, bmat, cmat, A, D, block_t=64):
    if os.environ.get("REPRO_KERNEL_SURROGATE") == "1" and _on_cpu():
        # differentiable surrogate: its AD transpose streams the same
        # tensors a fused backward kernel would (inputs + grads once)
        return _surrogate(xc, dt, bmat, cmat, A, D)
    return _scan_vjp(xc, dt, bmat, cmat, A, D, block_t)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _scan_vjp(xc, dt, bmat, cmat, A, D, block_t=64):
    return selective_scan_bdt(xc, dt, bmat, cmat, A, D, block_t=block_t,
                              interpret=_on_cpu())


def _fwd(xc, dt, bmat, cmat, A, D, block_t):
    return (_scan_vjp(xc, dt, bmat, cmat, A, D, block_t),
            (xc, dt, bmat, cmat, A, D))


def _bwd(block_t, res, g):
    _, vjp = jax.vjp(lambda *a: selective_scan_ref(*a)[0], *res)
    return vjp(g)


_scan_vjp.defvjp(_fwd, _bwd)
