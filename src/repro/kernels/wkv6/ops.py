"""jit'd public wrapper for the wkv6 kernel ([B,T,H,N] layout, custom
VJP via reference recompute, interpret mode on CPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_bhtn
from repro.kernels.wkv6.ref import wkv6_ref


def _on_cpu():
    return jax.default_backend() == "cpu"


def wkv6(r, k, v, w, u, block_t=64):
    """r,k,v,w [B,T,H,N]; u [H,N] -> y [B,T,H,N] float32."""
    import os
    if os.environ.get("REPRO_KERNEL_SURROGATE") == "1" and _on_cpu():
        # differentiable HBM-traffic stand-in (dry-run only): fwd+bwd
        # stream inputs/grads once — state stays in VMEM.
        return (r.astype(jnp.float32) * k.astype(jnp.float32)
                + v.astype(jnp.float32) * w.astype(jnp.float32) + u)
    return _wkv_vjp(r, k, v, w, u, block_t)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _wkv_vjp(r, k, v, w, u, block_t=64):
    B, T, H, N = r.shape
    to = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, T, N)
    ub = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N)
    y = wkv6_bhtn(to(r), to(k), to(v), to(w), ub,
                  block_t=block_t, interpret=_on_cpu())
    return y.reshape(B, H, T, N).transpose(0, 2, 1, 3)


def _fwd(r, k, v, w, u, block_t):
    return _wkv_vjp(r, k, v, w, u, block_t), (r, k, v, w, u)


def _bwd(block_t, res, g):
    r, k, v, w, u = res
    _, vjp = jax.vjp(lambda *a: wkv6_ref(*a)[0], r, k, v, w, u)
    return vjp(g)


_wkv_vjp.defvjp(_fwd, _bwd)
