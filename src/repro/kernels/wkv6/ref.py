"""Pure-jnp oracle for the wkv6 kernel: the O(T) scan recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, s0=None):
    """r,k,v,w [B,T,H,N]; u [H,N] -> y [B,T,H,N] (f32), final S."""
    B, T, H, N = r.shape
    s = (jnp.zeros((B, H, N, N), jnp.float32) if s0 is None
         else s0.astype(jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhn,bhnm->bhm", rt,
                       s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32)
               for t in (r, k, v, w))
    s, ys = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(ys, 0, 1), s
