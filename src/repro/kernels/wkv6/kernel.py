"""Pallas TPU wkv6 kernel: chunked recurrence with VMEM-resident state.

The recurrence S_t = diag(w_t) S_{t-1} + k_t v_t^T is inherently
sequential in T, but the HBM traffic need not be: the grid walks
(batch*head, time-chunk) with the chunk axis sequential; the [N, N] f32
state lives in VMEM scratch across chunks, and each grid step streams
one [bt, N] tile of r/k/v/w through VMEM.  Per chunk the kernel runs the
bt inner steps as an unrolled loop of rank-1 updates + [N]x[N,N]
products on-chip — HBM sees each input element exactly once and the
state never spills (the memory-bound reference scan reloads S per step).

(The fully-matmul "intra-chunk attention" formulation trades this for
MXU utilization but needs per-channel exp rescaling that overflows for
fast-decay channels; the rank-1 form is exact — see DESIGN.md.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_scr, *, bt, nt):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)                       # [N]
    r = r_ref[0].astype(jnp.float32)                       # [bt, N]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)

    def step(i, carry):
        s, y = carry
        rt, kt, vt, wt = r[i], k[i], v[i], w[i]            # [N]
        kv = kt[:, None] * vt[None, :]                     # [N, N]
        yt = rt @ (s + u[:, None] * kv)                    # [N]
        s = wt[:, None] * s + kv
        y = jax.lax.dynamic_update_index_in_dim(y, yt, i, 0)
        return s, y

    s0 = s_scr[...]
    y0 = jnp.zeros((bt, r.shape[1]), jnp.float32)
    s, y = jax.lax.fori_loop(0, bt, step, (s0, y0))
    s_scr[...] = s
    y_ref[0, ...] = y.astype(y_ref.dtype)


def wkv6_bhtn(r, k, v, w, u, *, block_t=64, interpret=False):
    """r,k,v,w [BH, T, N]; u [BH, N] -> y [BH, T, N] float32."""
    BH, T, N = r.shape
    bt = min(block_t, T)
    assert T % bt == 0, (T, bt)
    nt = T // bt
    kern = functools.partial(_kernel, bt=bt, nt=nt)
    return pl.pallas_call(
        kern,
        grid=(BH, nt),
        in_specs=[
            pl.BlockSpec((1, bt, N), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, bt, N), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, bt, N), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, bt, N), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, N), lambda h, t: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, N), lambda h, t: (h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, N), jnp.float32),
        scratch_shapes=[_vmem((N, N), jnp.float32)],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(r, k, v, w, u)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _tpu_params():
    from repro.kernels.compat import tpu_compiler_params
    return tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
