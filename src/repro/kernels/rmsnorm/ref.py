"""Pure-jnp oracle for the fused rmsnorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, *, eps=1e-6, gemma_style=False):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if gemma_style \
        else scale.astype(jnp.float32)
    return (y * w).astype(x.dtype)
