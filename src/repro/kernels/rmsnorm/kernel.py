"""Pallas TPU fused rmsnorm: one HBM pass per row block.

Unfused XLA lowers rmsnorm as square -> reduce -> rsqrt -> mul -> mul
with an intermediate round-trip when fusion breaks across the reduce;
the kernel keeps the [br, d] tile in VMEM, does the row reduction and
both multiplies in-register, and writes once.  Rows are blocked on the
grid; d stays whole (lane-dim aligned when d % 128 == 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _normalize(x, s_ref, o_ref, eps, gemma_style):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = s_ref[...].astype(jnp.float32)
    if gemma_style:
        w = 1.0 + w
    o_ref[...] = (y * w).astype(o_ref.dtype)


def _kernel(x_ref, s_ref, o_ref, *, eps, gemma_style):
    _normalize(x_ref[...].astype(jnp.float32), s_ref, o_ref, eps,
               gemma_style)


def _reduce_kernel(p_ref, s_ref, o_ref, *, eps, gemma_style):
    # allreduce epilogue: the [P, br, d] partials tile is summed over P
    # in f32 IN VMEM — the terminal reduce round of the collective —
    # and normalized before the single HBM write.  The reduced tensor
    # never round-trips through HBM.
    _normalize(p_ref[...].astype(jnp.float32).sum(axis=0), s_ref, o_ref,
               eps, gemma_style)


def rmsnorm_2d(x, scale, *, eps=1e-6, gemma_style=False, block_rows=256,
               interpret=False):
    """x [R, d], scale [d] -> [R, d]."""
    R, d = x.shape
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    kern = functools.partial(_kernel, eps=eps, gemma_style=gemma_style)
    return pl.pallas_call(
        kern,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        interpret=interpret,
    )(x, scale)


def rmsnorm_reduce_2d(parts, scale, *, eps=1e-6, gemma_style=False,
                      block_rows=256, interpret=False):
    """parts [P, R, d], scale [d] -> [R, d]: allreduce-epilogue fusion.

    Sums the P partial activations (f32) and rmsnorms the result in one
    kernel — P tile reads + 1 write per row block, vs the unfused
    P reads + 1 write (reduce) + 1 read + 1 write (norm)."""
    P, R, d = parts.shape
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    kern = functools.partial(_reduce_kernel, eps=eps,
                             gemma_style=gemma_style)
    return pl.pallas_call(
        kern,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((P, br, d), lambda i: (0, i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), parts.dtype),
        interpret=interpret,
    )(parts, scale)
