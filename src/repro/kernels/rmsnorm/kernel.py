"""Pallas TPU fused rmsnorm: one HBM pass per row block.

Unfused XLA lowers rmsnorm as square -> reduce -> rsqrt -> mul -> mul
with an intermediate round-trip when fusion breaks across the reduce;
the kernel keeps the [br, d] tile in VMEM, does the row reduction and
both multiplies in-register, and writes once.  Rows are blocked on the
grid; d stays whole (lane-dim aligned when d % 128 == 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps, gemma_style):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = s_ref[...].astype(jnp.float32)
    if gemma_style:
        w = 1.0 + w
    o_ref[...] = (y * w).astype(o_ref.dtype)


def rmsnorm_2d(x, scale, *, eps=1e-6, gemma_style=False, block_rows=256,
               interpret=False):
    """x [R, d], scale [d] -> [R, d]."""
    R, d = x.shape
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    kern = functools.partial(_kernel, eps=eps, gemma_style=gemma_style)
    return pl.pallas_call(
        kern,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        interpret=interpret,
    )(x, scale)
