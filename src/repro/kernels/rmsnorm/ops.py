"""jit'd public wrapper for the fused rmsnorm kernel (any leading
shape; custom VJP via reference recompute; interpret mode on CPU)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_2d
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _on_cpu():
    return jax.default_backend() == "cpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm(x, scale, eps=1e-6, gemma_style=False):
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    # pick a row block that divides (rows are a product of batch dims)
    R = flat.shape[0]
    br = 256
    while R % br:
        br //= 2
    out = rmsnorm_2d(flat, scale, eps=eps, gemma_style=gemma_style,
                     block_rows=max(br, 1), interpret=_on_cpu())
    return out.reshape(shape)


def _fwd(x, scale, eps, gemma_style):
    return rmsnorm(x, scale, eps, gemma_style), (x, scale)


def _bwd(eps, gemma_style, res, g):
    x, scale = res
    _, vjp = jax.vjp(lambda x_, s_: rmsnorm_ref(x_, s_, eps=eps,
                                                gemma_style=gemma_style),
                     x, scale)
    return vjp(g)


rmsnorm.defvjp(_fwd, _bwd)
