"""jit'd public wrappers for the fused rmsnorm kernels (any leading
shape; custom VJP via reference recompute; interpret mode whenever no
TPU backs the process — see kernels.compat.pallas_interpret)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.compat import pallas_interpret
from repro.kernels.rmsnorm.kernel import rmsnorm_2d, rmsnorm_reduce_2d
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _block_rows(R: int) -> int:
    br = 256
    while R % br:
        br //= 2
    return max(br, 1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm(x, scale, eps=1e-6, gemma_style=False):
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    # pick a row block that divides (rows are a product of batch dims)
    out = rmsnorm_2d(flat, scale, eps=eps, gemma_style=gemma_style,
                     block_rows=_block_rows(flat.shape[0]),
                     interpret=pallas_interpret())
    return out.reshape(shape)


def _fwd(x, scale, eps, gemma_style):
    return rmsnorm(x, scale, eps, gemma_style), (x, scale)


def _bwd(eps, gemma_style, res, g):
    x, scale = res
    _, vjp = jax.vjp(lambda x_, s_: rmsnorm_ref(x_, s_, eps=eps,
                                                gemma_style=gemma_style),
                     x, scale)
    return vjp(g)


rmsnorm.defvjp(_fwd, _bwd)


def rmsnorm_allreduce_ref(parts, scale, *, eps=1e-6, gemma_style=False):
    """Oracle for the fused epilogue: f32 sum over the partials axis,
    then the rmsnorm reference."""
    red = parts.astype(jnp.float32).sum(axis=0).astype(parts.dtype)
    return rmsnorm_ref(red, scale, eps=eps, gemma_style=gemma_style)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm_allreduce(parts, scale, eps=1e-6, gemma_style=False):
    """Fused allreduce->rmsnorm: ``parts`` [P, ..., d] are the per-rank
    partial activations (e.g. one ``all_gather`` of a tensor-parallel
    output); returns rmsnorm(sum over P) of shape [..., d] without ever
    writing the reduced tensor to HBM.  The collective's terminal
    reduce round runs as the kernel's epilogue — the compute-fusion leg
    of the device-side transport (api.mpix_allreduce_rmsnorm)."""
    P = parts.shape[0]
    d = parts.shape[-1]
    shape = parts.shape[1:]
    flat = parts.reshape(P, -1, d)
    out = rmsnorm_reduce_2d(flat, scale, eps=eps, gemma_style=gemma_style,
                            block_rows=_block_rows(flat.shape[1]),
                            interpret=pallas_interpret())
    return out.reshape(shape)


def _ar_fwd(parts, scale, eps, gemma_style):
    return rmsnorm_allreduce(parts, scale, eps, gemma_style), (parts, scale)


def _ar_bwd(eps, gemma_style, res, g):
    parts, scale = res
    _, vjp = jax.vjp(
        lambda p_, s_: rmsnorm_allreduce_ref(p_, s_, eps=eps,
                                             gemma_style=gemma_style),
        parts, scale)
    return vjp(g)


rmsnorm_allreduce.defvjp(_ar_fwd, _ar_bwd)
