"""Pallas TPU flash attention (online softmax, GQA, causal/window,
logit softcap).

Tiling: grid = (B * H, nQ, nK); the kv axis is the innermost sequential
dimension ("arbitrary"), so the [bq, D] f32 accumulator and the running
(max, sum) statistics live in VMEM scratch across kv steps and flush to
the output block on the last step.  Q/K/V tiles stream HBM -> VMEM per
step; D is kept whole (128/256 — MXU-aligned) and bq/bk default to 128
lanes/sublanes-aligned tiles.

GQA is expressed in the index_map: kv head index = query head // group
size, so no repeated KV materializes in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
            scale, causal, window, softcap, bq, bk, nk):
    j = pl.program_id(2)    # kv block
    i = pl.program_id(1)    # q block

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0].astype(jnp.float32) * scale            # [bq, D]
    k = k_ref[0].astype(jnp.float32)                    # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_s[:, 0], l_s[:, 0]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_cur[:, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = alpha * l_prev + p.sum(axis=1)
    acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())))
    m_s[:, 0], l_s[:, 0] = m_cur, l_cur

    @pl.when(j == nk - 1)
    def _flush():
        # rows with no live kv (fully masked) produce 0, not NaN
        denom = jnp.where(l_s[:, 0] > 0, l_s[:, 0], 1.0)
        o_ref[0, ...] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


def _kernel_gather(idx_ref, q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                   scale, causal, window, softcap, bq, bk, nk):
    """Dispatch-gather prologue: the q tile is assembled IN VMEM from a
    token-order q buffer via per-output row indices (``-1`` -> zero
    row) — the terminal gather round of an alltoall-style dispatch
    fused into the attention kernel, so the permuted q tensor never
    materializes in HBM.  Positions (causal/window masks) are
    output-order.  The row gather uses a traced index vector; on TPU
    this relies on Mosaic's dynamic-gather lowering (interpret mode —
    the CI path — models it exactly)."""
    j = pl.program_id(2)    # kv block
    i = pl.program_id(1)    # q block

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    idx = idx_ref[0]                                    # [bq] int32
    live = idx >= 0
    qfull = q_ref[0].astype(jnp.float32)                # [Sq, D]
    q = qfull[jnp.where(live, idx, 0)]                  # [bq, D]
    q = jnp.where(live[:, None], q, 0.0) * scale
    k = k_ref[0].astype(jnp.float32)                    # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.broadcast_to(live[:, None], (bq, bk))
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_s[:, 0], l_s[:, 0]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_cur[:, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = alpha * l_prev + p.sum(axis=1)
    acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())))
    m_s[:, 0], l_s[:, 0] = m_cur, l_cur

    @pl.when(j == nk - 1)
    def _flush():
        # dead rows (idx -1): every kv position was masked, so the
        # running max never left NEG_INF and p degenerated to exp(0) —
        # the accumulator holds garbage there; zero it at the write.
        denom = jnp.where(l_s[:, 0] > 0, l_s[:, 0], 1.0)
        out = acc[...] / denom[:, None]
        o_ref[0, ...] = jnp.where(live[:, None], out,
                                  0.0).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=None,
                         softcap=None, scale=None, block_q=128,
                         block_k=128, interpret=False, q_rows=None,
                         nheads=None):
    """q [BH, Sq, D], k/v [BK, Sk, D]; BH = BK * group -> out like q.

    ``q_rows`` [B, Sq] (int32, requires ``nheads`` with BH = B * nheads)
    turns on the dispatch-gather prologue: output row t of batch b
    attends with row ``q_rows[b, t]`` of the token-order q buffer
    (``-1`` -> zero row, output row is 0)."""
    BH, Sq, D = q.shape
    BK, Sk, _ = k.shape
    assert BH % BK == 0
    group = BH // BK
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nk = Sk // bk
    scale = scale if scale is not None else D ** -0.5
    grid = (BH, Sq // bq, nk)
    kv_specs = [
        pl.BlockSpec((1, bk, D), lambda h, i, j, g=group: (h // g, j, 0)),
        pl.BlockSpec((1, bk, D), lambda h, i, j, g=group: (h // g, j, 0)),
    ]
    out_spec = pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0))
    scratch = [
        _vmem((bq, D), jnp.float32),
        _vmem((bq, 1), jnp.float32),
        _vmem((bq, 1), jnp.float32),
    ]
    if q_rows is None:
        kern = functools.partial(_kernel, scale=scale, causal=causal,
                                 window=window, softcap=softcap,
                                 bq=bq, bk=bk, nk=nk)
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
                      *kv_specs],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
            scratch_shapes=scratch,
            compiler_params=_tpu_params(),
            interpret=interpret,
        )(q, k, v)
    assert nheads is not None and BH % nheads == 0, (BH, nheads)
    assert q_rows.shape == (BH // nheads, Sq), (q_rows.shape, Sq)
    kern = functools.partial(_kernel_gather, scale=scale, causal=causal,
                             window=window, softcap=softcap,
                             bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            # idx tile for this q block, shared by the batch's heads
            pl.BlockSpec((1, bq), lambda h, i, j, nh=nheads: (h // nh, i)),
            # the FULL token-order q row buffer for this head
            pl.BlockSpec((1, Sq, D), lambda h, i, j: (h, 0, 0)),
            *kv_specs,
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=scratch,
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(q_rows.astype(jnp.int32), q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _tpu_params():
    from repro.kernels.compat import tpu_compiler_params
    return tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "arbitrary"))
