"""Pallas TPU flash attention (online softmax, GQA, causal/window,
logit softcap).

Tiling: grid = (B * H, nQ, nK); the kv axis is the innermost sequential
dimension ("arbitrary"), so the [bq, D] f32 accumulator and the running
(max, sum) statistics live in VMEM scratch across kv steps and flush to
the output block on the last step.  Q/K/V tiles stream HBM -> VMEM per
step; D is kept whole (128/256 — MXU-aligned) and bq/bk default to 128
lanes/sublanes-aligned tiles.

GQA is expressed in the index_map: kv head index = query head // group
size, so no repeated KV materializes in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
            scale, causal, window, softcap, bq, bk, nk):
    j = pl.program_id(2)    # kv block
    i = pl.program_id(1)    # q block

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0].astype(jnp.float32) * scale            # [bq, D]
    k = k_ref[0].astype(jnp.float32)                    # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_s[:, 0], l_s[:, 0]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_cur[:, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = alpha * l_prev + p.sum(axis=1)
    acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())))
    m_s[:, 0], l_s[:, 0] = m_cur, l_cur

    @pl.when(j == nk - 1)
    def _flush():
        # rows with no live kv (fully masked) produce 0, not NaN
        denom = jnp.where(l_s[:, 0] > 0, l_s[:, 0], 1.0)
        o_ref[0, ...] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=None,
                         softcap=None, scale=None, block_q=128,
                         block_k=128, interpret=False):
    """q [BH, Sq, D], k/v [BK, Sk, D]; BH = BK * group -> out like q."""
    BH, Sq, D = q.shape
    BK, Sk, _ = k.shape
    assert BH % BK == 0
    group = BH // BK
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nk = Sk // bk
    scale = scale if scale is not None else D ** -0.5

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, softcap=softcap,
                             bq=bq, bk=bk, nk=nk)
    grid = (BH, Sq // bq, nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            _vmem((bq, D), jnp.float32),
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, 1), jnp.float32),
        ],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _tpu_params():
    from repro.kernels.compat import tpu_compiler_params
    return tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "arbitrary"))
