"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  scale=None):
    """q [B,Sq,H,D], k/v [B,Sk,K,D] (GQA: H multiple of K) -> [B,Sq,H,D]."""
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    if H != K:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)
