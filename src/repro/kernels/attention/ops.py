"""jit'd public wrapper for the flash-attention kernel.

Layout adaptation [B,S,H,D] <-> [B*H,S,D], GQA head mapping, custom VJP
(forward = kernel; backward = recompute via the jnp reference — same
math, so gradients are exact up to dtype rounding), and automatic
interpret-mode on CPU so every test/benchmark runs here while the same
code path targets TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention_bhsd
from repro.kernels.attention.ref import attention_ref
from repro.kernels.compat import pallas_interpret


def _on_cpu():
    return jax.default_backend() == "cpu"


def _to_bhsd(x):
    B, S, H, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)


def _from_bhsd(x, B, H):
    BH, S, D = x.shape
    return x.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def _surrogate(q, k, v):
    """HBM-traffic-equivalent stand-in (REPRO_KERNEL_SURROGATE dry-run
    only): streams q/k/v once, writes out once — the flash kernel's
    memory signature, no [Sq, Sk] logits in HBM."""
    import jax.numpy as jnp
    B, Sq, H, D = q.shape
    K = k.shape[2]
    km = k.astype(jnp.float32).mean(1, keepdims=True)   # [B,1,K,D]
    vm = v.astype(jnp.float32).mean(1, keepdims=True)
    mix = (km + vm).repeat(H // K, axis=2)              # [B,1,H,D]
    return (q.astype(jnp.float32) + mix).astype(q.dtype)


def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, q_rows=None):
    """q [B,Sq,H,D], k/v [B,Sk,K,D] -> [B,Sq,H,D] (flash kernel).

    ``q_rows`` ([Sq] or [B, Sq] int32) fuses a dispatch-gather prologue
    into the kernel: output row t attends with token-order q row
    ``q_rows[..., t]`` (``-1`` -> zero output row), so the permuted q of
    an alltoall-style dispatch never materializes in HBM.  Causal /
    window positions are output-order."""
    import os
    if os.environ.get("REPRO_KERNEL_SURROGATE") == "1" and _on_cpu():
        # differentiable surrogate (dry-run): fwd+bwd stream q/k/v/grads
        # once — the flash fwd+bwd kernels' HBM signature
        return _surrogate(q, k, v)
    if q_rows is not None:
        if q_rows.ndim == 1:
            q_rows = jnp.broadcast_to(q_rows[None], (q.shape[0],)
                                      + q_rows.shape)
        return _flash_gather_vjp(q, k, v, q_rows, causal, window,
                                 softcap, scale, block_q, block_k)
    return _flash_vjp(q, k, v, causal, window, softcap, scale, block_q,
                      block_k)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_vjp(q, k, v, causal=True, window=None, softcap=None,
               scale=None, block_q=128, block_k=128):
    B, Sq, H, D = q.shape
    bq = min(block_q, Sq)
    bk = min(block_k, Sq if k is None else k.shape[1])
    out = flash_attention_bhsd(
        _to_bhsd(q), _to_bhsd(k), _to_bhsd(v), causal=causal,
        window=window, softcap=softcap, scale=scale,
        block_q=bq, block_k=bk, interpret=pallas_interpret())
    return _from_bhsd(out, B, H)


def _fwd(q, k, v, causal, window, softcap, scale, block_q, block_k):
    out = _flash_vjp(q, k, v, causal, window, softcap, scale,
                     block_q, block_k)
    return out, (q, k, v)


def _bwd(causal, window, softcap, scale, block_q, block_k, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window, softcap=softcap,
                                         scale=scale), q, k, v)
    return vjp(g)


_flash_vjp.defvjp(_fwd, _bwd)


def gathered_attention_ref(q, k, v, q_rows, *, causal=True, window=None,
                           softcap=None, scale=None):
    """Oracle for the gather-prologue kernel: explicit jnp gather of the
    token-order q rows (``-1`` -> zero row), then the plain reference;
    fully-dead output rows are zeroed like the kernel's flush."""
    live = q_rows >= 0                                  # [B, Sq]
    safe = jnp.where(live, q_rows, 0)
    qg = jnp.take_along_axis(q, safe[..., None, None], axis=1)
    qg = jnp.where(live[..., None, None], qg, 0)
    out = attention_ref(qg, k, v, causal=causal, window=window,
                        softcap=softcap, scale=scale)
    return jnp.where(live[..., None, None], out, 0)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _flash_gather_vjp(q, k, v, q_rows, causal=True, window=None,
                      softcap=None, scale=None, block_q=128,
                      block_k=128):
    B, Sq, H, D = q.shape
    bq = min(block_q, Sq)
    bk = min(block_k, k.shape[1])
    out = flash_attention_bhsd(
        _to_bhsd(q), _to_bhsd(k), _to_bhsd(v), causal=causal,
        window=window, softcap=softcap, scale=scale,
        block_q=bq, block_k=bk, interpret=pallas_interpret(),
        q_rows=q_rows, nheads=H)
    return _from_bhsd(out, B, H)


def _gather_fwd(q, k, v, q_rows, causal, window, softcap, scale,
                block_q, block_k):
    out = _flash_gather_vjp(q, k, v, q_rows, causal, window, softcap,
                            scale, block_q, block_k)
    return out, (q, k, v, q_rows)


def _gather_bwd(causal, window, softcap, scale, block_q, block_k,
                res, g):
    q, k, v, q_rows = res
    # the gather is part of the differentiated graph, so d/dq is the
    # scatter-add of the gathered-row grads back to token order
    _, vjp = jax.vjp(
        lambda q_, k_, v_: gathered_attention_ref(
            q_, k_, v_, q_rows, causal=causal, window=window,
            softcap=softcap, scale=scale), q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None


_flash_gather_vjp.defvjp(_gather_fwd, _gather_bwd)
