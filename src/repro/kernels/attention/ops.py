"""jit'd public wrapper for the flash-attention kernel.

Layout adaptation [B,S,H,D] <-> [B*H,S,D], GQA head mapping, custom VJP
(forward = kernel; backward = recompute via the jnp reference — same
math, so gradients are exact up to dtype rounding), and automatic
interpret-mode on CPU so every test/benchmark runs here while the same
code path targets TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention_bhsd
from repro.kernels.attention.ref import attention_ref


def _on_cpu():
    return jax.default_backend() == "cpu"


def _to_bhsd(x):
    B, S, H, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)


def _from_bhsd(x, B, H):
    BH, S, D = x.shape
    return x.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def _surrogate(q, k, v):
    """HBM-traffic-equivalent stand-in (REPRO_KERNEL_SURROGATE dry-run
    only): streams q/k/v once, writes out once — the flash kernel's
    memory signature, no [Sq, Sk] logits in HBM."""
    import jax.numpy as jnp
    B, Sq, H, D = q.shape
    K = k.shape[2]
    km = k.astype(jnp.float32).mean(1, keepdims=True)   # [B,1,K,D]
    vm = v.astype(jnp.float32).mean(1, keepdims=True)
    mix = (km + vm).repeat(H // K, axis=2)              # [B,1,H,D]
    return (q.astype(jnp.float32) + mix).astype(q.dtype)


def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128):
    """q [B,Sq,H,D], k/v [B,Sk,K,D] -> [B,Sq,H,D] (flash kernel)."""
    import os
    if os.environ.get("REPRO_KERNEL_SURROGATE") == "1" and _on_cpu():
        # differentiable surrogate (dry-run): fwd+bwd stream q/k/v/grads
        # once — the flash fwd+bwd kernels' HBM signature
        return _surrogate(q, k, v)
    return _flash_vjp(q, k, v, causal, window, softcap, scale, block_q,
                      block_k)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_vjp(q, k, v, causal=True, window=None, softcap=None,
               scale=None, block_q=128, block_k=128):
    B, Sq, H, D = q.shape
    bq = min(block_q, Sq)
    bk = min(block_k, Sq if k is None else k.shape[1])
    out = flash_attention_bhsd(
        _to_bhsd(q), _to_bhsd(k), _to_bhsd(v), causal=causal,
        window=window, softcap=softcap, scale=scale,
        block_q=bq, block_k=bk, interpret=_on_cpu())
    return _from_bhsd(out, B, H)


def _fwd(q, k, v, causal, window, softcap, scale, block_q, block_k):
    out = _flash_vjp(q, k, v, causal, window, softcap, scale,
                     block_q, block_k)
    return out, (q, k, v)


def _bwd(causal, window, softcap, scale, block_q, block_k, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window, softcap=softcap,
                                         scale=scale), q, k, v)
    return vjp(g)


_flash_vjp.defvjp(_fwd, _bwd)
