"""Version-robust Pallas TPU accessors.

``pallas.tpu`` renamed ``TPUCompilerParams`` to ``CompilerParams``; the
kernels build their params through here so they lower on either jax.
"""
from __future__ import annotations


def tpu_compiler_params(**kwargs):
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
