"""Version-robust Pallas TPU accessors.

``pallas.tpu`` renamed ``TPUCompilerParams`` to ``CompilerParams``; the
kernels build their params through here so they lower on either jax.

``pallas_interpret()`` is the interpret-mode fallback shim: Pallas
kernels (the compute kernels and the device-side ``PallasTransport``
lowering) ask it whether to run under the Pallas interpreter instead of
the Mosaic TPU compiler.  ``REPRO_PALLAS_INTERPRET=1`` forces interpret
mode anywhere (``0`` forces it off); unset, it auto-enables whenever no
TPU accelerator backs the default jax backend — which is what makes the
whole kernel surface, transport included, run bit-exact in tier-1 CI on
CPU-only hosts.
"""
from __future__ import annotations

import os


def tpu_compiler_params(**kwargs):
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def pallas_interpret() -> bool:
    """Should Pallas kernels run in interpret mode here?

    Priority: explicit env override (``REPRO_PALLAS_INTERPRET`` = 1/0),
    else auto-on when the default backend is not a TPU (CPU CI hosts,
    GPU hosts without a Mosaic path — the kernels target the TPU
    lowering, everything else interprets)."""
    v = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if v in ("1", "true", "on", "yes"):
        return True
    if v in ("0", "false", "off", "no"):
        return False
    import jax
    return jax.default_backend() != "tpu"
