"""Version-robust accessors for JAX APIs that moved across releases.

The repo targets the current JAX surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.lax.axis_size``, ``Mesh(..., axis_types=...)``)
but must also run on older installs (0.4.x) where those live elsewhere
or do not exist.  Every call site goes through this module instead of
feature-testing jax inline.

  * ``axis_size(name)``   — ``jax.lax.axis_size`` or the ``psum(1, name)``
                            trick (special-cased by jax to a static int).
  * ``shard_map(...)``    — ``jax.shard_map`` or the ``jax.experimental``
                            version; the ``check_vma`` kwarg maps onto the
                            old ``check_rep``.
  * ``make_mesh(...)``    — drops ``axis_types`` when unsupported.
  * ``set_mesh(mesh)``    — context manager; a no-op on versions without
                            an ambient-mesh concept (every shard_map here
                            carries its mesh explicitly, so nothing is
                            lost).
  * ``pallas_interpret()`` — re-export of the kernels-layer shim: should
                            Pallas kernels (including the device-side
                            ``PallasTransport``) run under the Pallas
                            interpreter?  ``REPRO_PALLAS_INTERPRET=1``
                            forces on, ``0`` forces off, unset auto-ons
                            when no TPU backs the default backend.
"""
from __future__ import annotations

import contextlib

import jax

from repro.kernels.compat import pallas_interpret  # noqa: F401 (re-export)


def axis_size(name) -> int:
    """Static size of a manual mesh axis (callable inside shard_map)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    # psum of the literal 1 is special-cased at trace time to the static
    # axis size (a Python int), on every jax version.
    return jax.lax.psum(1, name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis_types where supported."""
    axis_names = tuple(axis_names)
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = ((jax.sharding.AxisType.Auto,)
                                * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), axis_names, devices=devices,
                         **kwargs)


def set_mesh(mesh):
    """Ambient-mesh context manager (no-op where jax has none)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return contextlib.nullcontext(mesh)
