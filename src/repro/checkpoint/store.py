"""Sharded, atomic, async checkpointing with elastic restore.

Layout: <dir>/step_<N>/
    manifest.json          tree structure + leaf shapes/dtypes + meta
    shard_<k>.npz          leaf arrays owned by host k (leaves are
                           assigned round-robin by size for balance)
    _COMMITTED             written last -> atomicity marker

Fault-tolerance properties exercised by tests:
  * atomic: a crash mid-save leaves no _COMMITTED marker; restore picks
    the newest committed step and ignores partial directories.
  * async: ``AsyncCheckpointer`` snapshots to host memory synchronously
    (device_get) and writes in a background thread — the train loop
    blocks only for the copy, not the I/O.
  * elastic: restore takes the *tree*, not the mesh — arrays come back
    as numpy and are re-placed by the caller under any mesh/sharding
    (repro.launch.train re-shards them onto the current topology).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import ml_dtypes  # registers bfloat16 etc. with numpy  # noqa: F401
import numpy as np

import jax

_MARKER = "_COMMITTED"


class CheckpointCorruptError(RuntimeError):
    """A committed checkpoint could not be restored intact: truncated or
    bit-flipped shard file, unparseable manifest, missing leaf, shape or
    byte-count mismatch.  Typed so recovery code
    (``FaultTolerantLoop.resume_or_init``) can fall back to the newest
    *intact* checkpoint instead of crashing — while genuine programming
    errors (a tree_like that doesn't match the run) still surface with
    the full underlying cause chained."""


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz can't serialize extension dtypes (bfloat16): store raw bytes."""
    if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
        return arr.view(np.uint8)
    return arr


def _from_savable(arr: np.ndarray, dtype: str, shape) -> np.ndarray:
    want = np.dtype(dtype)
    if arr.dtype != want:
        arr = arr.view(want)
    return arr.reshape(shape)


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def save_checkpoint(ckpt_dir, step: int, tree, *, num_shards: int = 1,
                    meta: dict | None = None):
    """Synchronous sharded atomic save (host 0 API; in multi-host each
    host writes its own shard file — simulated here by writing all)."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves = _leaf_paths(tree)
    host = [np.asarray(jax.device_get(v)) for _, v in leaves]
    # round-robin-by-size shard assignment
    order = sorted(range(len(host)), key=lambda i: -host[i].nbytes)
    owner = {}
    loads = [0] * num_shards
    for i in order:
        k = loads.index(min(loads))
        owner[i] = k
        loads[k] += host[i].nbytes
    manifest = {
        "step": step,
        "meta": meta or {},
        "leaves": [{"path": p, "shape": list(v.shape),
                    "dtype": str(v.dtype), "shard": owner[i]}
                   for i, (p, v) in enumerate(zip(
                       [p for p, _ in leaves], host))],
        "num_shards": num_shards,
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    for k in range(num_shards):
        arrs = {f"leaf_{i}": _to_savable(host[i])
                for i in range(len(host)) if owner[i] == k}
        np.savez(tmp / f"shard_{k}.npz", **arrs)
    (tmp / _MARKER).write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def committed_steps(ckpt_dir) -> list[int]:
    """Committed step numbers, newest first (the fallback walk order
    for ``FaultTolerantLoop.resume_or_init``)."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / _MARKER).exists():
            steps.append(int(d.name.split("_")[1]))
    return sorted(steps, reverse=True)


def latest_step(ckpt_dir) -> int | None:
    steps = committed_steps(ckpt_dir)
    return steps[0] if steps else None


def restore_checkpoint(ckpt_dir, tree_like, step: int | None = None):
    """Returns (tree of numpy arrays shaped like ``tree_like``, meta).
    The caller re-places leaves under its current mesh (elastic).
    Raises ``CheckpointCorruptError`` when the committed step's files
    are damaged (truncated shard, flipped manifest bytes, missing or
    misshapen leaf)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    try:
        manifest = json.loads((d / "manifest.json").read_text())
        num_shards = int(manifest["num_shards"])
        leaves_meta = {m["path"]: (i, m) for i, m in
                       enumerate(manifest["leaves"])}
    except (OSError, ValueError, KeyError, TypeError) as e:
        raise CheckpointCorruptError(
            f"unreadable manifest in {d}: {e!r}") from e
    shards = {}
    try:
        for k in range(num_shards):
            with np.load(d / f"shard_{k}.npz") as z:
                shards.update({n: z[n] for n in z.files})
    except Exception as e:  # zipfile/np.load raise a zoo of types on
        raise CheckpointCorruptError(  # truncation and bad CRCs
            f"unreadable shard in {d}: {e!r}") from e
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for kp, like in flat:
        path = jax.tree_util.keystr(kp)
        if path not in leaves_meta:
            raise CheckpointCorruptError(
                f"checkpoint {d} missing leaf {path}")
        i, m = leaves_meta[path]
        key = f"leaf_{i}"
        if key not in shards:
            raise CheckpointCorruptError(
                f"checkpoint {d} shard files missing array for {path}")
        try:
            arr = _from_savable(shards[key], m["dtype"], m["shape"])
        except (ValueError, TypeError) as e:  # bad dtype string, byte
            raise CheckpointCorruptError(  # count not divisible, ...
                f"checkpoint {d} leaf {path} undecodable: {e!r}") from e
        want = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise CheckpointCorruptError(
                f"checkpoint {d} leaf {path} shape {arr.shape} != {want}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(tdef, out), manifest["meta"]


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training (one in flight at a time)."""

    def __init__(self, ckpt_dir, num_shards: int = 1):
        self.ckpt_dir = Path(ckpt_dir)
        self.num_shards = num_shards
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree, meta=None):
        self.wait()
        host_tree = jax.tree.map(  # blocking part: device -> host copy
            lambda v: np.asarray(jax.device_get(v)), tree)

        def _write():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree,
                                num_shards=self.num_shards, meta=meta)
            except Exception as e:   # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            e, self.last_error = self.last_error, None
            raise e
