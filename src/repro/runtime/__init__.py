from repro.runtime.fault import (  # noqa: F401
    FaultTolerantLoop, LinkFault, PreemptionSignal)
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.elastic import (  # noqa: F401
    ElasticScheduleSet, RankLossSignal, rank_remap, remesh_plan,
    shrink_topology)
from repro.runtime.tuning_daemon import (  # noqa: F401
    DriftReport, TuningDaemon)
