from repro.runtime.fault import (  # noqa: F401
    FaultTolerantLoop, PreemptionSignal)
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.elastic import remesh_plan  # noqa: F401
