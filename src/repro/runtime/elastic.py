"""Elastic scaling: survive mesh-shape changes without a restart.

Two paths live here:

  * **Elastic restore** (the original path): checkpoints store host
    numpy (sharding-free), so a run can *restart* onto a different mesh
    shape; ``remesh_plan`` recomputes the data decomposition and
    validates divisibility.

  * **Elastic re-derivation** (the no-restart path): on rank loss
    mid-run, ``shrink_topology`` rebuilds the surviving ``Topology``
    (dropping whole coordinate slices when the loss is geometric — a
    dead pod, a dead torus row — else flattening to the survivor set),
    ``rank_remap`` renumbers survivors densely, and
    ``ElasticScheduleSet.shrink`` re-derives every registered
    ``CommSchedule`` for the shrunk topology, warms the armed
    executors, and evicts the stale geometry's compiled-executor cache
    entries — swapped in place under the running ``FaultTolerantLoop``
    (``on_rank_loss``), no process restart.  The re-derived schedules
    are the same builders run on the shrunk topology, so they are
    bit-exact with a fresh build on that topology (asserted in tests
    and the ``fleet`` benchmark section).

``RankLossSignal`` is the latch between whatever detects the loss (a
heartbeat monitor, the scheduler, a test) and the loop that reacts.
"""
from __future__ import annotations

import dataclasses
import threading

from repro.core.topology import TopoLevel, Topology


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_devices: int
    new_devices: int
    global_batch: int
    per_device_batch: int
    num_data_shards: int

    @property
    def scale(self) -> float:
        return self.new_devices / self.old_devices


def remesh_plan(*, global_batch: int, old_devices: int, new_devices: int,
                data_axis_size: int) -> RemeshPlan:
    """Keep the global batch invariant; redistribute rows.

    data_axis_size = product of batch-sharded mesh axes on the NEW mesh.
    """
    if global_batch % data_axis_size:
        raise ValueError(
            f"global_batch {global_batch} not divisible by new data axis "
            f"{data_axis_size}; elastic restore would change the "
            f"trajectory")
    return RemeshPlan(
        old_devices=old_devices, new_devices=new_devices,
        global_batch=global_batch,
        per_device_batch=global_batch // data_axis_size,
        num_data_shards=data_axis_size)


# ---------------------------------------------------------------------------
# rank loss -> shrunk topology
# ---------------------------------------------------------------------------


class RankLossSignal:
    """Latches lost-rank notices (heartbeat monitor, scheduler, tests).

    ``trigger(ranks)`` accumulates; ``take()`` returns the deduplicated
    sorted list and clears the latch (None when nothing is pending) —
    the poll the ``FaultTolerantLoop`` makes once per step.  Thread-safe
    so a heartbeat thread can trigger while the loop steps.
    """

    def __init__(self):
        self._lost: set[int] = set()
        self._lock = threading.Lock()

    def trigger(self, ranks) -> None:
        ranks = [int(r) for r in (ranks if hasattr(ranks, "__iter__")
                                  else (ranks,))]
        with self._lock:
            self._lost.update(ranks)

    @property
    def pending(self) -> bool:
        return bool(self._lost)

    def take(self) -> list[int] | None:
        with self._lock:
            if not self._lost:
                return None
            out = sorted(self._lost)
            self._lost.clear()
            return out


def shrink_topology(topo: Topology, lost_ranks) -> Topology:
    """The surviving ``Topology`` after ``lost_ranks`` drop.

    When the loss is whole coordinate slices of one level (a dead pod
    at the DCN level, a dead row of a torus axis), that level shrinks
    in place and every other level — names, sizes, link models, DCN
    flags, including measured ``lm[]`` coefficients — is preserved, so
    staged builders keep their hierarchy.  A level shrunk to size 1 is
    dropped (it no longer routes anything).  Any other loss shape
    flattens to a single level of survivors over the innermost link
    class — the conservative geometry that is always correct.
    """
    lost = sorted({int(r) for r in lost_ranks})
    if not lost:
        raise ValueError("lost_ranks is empty; nothing to shrink")
    bad = [r for r in lost if r < 0 or r >= topo.nranks]
    if bad:
        raise ValueError(f"lost ranks {bad} out of range for "
                         f"nranks={topo.nranks}")
    if len(lost) >= topo.nranks:
        raise ValueError("all ranks lost; no surviving topology")
    lost_set = set(lost)
    for i, lv in enumerate(topo.levels):
        if lv.size < 2:
            continue
        coords_lost = {topo.coords(r)[i] for r in lost}
        if len(coords_lost) >= lv.size:
            continue
        slice_ranks = {r for r in range(topo.nranks)
                       if topo.coords(r)[i] in coords_lost}
        if slice_ranks != lost_set:
            continue
        new_size = lv.size - len(coords_lost)
        levels = []
        for j, l2 in enumerate(topo.levels):
            if j == i:
                if new_size == 1 and len(topo.levels) > 1:
                    continue
                levels.append(TopoLevel(l2.name, new_size, l2.link,
                                        l2.dcn))
            else:
                levels.append(l2)
        return Topology.from_levels(levels)
    inner = topo.levels[-1]
    return Topology.from_levels(
        [TopoLevel(inner.name, topo.nranks - len(lost), inner.link,
                   dcn=False)])


def rank_remap(topo: Topology, lost_ranks) -> dict[int, int]:
    """Dense renumbering of survivors: old rank -> new rank.

    Survivors keep their relative (row-major) order, which for
    whole-slice removal means the new rank's coordinates are the old
    ones with the shrunk axis renumbered — checkpoint shards and data
    shards move by this map, nothing is reshuffled.
    """
    lost = {int(r) for r in lost_ranks}
    return {old: new for new, old in enumerate(
        r for r in range(topo.nranks) if r not in lost)}


# ---------------------------------------------------------------------------
# in-place schedule re-derivation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ElasticSwapReport:
    """What one ``ElasticScheduleSet.shrink`` did (benchmark/telemetry
    record: the ``fleet`` section counts ``rederived``)."""

    lost_ranks: tuple
    old_fingerprint: str
    new_fingerprint: str
    rederived: tuple              # schedule names rebuilt
    refit: tuple                  # names whose algorithm changed
    invalidated: int              # stale compiled executors evicted
    generation: int
    remap: dict


class ElasticScheduleSet:
    """Named staged ``CommSchedule``s that survive rank loss in place.

    entries: name -> (collective, algorithm) — the plans a training or
    serving loop holds (grad-sync allreduce, MoE alltoall, ...).  Each
    is built from the live ``algorithms.REGISTRY`` against the current
    topology and warmed through the armed executor cache, exactly like
    ``api._schedule`` does.  ``shrink(lost)`` is the elastic swap: new
    topology, every schedule re-derived by the same builders (so the
    result is bit-exact with a fresh build on that topology), stale
    executors evicted — the running loop keeps the same object and
    never restarts.  An algorithm the shrunk topology cannot express
    (``NotApplicable`` — e.g. a power-of-2-only variant after dropping
    to 6 ranks) falls back down the selector's fixed preference ladder
    and is reported in ``refit``.
    """

    def __init__(self, topo: Topology, entries: dict, *,
                 warm: bool = True):
        self.topo = topo
        self.entries = {name: (coll, algo)
                        for name, (coll, algo) in entries.items()}
        self.generation = 0
        self.schedules: dict = {}
        self.executors: dict = {}
        self._warm = warm
        self._build()

    def _build(self) -> list[str]:
        from repro.core import executor
        from repro.core.algorithms import REGISTRY
        from repro.core.schedule import NotApplicable
        from repro.core.selector import _FIXED

        refit = []
        schedules, executors = {}, {}
        for name, (coll, algo) in self.entries.items():
            try:
                sched = REGISTRY[coll][algo](self.topo)
            except NotApplicable:
                ladder = [a for a in _FIXED.get(coll, ()) if a != algo]
                ladder += [a for a in REGISTRY[coll]
                           if a != algo and a not in ladder]
                for cand in ladder:
                    try:
                        sched = REGISTRY[coll][cand](self.topo)
                    except NotApplicable:
                        continue
                    self.entries[name] = (coll, cand)
                    refit.append(name)
                    break
                else:
                    raise
            schedules[name] = sched
            if self._warm:
                executors[name] = executor.get_executor(sched,
                                                        topo=self.topo)
        self.schedules = schedules
        self.executors = executors
        return refit

    def schedule_for(self, name):
        return self.schedules[name]

    def executor_for(self, name):
        return self.executors[name]

    def shrink(self, lost_ranks) -> ElasticSwapReport:
        from repro.core import executor

        lost = tuple(sorted({int(r) for r in lost_ranks}))
        old = self.topo
        old_fp = old.fingerprint()
        new_topo = shrink_topology(old, lost)
        remap = rank_remap(old, lost)
        self.topo = new_topo
        refit = self._build()
        invalidated = executor.invalidate_topology(old_fp)
        self.generation += 1
        return ElasticSwapReport(
            lost_ranks=lost, old_fingerprint=old_fp,
            new_fingerprint=new_topo.fingerprint(),
            rederived=tuple(sorted(self.schedules)),
            refit=tuple(sorted(refit)),
            invalidated=invalidated, generation=self.generation,
            remap=remap)
