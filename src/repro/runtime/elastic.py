"""Elastic scaling: restore a run onto a different mesh shape.

Checkpoints store host numpy (sharding-free); the train state is
re-placed under the new mesh by ``jax.device_put`` with the new
sharding.  What must *change consistently* is the data decomposition
and the per-device batch — ``remesh_plan`` computes that and validates
divisibility, so a 2-pod run can restart as 1-pod (degraded) or 4-pod
(scaled up) without touching the global training trajectory.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_devices: int
    new_devices: int
    global_batch: int
    per_device_batch: int
    num_data_shards: int

    @property
    def scale(self) -> float:
        return self.new_devices / self.old_devices


def remesh_plan(*, global_batch: int, old_devices: int, new_devices: int,
                data_axis_size: int) -> RemeshPlan:
    """Keep the global batch invariant; redistribute rows.

    data_axis_size = product of batch-sharded mesh axes on the NEW mesh.
    """
    if global_batch % data_axis_size:
        raise ValueError(
            f"global_batch {global_batch} not divisible by new data axis "
            f"{data_axis_size}; elastic restore would change the "
            f"trajectory")
    return RemeshPlan(
        old_devices=old_devices, new_devices=new_devices,
        global_batch=global_batch,
        per_device_batch=global_batch // data_axis_size,
        num_data_shards=data_axis_size)
