"""Fault handling for long runs: preemption-aware checkpoint/restart.

At 1000+ nodes the mean time between node failures is minutes-to-hours;
the contract implemented here is the standard production one:

  * periodic async checkpoints (every ``ckpt_every`` steps),
  * a preemption signal (SIGTERM on most schedulers) triggers one final
    synchronous checkpoint before exit,
  * on (re)start, training resumes from the newest committed step —
    combined with the step-addressable data pipeline this makes any
    crash exactly-once-recoverable: no data is skipped or repeated,
  * restart may happen on a *different* mesh shape (elastic restore —
    leaves come back as host numpy and are re-placed).
"""
from __future__ import annotations

import signal
from typing import Callable

from repro.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint


class PreemptionSignal:
    """Latches SIGTERM/SIGINT-style preemption notices (or test calls)."""

    def __init__(self, install_handlers: bool = False):
        self._hit = False
        if install_handlers:
            signal.signal(signal.SIGTERM, lambda *_: self.trigger())

    def trigger(self):
        self._hit = True

    @property
    def preempted(self) -> bool:
        return self._hit


class FaultTolerantLoop:
    """Drives ``step_fn(state, step) -> state`` with checkpoint/restart.

    step_fn must be pure w.r.t. (state, step); the data pipeline is
    addressed by ``step`` inside it.  ``state`` is a pytree.
    """

    def __init__(self, ckpt_dir, *, ckpt_every: int = 100,
                 preemption: PreemptionSignal | None = None,
                 num_shards: int = 1):
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.preemption = preemption or PreemptionSignal()
        self.ckpt = AsyncCheckpointer(ckpt_dir, num_shards=num_shards)

    def resume_or_init(self, init_state):
        step = latest_step(self.ckpt_dir)
        if step is None:
            return init_state, 0
        tree, meta = restore_checkpoint(self.ckpt_dir, init_state,
                                        step=step)
        return tree, meta.get("next_step", step + 1)

    def run(self, state, step_fn: Callable, *, start_step: int,
            num_steps: int, on_step=None):
        step = start_step
        end = start_step + num_steps
        while step < end:
            state = step_fn(state, step)
            step += 1
            if on_step is not None:
                on_step(step, state)
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, state, meta={"next_step": step})
            if self.preemption.preempted:
                self.ckpt.wait()
                self.ckpt.save(step, state, meta={"next_step": step,
                                                  "preempted": True})
                self.ckpt.wait()
                return state, step
        self.ckpt.wait()
        self.ckpt.save(end, state, meta={"next_step": end})
        self.ckpt.wait()
        return state, step
