"""Fault handling for long runs: preemption-aware checkpoint/restart.

At 1000+ nodes the mean time between node failures is minutes-to-hours;
the contract implemented here is the standard production one:

  * periodic async checkpoints (every ``ckpt_every`` steps),
  * a preemption signal (SIGTERM/SIGINT on most schedulers) triggers
    one final synchronous checkpoint before exit — at most one
    committed checkpoint per step, even when preemption lands exactly
    on a periodic checkpoint boundary,
  * on (re)start, training resumes from the newest committed step —
    combined with the step-addressable data pipeline this makes any
    crash exactly-once-recoverable: no data is skipped or repeated,
  * restart may happen on a *different* mesh shape (elastic restore —
    leaves come back as host numpy and are re-placed),
  * a latched rank-loss notice (``runtime.elastic.RankLossSignal``)
    triggers an in-place elastic swap instead of an exit: checkpoint,
    hand the surviving-rank list to ``on_rank_loss``, and keep stepping
    with whatever state/step_fn the handler returns — no restart.

``LinkFault`` is the deterministic degraded-fabric injector the drift
tests and the CI healing leg use: it scales specific topology levels'
alpha/beta inside ``core.linkprobe.model_timer`` so a probe pass
observes exactly the injected degradation and nothing else.
"""
from __future__ import annotations

import dataclasses
import math
import signal
import warnings
from typing import Callable

from repro.checkpoint import AsyncCheckpointer, CheckpointCorruptError, \
    committed_steps, restore_checkpoint
from repro.core.topology import LinkModel


class PreemptionSignal:
    """Latches SIGTERM/SIGINT preemption notices (or test calls).

    ``install_handlers=True`` installs the latch on BOTH signals —
    cluster schedulers deliver SIGTERM, interactive runs deliver SIGINT
    — and *chains* any previously installed callable handler instead of
    clobbering it, so a metrics flusher or profiler hook registered
    before the loop still runs.  The default SIGINT handler (which
    raises ``KeyboardInterrupt``) is deliberately not chained: the
    latch exists precisely to replace the abort with a final
    checkpoint.  ``uninstall()`` restores whatever was displaced.
    """

    _SIGNALS = (signal.SIGTERM, signal.SIGINT)

    def __init__(self, install_handlers: bool = False):
        self._hit = False
        self._prev: dict = {}
        if install_handlers:
            self.install()

    def install(self) -> None:
        for sig in self._SIGNALS:
            if sig in self._prev:       # idempotent: never chain self
                continue
            prev = signal.getsignal(sig)
            self._prev[sig] = prev
            signal.signal(sig, self._make_handler(prev))

    def _make_handler(self, prev):
        def handler(signum, frame):
            self.trigger()
            if callable(prev) and prev is not signal.default_int_handler:
                prev(signum, frame)
        return handler

    def uninstall(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        self._prev.clear()

    def trigger(self):
        self._hit = True

    @property
    def preempted(self) -> bool:
        return self._hit


@dataclasses.dataclass
class LinkFault:
    """Multiplicative per-level link degradation (test/CI injector).

    ``degrade(level, alpha_scale=, beta_scale=)`` arms the fault;
    ``apply(level, link)`` is the hook ``linkprobe.model_timer`` calls
    per probe — it returns the degraded ``LinkModel`` for armed levels
    and the original otherwise.  Scaling alpha and beta independently
    matters: a congested DCN shows up as a beta (bandwidth) collapse
    with latency intact, which is exactly the drift shape that must
    heal *only* the beta-dominated table cells.

    ``apply``/``clear`` are the shared link-injector protocol: any
    object with this pair plugs into ``linkprobe.model_timer`` —
    ``core.chaos.FaultPlan`` implements the same pair so a chaos
    campaign's hang events degrade the modeled fabric a probe pass
    observes, through the exact same hook.
    """

    scales: dict = dataclasses.field(default_factory=dict)

    def degrade(self, level: int, *, alpha_scale: float = 1.0,
                beta_scale: float = 1.0) -> None:
        # mirror LinkModel.__post_init__: finite and non-negative, so a
        # NaN/inf scale is rejected here instead of poisoning every
        # modeled probe time downstream
        for name, s in (("alpha_scale", alpha_scale),
                        ("beta_scale", beta_scale)):
            if not math.isfinite(s) or s < 0:
                raise ValueError(
                    f"{name} must be finite and >= 0, got {s}")
        self.scales[int(level)] = (float(alpha_scale), float(beta_scale))

    def clear(self, level: int | None = None) -> None:
        if level is None:
            self.scales.clear()
        else:
            self.scales.pop(int(level), None)

    def apply(self, level: int, link: LinkModel) -> LinkModel:
        sa, sb = self.scales.get(int(level), (1.0, 1.0))
        if sa == 1.0 and sb == 1.0:
            return link
        return LinkModel(alpha=link.alpha * sa, beta=link.beta * sb)


class FaultTolerantLoop:
    """Drives ``step_fn(state, step) -> state`` with checkpoint/restart.

    step_fn must be pure w.r.t. (state, step); the data pipeline is
    addressed by ``step`` inside it.  ``state`` is a pytree.

    ``rank_loss`` (a ``runtime.elastic.RankLossSignal``-shaped latch
    with ``take() -> list | None``) plus ``on_rank_loss(state, step,
    lost_ranks)`` wire the elastic path: when ranks drop mid-run the
    loop checkpoints, lets the handler re-derive schedules for the
    shrunk topology (``runtime.elastic.ElasticScheduleSet.shrink``),
    and continues with the returned ``(state, step_fn)`` — the step
    counter and data pipeline never reset.
    """

    def __init__(self, ckpt_dir, *, ckpt_every: int = 100,
                 preemption: PreemptionSignal | None = None,
                 num_shards: int = 1,
                 rank_loss=None,
                 on_rank_loss: Callable | None = None,
                 on_degraded: Callable | None = None):
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.preemption = preemption or PreemptionSignal()
        self.ckpt = AsyncCheckpointer(ckpt_dir, num_shards=num_shards)
        self.rank_loss = rank_loss
        self.on_rank_loss = on_rank_loss
        self.on_degraded = on_degraded
        # DegradationReports drained from api.take_degradations() per
        # step — the loop-level record of every recovered fault
        self.degradations: list = []

    def resume_or_init(self, init_state):
        """Resume from the newest *intact* checkpoint.

        A corrupt committed step (truncated shard, flipped bytes —
        ``CheckpointCorruptError``) is skipped with a warning and the
        walk continues to the next-newest committed step; only when
        every committed checkpoint is corrupt (or none exists) does the
        loop fall back to ``(init_state, 0)``."""
        for step in committed_steps(self.ckpt_dir):
            try:
                tree, meta = restore_checkpoint(self.ckpt_dir, init_state,
                                                step=step)
            except CheckpointCorruptError as e:
                warnings.warn(
                    f"skipping corrupt checkpoint step {step}: {e}",
                    RuntimeWarning, stacklevel=2)
                continue
            return tree, meta.get("next_step", step + 1)
        return init_state, 0

    def _drain_degradations(self, step: int) -> None:
        from repro.core import api

        reports = api.take_degradations()
        if not reports:
            return
        self.degradations.extend(reports)
        if self.on_degraded is not None:
            for rep in reports:
                self.on_degraded(step, rep)

    def run(self, state, step_fn: Callable, *, start_step: int,
            num_steps: int, on_step=None):
        step = start_step
        end = start_step + num_steps
        # step of the newest checkpoint this run committed/enqueued —
        # the guard against double-saving one step when preemption (or
        # the final save) lands on a periodic checkpoint boundary
        saved = None
        while step < end:
            state = step_fn(state, step)
            step += 1
            self._drain_degradations(step)
            if on_step is not None:
                on_step(step, state)
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, state, meta={"next_step": step})
                saved = step
            if self.preemption.preempted:
                self.ckpt.wait()
                if saved != step:
                    self.ckpt.save(step, state,
                                   meta={"next_step": step,
                                         "preempted": True})
                    self.ckpt.wait()
                return state, step
            lost = (self.rank_loss.take()
                    if self.rank_loss is not None else None)
            if lost:
                # persist the pre-swap state, then re-derive in place
                self.ckpt.wait()
                if saved != step:
                    self.ckpt.save(step, state,
                                   meta={"next_step": step,
                                         "lost_ranks": sorted(lost)})
                    self.ckpt.wait()
                    saved = step
                if self.on_rank_loss is not None:
                    res = self.on_rank_loss(state, step, sorted(lost))
                    if res is not None:
                        state, new_fn = res
                        if new_fn is not None:
                            step_fn = new_fn
        self.ckpt.wait()
        if saved != end:
            self.ckpt.save(end, state, meta={"next_step": end})
            self.ckpt.wait()
        return state, step
