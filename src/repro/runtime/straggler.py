"""Straggler detection and work reassignment.

TPU SPMD steps are globally synchronous, so stragglers surface as slow
*hosts* (input pipeline, checkpoint writes) rather than slow compute
shards.  The standard mitigation — implemented here — is:

  * track a robust per-host step-time estimate (median + MAD),
  * flag hosts slower than ``threshold`` x fleet median,
  * reassign the flagged host's *data shard* to the fastest host (the
    step-addressable pipeline makes shards location-free), and surface
    the flag so the scheduler can swap the node at the next checkpoint.
"""
from __future__ import annotations

import collections
import statistics


class StragglerMonitor:
    def __init__(self, num_hosts: int, window: int = 16,
                 threshold: float = 1.5):
        self.num_hosts = num_hosts
        self.window = window
        self.threshold = threshold
        self.times = [collections.deque(maxlen=window)
                      for _ in range(num_hosts)]
        # host -> list of data shards it currently materializes
        self.assignment = {h: [h] for h in range(num_hosts)}

    def record(self, host: int, step_time: float):
        self.times[host].append(step_time)

    def _estimate(self, host: int) -> float | None:
        t = self.times[host]
        return statistics.median(t) if len(t) >= 3 else None

    def stragglers(self) -> list[int]:
        ests = {h: self._estimate(h) for h in range(self.num_hosts)}
        known = [e for e in ests.values() if e is not None]
        if len(known) < max(2, self.num_hosts // 2):
            return []
        fleet = statistics.median(known)
        return [h for h, e in ests.items()
                if e is not None and e > self.threshold * fleet]

    def rebalance(self) -> dict[int, list[int]]:
        """Move each straggler's shards to the fastest non-straggler;
        a host measured healthy again reclaims its home shard first.

        Recovery is symmetric with eviction: a shard moves away only
        while its home host is flagged, and moves back the moment the
        host's estimate drops under threshold — a transiently slow host
        (GC pause, checkpoint write) is not stranded shard-less forever
        with its donor permanently overloaded.  Hosts with no estimate
        yet stay evicted (unknown is not healthy)."""
        slow = set(self.stragglers())
        for h in range(self.num_hosts):
            if h in slow or self._estimate(h) is None:
                continue
            for donor, shards in self.assignment.items():
                if donor != h and h in shards:
                    shards.remove(h)
                    self.assignment[h].append(h)
        if not slow:
            return self.assignment
        fast = sorted(
            (h for h in range(self.num_hosts)
             if h not in slow and self._estimate(h) is not None),
            key=self._estimate)
        if not fast:
            return self.assignment
        it = 0
        for h in sorted(slow):
            if not self.assignment[h]:
                continue
            tgt = fast[it % len(fast)]
            it += 1
            self.assignment[tgt].extend(self.assignment[h])
            self.assignment[h] = []
        return self.assignment
