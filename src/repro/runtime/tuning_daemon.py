"""Online tuner daemon: re-probe, detect drift, heal — scoped.

The offline story (tuner.autotune at launch) leaves the gap every
offline-tuned MPI leaves open: the fabric the table was measured on is
not the fabric an hours-long run finishes on.  A congested DCN, a
flapping optical link, a straggling host — all shift the real
alpha/beta away from what the tuned winners and the armed executor
passes were priced with.

``TuningDaemon`` closes the loop between steps (or from a background
thread):

  1. **re-probe** the fabric through ``core.linkprobe`` (the same
     timer — wire or model+fault — every tick, so what it observes is
     the fabric, not probe variance);
  2. **detect drift** per level with the noise-tolerant ratio rule
     (``drifted_levels``, same tolerance shape as the tuner's
     ``_cell_differs``) — a re-confirmed fabric is a no-op tick;
  3. **heal scoped**: ``tuner.drift_cells`` model-prices every table
     cell under old and new links and lists only the cells whose
     selection could move; ``tuner.retune_cells`` re-measures exactly
     those (generation bump), never the whole table;
  4. **swap keys**: the table rebases onto the new measured
     fingerprint, the stale geometry's compiled executors and cached
     api plans are evicted (``invalidate_topology`` — scoped, the
     executor cache keys already carry ``topo.fingerprint()``), and
     ``TuningDaemon.topo`` becomes the new measured topology that
     subsequent collectives arm against.

Every tick returns a ``DriftReport`` so callers (and the ``fleet``
benchmark section) can assert the heal really was scoped: cells
re-measured vs total, executors evicted, generation.
"""
from __future__ import annotations

import dataclasses
import threading

from repro.core import linkprobe
from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """One daemon tick's outcome (telemetry + test/benchmark record)."""

    step: int
    drifted_levels: tuple         # level indices past tolerance
    affected_cells: tuple         # (collective, bucket) heal work list
    retuned_cells: tuple          # subset that meaningfully changed
    total_cells: int              # table size the scope is judged against
    invalidated: dict             # {"plans": n, "executors": m} evicted
    generation: int               # table generation after the tick
    old_fingerprint: str
    new_fingerprint: str
    stragglers: tuple = ()        # flagged hosts, when a monitor is wired
    probe_skipped: tuple = ()     # (level, reason) levels kept on prior
                                  # links (probe deadline hit, bad fit)

    @property
    def healed(self) -> bool:
        return bool(self.drifted_levels)


class TuningDaemon:
    """Between-step (or background) drift healer for one topology.

    The daemon owns the *measured* topology: construction runs one
    probe pass and rebuilds ``topo`` around the fitted link models, so
    the tuned table it ensures is keyed by measured geometry from the
    first step.  ``tick(step)`` re-probes every ``probe_every`` steps;
    ``start(interval_s)``/``stop()`` run the same pass from a daemon
    thread for serving loops that never yield.

    ``timer`` is the probe clock: ``None`` picks wire measurement on a
    big-enough mesh (model pricing otherwise); tests and the CI healing
    leg inject ``linkprobe.model_timer(topo, fault=LinkFault(...))`` so
    drift is deterministic.  ``monitor`` (a ``StragglerMonitor``) is
    rebalanced on every tick and its flagged hosts ride along in the
    report — slow-host healing and slow-link healing share a heartbeat.
    """

    def __init__(self, topo: Topology, *, path=None,
                 probe_every: int = 1, drift_tol: float = 1.25,
                 cell_tol: float = 1.10, sizes=linkprobe.DEFAULT_PROBE_SIZES,
                 repeats: int = 3, timer=None, force_model: bool = False,
                 include_xla: bool = True, monitor=None, table=None,
                 probe_deadline_s: float | None = None):
        from repro.core import tuner

        if probe_every < 1:
            raise ValueError(f"probe_every must be >= 1, got {probe_every}")
        self.path = path
        self.probe_every = int(probe_every)
        self.drift_tol = float(drift_tol)
        self.cell_tol = float(cell_tol)
        self.sizes = tuple(sizes)
        self.repeats = int(repeats)
        self.force_model = bool(force_model)
        self.include_xla = bool(include_xla)
        self.monitor = monitor
        self._timer = timer
        # per-level probe wall-clock bound: a hung wire becomes a
        # recorded skip (level keeps its prior link) instead of a
        # wedged daemon thread — see linkprobe.probe_links(deadline_s=)
        self.probe_deadline_s = probe_deadline_s
        self._lock = threading.Lock()
        self._thread = None
        self._stop = threading.Event()
        self.reports: list[DriftReport] = []
        # baseline probe: measured geometry from step 0
        probe = linkprobe.probe_links(topo, sizes=self.sizes,
                                      repeats=self.repeats, timer=timer,
                                      deadline_s=probe_deadline_s)
        self.topo = linkprobe.measured_topology(topo, probe)
        if table is None:
            table = tuner.ensure_table(
                self.topo, path=self.path, repeats=self.repeats,
                include_xla=self.include_xla,
                force_model=self.force_model, tol=self.cell_tol)
        self.table = table

    # -- the heartbeat ----------------------------------------------------
    def tick(self, step: int = 0) -> DriftReport | None:
        """Probe-and-heal when ``step`` lands on the probe cadence
        (always on step 0 cadence arithmetic: every ``probe_every``-th
        call).  Returns the tick's report, or None on off-cadence
        steps."""
        if step % self.probe_every:
            return None
        return self.probe_and_heal(step=step)

    def probe_and_heal(self, step: int = 0) -> DriftReport:
        """One full pass: probe, compare, heal if drifted, swap keys."""
        from repro.core import api, tuner

        with self._lock:
            stragglers: tuple = ()
            if self.monitor is not None:
                self.monitor.rebalance()
                stragglers = tuple(self.monitor.stragglers())
            probe = linkprobe.probe_links(
                self.topo, sizes=self.sizes, repeats=self.repeats,
                timer=self._timer, deadline_s=self.probe_deadline_s)
            probe_skipped = tuple(sorted(probe.skipped.items()))
            new_topo = linkprobe.measured_topology(self.topo, probe)
            drifted = tuple(linkprobe.drifted_levels(
                self.topo, new_topo, tol=self.drift_tol))
            total = sum(len(per) for per in self.table.entries.values())
            if not drifted:
                report = DriftReport(
                    step=step, drifted_levels=(), affected_cells=(),
                    retuned_cells=(), total_cells=total,
                    invalidated={"plans": 0, "executors": 0},
                    generation=self.table.generation,
                    old_fingerprint=self.topo.fingerprint(),
                    new_fingerprint=self.topo.fingerprint(),
                    stragglers=stragglers, probe_skipped=probe_skipped)
                self.reports.append(report)
                return report
            old_topo = self.topo
            old_fp = old_topo.fingerprint()
            cells = tuner.drift_cells(self.table, old_topo, new_topo,
                                      tol=self.cell_tol)
            # rebase the table onto the new measured geometry, then
            # re-measure ONLY the affected cells under it
            self.table.fingerprint = tuner.substrate_fingerprint(
                new_topo, force_model=self.force_model)
            retuned = tuner.retune_cells(
                self.table, new_topo, cells, repeats=self.repeats,
                force_model=self.force_model,
                include_xla=self.include_xla, tol=self.cell_tol)
            tuner.save_table(self.table, path=self.path)
            # evict the stale geometry AFTER repricing: retune_cells
            # built the new topology's executors, which stay warm
            invalidated = api.invalidate_topology(old_topo)
            self.topo = new_topo
            report = DriftReport(
                step=step, drifted_levels=drifted,
                affected_cells=tuple(cells), retuned_cells=tuple(retuned),
                total_cells=total, invalidated=invalidated,
                generation=self.table.generation,
                old_fingerprint=old_fp,
                new_fingerprint=new_topo.fingerprint(),
                stragglers=stragglers, probe_skipped=probe_skipped)
            self.reports.append(report)
            return report

    # -- background mode --------------------------------------------------
    def start(self, interval_s: float = 30.0) -> None:
        """Run ``probe_and_heal`` every ``interval_s`` seconds from a
        daemon thread until ``stop()`` (serving loops that never yield
        between steps)."""
        if self._thread is not None:
            raise RuntimeError("daemon already started")
        self._stop.clear()

        def loop():
            tick = 0
            while not self._stop.wait(interval_s):
                tick += 1
                self.probe_and_heal(step=tick)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="repro-tuning-daemon")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
