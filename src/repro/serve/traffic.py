"""Serving traffic simulator: Poisson arrivals, bursty tenant mixes.

Seeded and fully deterministic, so the ``serve`` benchmark section's
claims (every arrival completes, TTFT in steps, KV bytes) are
machine-independent.  Two pieces:

  * ``poisson_workload`` — a request trace: per-tenant Poisson arrival
    processes with occasional bursts (a geometric burst of back-to-back
    arrivals, the multi-tenant thundering-herd case) and skewed
    prompt/gen length distributions (low tenant ids are chatty /
    short-prompt, high ids are doc-heavy / long-prompt);
  * ``run_workload`` — drives a ``ContinuousBatchingEngine`` against a
    trace: virtual time advances ``dt`` per engine tick and requests
    are submitted when their arrival time passes.
"""
from __future__ import annotations

import numpy as np

from repro.serve.engine import ContinuousBatchingEngine, EngineStall, Request


def poisson_workload(seed: int = 0, *, arrival_rate: float = 4.0,
                     tenants: int = 2, n_requests: int = 32,
                     mean_prompt: int = 24, mean_gen: int = 8,
                     burst_frac: float = 0.25, burst_len: int = 4,
                     max_prompt: int = 128,
                     max_gen: int = 64) -> list[Request]:
    """Seeded multi-tenant request trace (list sorted by arrival)."""
    if arrival_rate <= 0:
        raise ValueError(f"arrival_rate must be > 0, got {arrival_rate}")
    if tenants < 1:
        raise ValueError(f"tenants must be >= 1, got {tenants}")
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    t, rid = 0.0, 0
    while rid < n_requests:
        t += float(rng.exponential(1.0 / arrival_rate))
        k = 1
        if rng.random() < burst_frac:
            k = 1 + int(rng.geometric(1.0 / burst_len))
        for _ in range(min(k, n_requests - rid)):
            tenant = int(rng.integers(tenants))
            # tenant skew: chatty tenants send short prompts, doc-heavy
            # tenants long ones — the ragged mix the paged pools absorb
            scale = 0.5 + 1.5 * tenant / max(1, tenants - 1)
            p = int(np.clip(rng.gamma(2.0, mean_prompt * scale / 2.0),
                            1, max_prompt))
            g = int(np.clip(rng.gamma(1.5, mean_gen / 1.5), 1, max_gen))
            reqs.append(Request(rid=rid, tenant=tenant, prompt_len=p,
                                gen_len=g, arrival=t))
            rid += 1
    return reqs


def run_workload(engine: ContinuousBatchingEngine,
                 requests: list[Request], *, dt: float = 0.05,
                 max_steps: int = 50_000) -> dict:
    """Drive the engine through a trace; returns ``engine.metrics()``.

    One engine tick per ``dt`` of virtual time; raises ``EngineStall``
    when the engine stops making progress with no arrivals left to
    unblock it (a decode pool too small for the workload).
    """
    pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
    vt, idle = 0.0, 0
    while pending or engine.pending:
        vt += dt
        while pending and pending[0].arrival <= vt:
            engine.submit(pending.pop(0))
        before = (len(engine.done),
                  sum(len(r.tokens) for r in engine.active))
        engine.step()
        after = (len(engine.done),
                 sum(len(r.tokens) for r in engine.active))
        idle = 0 if after != before or pending else idle + 1
        if idle > 8:
            raise EngineStall(
                f"workload stalled at step {engine.step_count}: "
                f"{engine.pending} requests stuck with no arrivals left")
        if engine.step_count >= max_steps:
            raise EngineStall(
                f"workload exceeded max_steps={max_steps}")
    return engine.metrics()
