"""Serving steps: batched prefill + KV-cache decode.

Sharding (sharding.cache_specs):
  * decode_32k  — batch over (pod, data), heads over model.
  * long_500k   — batch 1: KV / recurrent state sequence-sharded over
    the data axes (sequence parallelism); the partitioner turns the
    softmax over the sharded KV length into partial-softmax + psum (the
    log-sum-exp combine), so one decode step touches each chip's KV
    shard locally and crosses the wire with O(heads) scalars.
    Only the sub-quadratic archs (rwkv6, jamba) run this cell.

Decode greedily samples (argmax) to keep the step closed under jit;
the example driver shows temperature sampling on top of the logits.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.train import sharding
from repro.train.moe_dispatch import EPOptions, make_moe_dispatch


@dataclasses.dataclass(frozen=True)
class ServeOptions:
    use_kernel: bool = False
    long_context: bool = False       # SP cache layout (batch-1 decode)
    ep_options: EPOptions | None = None
    # explicit expert-parallel dispatch for MoE archs during prefill
    # (None = XLA-sharded default).  With overlap_chunks set, the
    # dispatch alltoall pipelines against the expert MLPs — the serve
    # hot path gets the same compute-comm overlap as training.
    resilience: object = None
    # chaos-resilient dispatch collectives: overrides ep_options'
    # resilience when both are set (the serve knob wins so launchers
    # can arm verification without rebuilding EPOptions).


def init_serve_cache(cfg, batch: int, max_len: int):
    return M.init_cache(cfg, batch, max_len)


def make_prefill_step(cfg, mesh, opts: ServeOptions) -> Callable:
    """(params, tokens[, frames/vision]) -> logits — full-sequence
    forward used for prompt processing; dry-run target of prefill_32k."""

    moe_dispatch = None
    if opts.ep_options is not None and cfg.moe is not None:
        ep_opts = opts.ep_options
        if opts.resilience is not None:
            ep_opts = dataclasses.replace(ep_opts,
                                          resilience=opts.resilience)
        moe_dispatch = make_moe_dispatch(mesh, ep_opts, cfg.mlp_act)

    def prefill(params, batch):
        kw = {}
        if cfg.encoder is not None:
            kw["encoder_frames"] = batch["encoder_frames"]
        if cfg.vision_prefix:
            kw["vision_embeds"] = batch["vision_embeds"]
        return M.forward(params, cfg, batch["tokens"],
                         use_kernel=opts.use_kernel,
                         moe_dispatch=moe_dispatch, **kw)

    return prefill


def make_decode_step(cfg, mesh, opts: ServeOptions) -> Callable:
    """(params, cache, tokens [B,1][, cross_src]) ->
    (next_tokens [B,1], cache').  ``cross_src`` is the precomputed
    encoder output for enc-dec archs (whisper)."""

    if cfg.encoder is not None:
        def decode(params, cache, tokens, cross_src):
            logits, cache = M.decode_step(params, cfg, cache, tokens,
                                          cross_src=cross_src)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt[:, None], cache
        return decode

    def decode(params, cache, tokens):
        logits, cache = M.decode_step(params, cfg, cache, tokens)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return decode


def jit_decode_step(cfg, mesh, opts: ServeOptions, params, cache):
    pspec = sharding.param_specs(params, cfg, mesh)
    cspec = sharding.cache_specs(cache, cfg, mesh,
                                 long_context=opts.long_context)
    d_axes = sharding.data_axes(mesh)
    tok_spec = P() if opts.long_context else P(d_axes)
    to_sh = lambda spec: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P))
    step = make_decode_step(cfg, mesh, opts)
    in_sh = [to_sh(pspec), to_sh(cspec), NamedSharding(mesh, tok_spec)]
    if cfg.encoder is not None:
        in_sh.append(NamedSharding(mesh, P(d_axes)))
    return jax.jit(step,
                   in_shardings=tuple(in_sh),
                   out_shardings=(NamedSharding(mesh, tok_spec),
                                  to_sh(cspec))), (pspec, cspec)
