"""Continuous-batching serve engine with disaggregated KV pools.

The production serving shape: requests arrive continuously, prefill and
decode run in *separate* rank pools (different pods of one Topology, so
pool-to-pool traffic crosses DCN), and each request's paged KV-cache
blocks move from the prefill pool to the decode pool through ragged
neighbor ``CommSchedule``s compiled by ``core.kvtransfer`` — the same
IR, transports, tuner policy and resilience ladder as every other
collective in the stack.

Request state machine::

    WAITING --admit--> PREFILL --kv ready--> TRANSFER
        ^                                        |
        |  preempted (decode pool OOM)           | ragged alltoallv
        +----------------------------------------+--> DECODE --> DONE

Scheduling invariants (tested in tests/test_serve_engine.py):

  * admission is strict FIFO by arrival — head-of-line blocking means
    the oldest waiting request is always first to get blocks (no
    starvation);
  * the block pools never double-free (``DoubleFreeError``) and every
    block is back in the free list when the engine drains;
  * decode-pool OOM evicts the *youngest* decoding request (LIFO
    preemption protects the oldest work) back to WAITING;
  * every transfer batch is verified bitwise against the gather oracle
    — a mismatch is a typed ``TransferVerificationError``, never a
    silently corrupt cache.  With ``resilience=`` armed the transfer
    additionally runs the verify/retry/fallback ladder and the engine
    collects the ``DegradationReport`` stream.

The engine clock is the *step* (one tick = admit + prefill + transfer +
decode); TTFT and throughput are reported both in deterministic steps
and in wall seconds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core import kvtransfer
from repro.core.topology import Topology

WAITING, PREFILL, TRANSFER, DECODE, DONE = (
    "waiting", "prefill", "transfer", "decode", "done")


class DoubleFreeError(ValueError):
    """A block was freed that is not currently allocated."""


class TransferVerificationError(RuntimeError):
    """A KV transfer batch did not match the gather oracle bitwise."""


class EngineStall(RuntimeError):
    """The engine made no progress for a full sweep of ticks."""


class BlockPool:
    """Paged KV block allocator for one rank (free-list, O(1) ops)."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, -1, -1))
        self._used: set[int] = set()

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._used)

    def alloc(self, k: int) -> list[int] | None:
        """k blocks or None (caller decides to wait / evict)."""
        if k > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(k)]
        self._used.update(ids)
        return ids

    def free(self, ids) -> None:
        for i in ids:
            if i not in self._used:
                raise DoubleFreeError(
                    f"block {i} freed but not allocated "
                    f"(in use: {sorted(self._used)})")
            self._used.remove(i)
            self._free.append(i)


@dataclasses.dataclass
class Request:
    rid: int
    tenant: int
    prompt_len: int
    gen_len: int
    arrival: float                 # wall seconds (simulator time ok)
    arrival_step: int = 0
    state: str = WAITING
    admitted_step: int | None = None
    first_token_step: int | None = None
    first_token_s: float | None = None
    done_step: int | None = None
    prefill_rank: int | None = None
    prefill_blocks: list[int] = dataclasses.field(default_factory=list)
    decode_rank: int | None = None
    decode_blocks: list[int] = dataclasses.field(default_factory=list)
    tokens: list[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0

    def n_blocks(self, block_tokens: int) -> int:
        return -(-self.prompt_len // block_tokens)   # ceil


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Pool geometry + transfer knobs.

    Ranks ``[0, prefill_ranks)`` prefill; ``[prefill_ranks,
    prefill_ranks + decode_ranks)`` decode.  With ``ranks_per_pod``
    equal to the pool sizes the two pools sit in different pods and
    every KV transfer crosses DCN — the regime locality-aware
    aggregation is for.
    """

    prefill_ranks: int = 4
    decode_ranks: int = 4
    ranks_per_pod: int = 4
    blocks_per_rank: int = 32
    block_tokens: int = 8        # tokens per paged block
    block_feat: int = 16         # per-token KV feature width
    max_decode_batch: int = 64   # decode tokens emitted per tick
    transport: str = "sim"
    resilience: object = None    # None | "canary" | "full" | options
    aggregate: bool | None = None  # None = selection policy ladder
    policy: str | None = None

    def topology(self) -> Topology:
        n = self.prefill_ranks + self.decode_ranks
        if n % self.ranks_per_pod:
            raise ValueError(
                f"prefill+decode ranks ({n}) must tile ranks_per_pod "
                f"({self.ranks_per_pod})")
        return Topology(n, self.ranks_per_pod)

    @property
    def block_bytes(self) -> int:
        return self.block_tokens * self.block_feat * 4   # float32


def _default_decode(req: Request, pos: int) -> int:
    """Deterministic stand-in sampler (replayable without a model)."""
    return int((req.rid * 7919 + pos * 104729 + req.tenant) % 32000)


class ContinuousBatchingEngine:
    """Continuous batching over disaggregated prefill/decode pools.

    ``decode_fn(req, pos) -> token`` plugs a real model step in;
    ``kv_fill(rid, block_idx, shape) -> np.ndarray`` plugs real prefill
    KV content in (the default is a seeded deterministic fill, which is
    what makes bit-exactness testable without a model).
    ``transports`` is forwarded to the resilient transfer path — the
    chaos tests inject ``chaos.wrap``-ped rungs there.
    """

    def __init__(self, cfg: EngineConfig, *,
                 decode_fn: Callable | None = None,
                 kv_fill: Callable | None = None,
                 transports: dict | None = None):
        self.cfg = cfg
        self.topo = cfg.topology()
        n = self.topo.nranks
        self.decode_fn = decode_fn or _default_decode
        self.kv_fill = kv_fill or self._seeded_fill
        self.transports = transports
        self.prefill_pool_ranks = range(cfg.prefill_ranks)
        self.decode_pool_ranks = range(cfg.prefill_ranks, n)
        self.pools = {r: BlockPool(cfg.blocks_per_rank) for r in range(n)}
        # one global block pool buffer, the transfer plans' substrate:
        # [nranks, blocks_per_rank, block_tokens, block_feat]
        self.kv = np.zeros((n, cfg.blocks_per_rank, cfg.block_tokens,
                            cfg.block_feat), np.float32)
        self.step_count = 0
        self.waiting: list[Request] = []     # FIFO by arrival
        self.active: list[Request] = []      # admitted, not DONE
        self.done: list[Request] = []
        self.transfer_log: list[dict] = []   # per-batch telemetry
        self.degradations: list = []         # resilience reports
        self.preemptions = 0
        self._wall0: float | None = None

    # -- deterministic KV content (the testable oracle input) -------------
    def _seeded_fill(self, rid: int, block_idx: int, shape) -> np.ndarray:
        rng = np.random.default_rng((rid, block_idx))
        return rng.normal(size=shape).astype(np.float32)

    # -- public API -------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.arrival_step = self.step_count
        self.waiting.append(req)

    @property
    def pending(self) -> int:
        return len(self.waiting) + len(self.active)

    def step(self) -> None:
        """One engine tick: admit -> prefill -> transfer -> decode."""
        if self._wall0 is None:
            self._wall0 = time.perf_counter()
        self.step_count += 1
        self._admit()
        self._prefill()
        self._transfer()
        self._decode()

    def run(self, *, max_steps: int = 10_000) -> dict:
        """Drive until every submitted request is DONE; returns metrics.
        Raises ``EngineStall`` if a tick sweep makes no progress."""
        idle = 0
        while self.pending:
            before = (len(self.done), sum(len(r.tokens)
                                          for r in self.active))
            self.step()
            after = (len(self.done), sum(len(r.tokens)
                                         for r in self.active))
            idle = idle + 1 if after == before else 0
            if idle > 4:
                raise EngineStall(
                    f"no progress for {idle} ticks at step "
                    f"{self.step_count}: {self.pending} requests stuck "
                    f"(decode pool too small for the workload?)")
            if self.step_count >= max_steps:
                raise EngineStall(f"exceeded max_steps={max_steps} with "
                                  f"{self.pending} requests pending")
        return self.metrics()

    # -- tick phases ------------------------------------------------------
    def _admit(self) -> None:
        """Strict FIFO: the head of the waiting queue is admitted as
        soon as any prefill rank has room; a blocked head blocks the
        queue (head-of-line = oldest-first = starvation-free)."""
        while self.waiting:
            req = self.waiting[0]
            k = req.n_blocks(self.cfg.block_tokens)
            rank = max(self.prefill_pool_ranks,
                       key=lambda r: self.pools[r].available)
            blocks = self.pools[rank].alloc(k)
            if blocks is None:
                return
            self.waiting.pop(0)
            req.state = PREFILL
            req.admitted_step = self.step_count
            req.prefill_rank, req.prefill_blocks = rank, blocks
            self.active.append(req)

    def _prefill(self) -> None:
        for req in self.active:
            if req.state != PREFILL:
                continue
            shape = (self.cfg.block_tokens, self.cfg.block_feat)
            for j, b in enumerate(req.prefill_blocks):
                self.kv[req.prefill_rank, b] = self.kv_fill(req.rid, j,
                                                            shape)
            req.state = TRANSFER

    def _alloc_decode(self, req: Request) -> bool:
        """Decode-pool blocks for ``req``; evicts the youngest decoding
        request on OOM (LIFO preemption)."""
        k = req.n_blocks(self.cfg.block_tokens)
        while True:
            rank = max(self.decode_pool_ranks,
                       key=lambda r: self.pools[r].available)
            blocks = self.pools[rank].alloc(k)
            if blocks is not None:
                req.decode_rank, req.decode_blocks = rank, blocks
                return True
            victims = [r for r in self.active if r.state == DECODE
                       and r is not req]
            if not victims:
                return False
            victim = max(victims, key=lambda r: (r.admitted_step, r.rid))
            self.pools[victim.decode_rank].free(victim.decode_blocks)
            victim.decode_rank = None
            victim.decode_blocks = []
            victim.tokens.clear()
            victim.state = WAITING
            victim.preemptions += 1
            self.preemptions += 1
            self.active.remove(victim)
            # preempted work re-enters the queue in arrival order so it
            # cannot leapfrog requests that never got served
            pos = next((i for i, w in enumerate(self.waiting)
                        if w.arrival > victim.arrival), len(self.waiting))
            self.waiting.insert(pos, victim)

    def _transfer(self) -> None:
        """Batch every TRANSFER-state request into ONE ragged plan."""
        ready: list[Request] = []
        for req in [r for r in self.active if r.state == TRANSFER]:
            if self._alloc_decode(req):
                ready.append(req)
        if not ready:
            return
        moves = []
        for req in ready:
            for pb, db in zip(req.prefill_blocks, req.decode_blocks):
                moves.append(kvtransfer.BlockMove(
                    src=req.prefill_rank, src_row=pb,
                    dst=req.decode_rank, dst_row=db))
        cfg = self.cfg
        tp = kvtransfer.build_transfer_plan(
            moves, self.topo, blocks_per_rank=cfg.blocks_per_rank,
            aggregate=cfg.aggregate, policy=cfg.policy,
            block_bytes=cfg.block_bytes)
        res = kvtransfer.run_transfer(
            tp, self.kv, transport=cfg.transport,
            resilience=cfg.resilience, transports=self.transports)
        if res.report is not None:
            self.degradations.append(res.report)
        if not kvtransfer.verify_bitwise(tp, self.kv, res):
            raise TransferVerificationError(
                f"KV transfer batch of {len(moves)} blocks mismatched "
                f"the gather oracle (plan {tp.plan.name}, transport "
                f"{cfg.transport})")
        kvtransfer.apply_updates(res, self.kv)
        traffic = tp.traffic()
        self.transfer_log.append({
            "step": self.step_count, "requests": len(ready),
            "blocks": len(moves), "bytes": res.nbytes,
            "plan": res.plan_name, "seconds": res.seconds,
            "modeled_s": tp.modeled_time(),
            "dcn_bytes": traffic["dcn"],
            "ici_bytes": traffic["ici"],
            "moves": tuple(moves),
        })
        for req in ready:
            self.pools[req.prefill_rank].free(req.prefill_blocks)
            req.prefill_rank, req.prefill_blocks = None, []
            req.state = DECODE

    def _decode(self) -> None:
        """One token per decoding request per tick, oldest first."""
        decoding = sorted(
            [r for r in self.active if r.state == DECODE],
            key=lambda r: (r.admitted_step, r.arrival, r.rid))
        for req in decoding[: self.cfg.max_decode_batch]:
            pos = len(req.tokens)
            req.tokens.append(self.decode_fn(req, pos))
            if req.first_token_step is None:
                req.first_token_step = self.step_count
                req.first_token_s = time.perf_counter() - self._wall0
            if len(req.tokens) >= req.gen_len:
                self.pools[req.decode_rank].free(req.decode_blocks)
                req.decode_rank, req.decode_blocks = None, []
                req.state = DONE
                req.done_step = self.step_count
                self.active.remove(req)
                self.done.append(req)

    # -- metrics ----------------------------------------------------------
    def metrics(self) -> dict:
        wall = (time.perf_counter() - self._wall0
                if self._wall0 is not None else 0.0)
        toks = sum(len(r.tokens) for r in self.done + self.active)
        ttft = sorted(r.first_token_step - r.arrival_step
                      for r in self.done if r.first_token_step is not None)
        def pct(q: float) -> float:
            if not ttft:
                return 0.0
            return float(ttft[min(len(ttft) - 1, int(q * len(ttft)))])
        xfer = self.transfer_log
        return {
            "submitted": len(self.done) + self.pending,
            "completed": len(self.done),
            "steps": self.step_count,
            "tokens": toks,
            "tokens_per_step": round(toks / max(1, self.step_count), 3),
            "tokens_per_s": round(toks / wall, 1) if wall > 0 else 0.0,
            "wall_s": round(wall, 4),
            "preemptions": self.preemptions,
            "ttft_steps": {"mean": (round(sum(ttft) / len(ttft), 3)
                                    if ttft else 0.0),
                           "p50": pct(0.50), "p99": pct(0.99)},
            "kv_transfer": {
                "plans": len(xfer),
                "blocks": sum(x["blocks"] for x in xfer),
                "bytes": sum(x["bytes"] for x in xfer),
                "dcn_bytes": sum(x["dcn_bytes"] for x in xfer),
                "ici_bytes": sum(x["ici_bytes"] for x in xfer),
                "wall_s": round(sum(x["seconds"] for x in xfer), 4),
                "modeled_s": sum(x["modeled_s"] for x in xfer),
                "plan_names": sorted({x["plan"] for x in xfer}),
            },
            "degradations": len(self.degradations),
        }
