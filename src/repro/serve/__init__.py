from repro.serve.step import (  # noqa: F401
    ServeOptions, jit_decode_step, make_decode_step, make_prefill_step,
    init_serve_cache)
from repro.serve.engine import (  # noqa: F401
    BlockPool, ContinuousBatchingEngine, DoubleFreeError, EngineConfig,
    EngineStall, Request, TransferVerificationError)
from repro.serve.traffic import poisson_workload, run_workload  # noqa: F401
