from repro.serve.step import (  # noqa: F401
    ServeOptions, make_decode_step, make_prefill_step, init_serve_cache)
