"""Assigned-architecture registry: ``get_config(arch)`` / ``get_smoke(arch)``.

One module per architecture (the assignment's exact published numbers);
``SMOKE`` variants are hand-reduced same-family configs for CPU tests.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "gemma2-2b", "gemma-2b", "qwen3-14b", "smollm-360m",
    "deepseek-v3-671b", "moonshot-v1-16b-a3b", "rwkv6-3b",
    "whisper-small", "qwen2-vl-7b", "jamba-1.5-large-398b",
]


def _module(arch: str):
    return importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return _module(arch).config()


def get_smoke(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return _module(arch).smoke()
