"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff(dense)=18432,
MoE 256e top-8 + 1 shared (d_expert=2048), MLA (q_lora 1536, kv_lora 512,
nope 128 + rope 64, v 128), sigmoid router scale 2.5, vocab=129280,
first 3 layers dense.  MTP head omitted (see DESIGN.md).
[arXiv:2412.19437]"""
from repro.models.config import (BlockSpec, MLAConfig, ModelConfig,
                                 MoEConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        d_model=7168, vocab_size=129280, d_ff=18432,
        prefix=(BlockSpec("mla", "mlp"),) * 3,
        period=(BlockSpec("mla", "moe"),), n_periods=58,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128, n_heads=128, rope_theta=10000.0),
        moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                      router="sigmoid", route_scale=2.5, norm_topk=True),
        mlp_act="silu", tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke",
        d_model=64, vocab_size=277, d_ff=160,
        prefix=(BlockSpec("mla", "mlp"),),
        period=(BlockSpec("mla", "moe"),), n_periods=2,
        mla=MLAConfig(q_lora_rank=24, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16, n_heads=4, rope_theta=10000.0),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=48, n_shared=1,
                      router="sigmoid", route_scale=2.5, norm_topk=True),
        mlp_act="silu", tie_embeddings=False,
    )
