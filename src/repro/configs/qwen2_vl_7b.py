"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE (sections 16/24/24), dynamic-resolution patch
frontend stubbed (input_specs supplies precomputed patch embeddings for
the leading vision positions).  [arXiv:2409.12191]"""
from repro.models.config import AttnConfig, BlockSpec, ModelConfig

VISION_PREFIX = 256            # stubbed patch positions per sequence


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        d_model=3584, vocab_size=152064, d_ff=18944,
        prefix=(), period=(BlockSpec("attn", "mlp"),), n_periods=28,
        attn=AttnConfig(n_heads=28, n_kv_heads=4, head_dim=128,
                        rope_theta=1_000_000.0,
                        mrope_sections=(16, 24, 24)),
        mlp_act="silu", tie_embeddings=False,
        vision_prefix=VISION_PREFIX,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b-smoke",
        d_model=64, vocab_size=277, d_ff=160,
        prefix=(), period=(BlockSpec("attn", "mlp"),), n_periods=3,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                        rope_theta=1_000_000.0,
                        mrope_sections=(2, 3, 3)),
        mlp_act="silu", tie_embeddings=False,
        vision_prefix=8,
    )
