"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536 —
Finch, data-dependent decay, head_dim 64.  [arXiv:2404.05892]"""
from repro.models.config import BlockSpec, ModelConfig, RWKVConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        d_model=2560, vocab_size=65536, d_ff=8960,
        prefix=(), period=(BlockSpec("rwkv", "cmix"),), n_periods=32,
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke",
        d_model=64, vocab_size=277, d_ff=160,
        prefix=(), period=(BlockSpec("rwkv", "cmix"),), n_periods=3,
        rwkv=RWKVConfig(head_dim=16, decay_lora=8, mix_lora=8),
        tie_embeddings=False,
    )
