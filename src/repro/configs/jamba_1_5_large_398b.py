"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16e top-2 — mamba+attn 1:7 interleave (attn at offset 4
of every 8), MoE every other layer; attention carries no positional
embedding (mamba supplies order).  [arXiv:2403.19887]"""
from repro.models.config import (AttnConfig, BlockSpec, MambaConfig,
                                 ModelConfig, MoEConfig)


def _period(window=None):
    # layers 0..7: attn at 4, MoE on odd layers (offsets from the paper)
    return tuple(
        BlockSpec(mixer=("attn" if i == 4 else "mamba"),
                  ff=("moe" if i % 2 == 1 else "mlp"))
        for i in range(8))


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        d_model=8192, vocab_size=65536, d_ff=24576,
        prefix=(), period=_period(), n_periods=9,
        attn=AttnConfig(n_heads=64, n_kv_heads=8, head_dim=128,
                        use_rope=False),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576,
                      router="softmax", norm_topk=True),
        mlp_act="silu", tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-smoke",
        d_model=64, vocab_size=277, d_ff=160,
        prefix=(), period=_period(), n_periods=1,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                        use_rope=False),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=48,
                      router="softmax", norm_topk=True),
        mlp_act="silu", tie_embeddings=False,
    )
