"""whisper-small [audio]: enc-dec 12L+12L d_model=768 12H d_ff=3072
vocab=51865 — conv frontend stubbed (input_specs supplies 1500
precomputed frame embeddings); plain (non-gated) GELU MLP.
[arXiv:2212.04356]"""
from repro.models.config import (AttnConfig, BlockSpec, EncoderConfig,
                                 ModelConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        d_model=768, vocab_size=51865, d_ff=3072,
        prefix=(),
        period=(BlockSpec("attn", "mlp", cross=True),), n_periods=12,
        attn=AttnConfig(n_heads=12, n_kv_heads=12, head_dim=64,
                        rope_theta=10000.0),
        encoder=EncoderConfig(n_layers=12, d_model=768, n_heads=12,
                              d_ff=3072, n_frames=1500),
        mlp_act="gelu", gated_mlp=False, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke",
        d_model=64, vocab_size=277, d_ff=128,
        prefix=(),
        period=(BlockSpec("attn", "mlp", cross=True),), n_periods=2,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                        rope_theta=10000.0),
        encoder=EncoderConfig(n_layers=2, d_model=64, n_heads=4,
                              d_ff=128, n_frames=30),
        mlp_act="gelu", gated_mlp=False, tie_embeddings=True,
    )
