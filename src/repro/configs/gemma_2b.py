"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=256000 — GeGLU, head_dim=256.  [arXiv:2403.08295]"""
from repro.models.config import AttnConfig, BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        d_model=2048, vocab_size=256000, d_ff=16384,
        prefix=(), period=(BlockSpec("attn", "mlp"),), n_periods=18,
        attn=AttnConfig(n_heads=8, n_kv_heads=1, head_dim=256,
                        rope_theta=10000.0),
        mlp_act="gelu", gemma_norm=True, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke",
        d_model=64, vocab_size=277, d_ff=192,
        prefix=(), period=(BlockSpec("attn", "mlp"),), n_periods=3,
        attn=AttnConfig(n_heads=4, n_kv_heads=1, head_dim=16,
                        rope_theta=10000.0),
        mlp_act="gelu", gemma_norm=True, tie_embeddings=True,
    )
