"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (kv=16) d_ff=1408
(expert dim), MoE 64e top-6 + 2 shared, sigmoid router, first layer
dense (d_ff 11264), vocab=163840 — kimi/moonlight family.
[hf:moonshotai/Moonlight-16B-A3B]"""
import dataclasses

from repro.models.config import AttnConfig, BlockSpec, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        d_model=2048, vocab_size=163840, d_ff=11264,
        prefix=(BlockSpec("attn", "mlp"),),
        period=(BlockSpec("attn", "moe"),), n_periods=47,
        attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                        rope_theta=50000.0),
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                      router="sigmoid", route_scale=2.446, norm_topk=True),
        mlp_act="silu", tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-smoke",
        d_model=64, vocab_size=277, d_ff=160,
        prefix=(BlockSpec("attn", "mlp"),),
        period=(BlockSpec("attn", "moe"),), n_periods=2,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                        rope_theta=50000.0),
        moe=MoEConfig(n_experts=8, top_k=3, d_expert=48, n_shared=2,
                      router="sigmoid", route_scale=2.446, norm_topk=True),
        mlp_act="silu", tie_embeddings=True,
    )
