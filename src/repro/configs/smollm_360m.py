"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 — llama-arch small.  [hf:HuggingFaceTB/SmolLM-360M]"""
from repro.models.config import AttnConfig, BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        d_model=960, vocab_size=49152, d_ff=2560,
        prefix=(), period=(BlockSpec("attn", "mlp"),), n_periods=32,
        attn=AttnConfig(n_heads=15, n_kv_heads=5, head_dim=64,
                        rope_theta=10000.0),
        mlp_act="silu", tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-smoke",
        d_model=60, vocab_size=277, d_ff=160,
        prefix=(), period=(BlockSpec("attn", "mlp"),), n_periods=3,
        attn=AttnConfig(n_heads=3, n_kv_heads=1, head_dim=20,
                        rope_theta=10000.0),
        mlp_act="silu", tie_embeddings=True,
    )
