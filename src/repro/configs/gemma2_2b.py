"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000 — local+global alternating (window 4096), logit softcaps,
sandwich norms, GeGLU, head_dim 256.  [arXiv:2408.00118]"""
from repro.models.config import AttnConfig, BlockSpec, ModelConfig

WINDOW = 4096


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        d_model=2304, vocab_size=256000, d_ff=9216,
        prefix=(),
        period=(BlockSpec("attn", "mlp", window=WINDOW),   # local
                BlockSpec("attn", "mlp", window=None)),    # global
        n_periods=13,
        attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=256,
                        rope_theta=10000.0, softcap=50.0),
        mlp_act="gelu", gemma_norm=True, post_block_norm=True,
        tie_embeddings=True, final_softcap=30.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b-smoke",
        d_model=64, vocab_size=277, d_ff=128,
        prefix=(),
        period=(BlockSpec("attn", "mlp", window=8),
                BlockSpec("attn", "mlp", window=None)),
        n_periods=2,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                        rope_theta=10000.0, softcap=50.0),
        mlp_act="gelu", gemma_norm=True, post_block_norm=True,
        tie_embeddings=True, final_softcap=30.0,
    )
