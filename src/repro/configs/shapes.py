"""Assigned input shapes and (arch x shape) cell applicability."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: only the SSM and the hybrid
# run it; the 8 pure-full-attention archs skip (see DESIGN.md §5).
LONG_OK = {"rwkv6-3b", "jamba-1.5-large-398b"}


def runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_OK
    return True


def cells():
    """All 40 assigned cells with a runnable flag."""
    from repro.configs import ARCHS
    return [(a, s, runnable(a, s)) for a in ARCHS for s in SHAPES]
