"""qwen3-14b [dense]: 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, head_dim 128, untied.  [hf:Qwen/Qwen3-14B]"""
from repro.models.config import AttnConfig, BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b",
        d_model=5120, vocab_size=151936, d_ff=17408,
        prefix=(), period=(BlockSpec("attn", "mlp"),), n_periods=40,
        attn=AttnConfig(n_heads=40, n_kv_heads=8, head_dim=128,
                        rope_theta=1_000_000.0, qk_norm=True),
        mlp_act="silu", tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-smoke",
        d_model=64, vocab_size=277, d_ff=160,
        prefix=(), period=(BlockSpec("attn", "mlp"),), n_periods=3,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                        rope_theta=1_000_000.0, qk_norm=True),
        mlp_act="silu", tie_embeddings=False,
    )
