"""Persistent-executor compilation of ``CommSchedule``s.

MPI Advance's core performance move is hoisting all collective setup
into one-time persistent initialization (MPI-4 persistent collectives)
so the steady-state path pays only for data movement.  The plan layer
already does the algorithmic half at build time; this module does the
*execution* half: a ``CommSchedule`` is lowered once to a
``CompiledExec`` and cached process-wide, so repeated execution —
training steps, tuner timing loops, bit-exactness sweeps — never
re-derives tables, re-uploads constants, or re-runs Python shape logic.

The compile pass (all steps skipped with ``optimize=False`` or
``REPRO_EXEC_OPTIMIZE=0``):

  1. **local_pre fold** — a bijective pre-permutation (Bruck rotation)
     is composed into every round's gather/scatter tables and into
     ``local_post``, eliding one whole-buffer gather per execution.
  2. **Round fusion** — each non-reduce round merges, whole, into the
     earliest earlier round where the ``schedule.can_fuse`` legality
     rule holds (disjoint src/dst sets, no scatter->gather aliasing
     across the gap) AND the padded message widths match (a
     profitability condition on top of legality: unequal widths would
     pad the narrower round's messages on the wire): one ``ppermute``
     disappears per merge, a direct cut of the alpha term, and the
     merged round's max-priced time is ``max(a, b)`` — never slower
     under the alpha-beta model.  Reduce rounds are barriers —
     accumulation order is preserved bit-for-bit.
  3. **Topology-armed fusion + reordering** (only with a ``topo=``) —
     a second compaction over the already-fused rounds, armed with the
     alpha-beta ``Topology`` cost model.  Per-edge hazard lower bounds
     form the src/dst interference DAG; rounds are then greedily packed
     into earlier antichains (concurrent rounds priced by max link
     time) through two pointwise-cost-safe moves:
       * whole-round merge into ONE earlier round with *any* widths —
         the merged round carries ``payload`` so every edge keeps its
         pre-merge priced width, per-port times are unchanged, and the
         merged round costs ``max(a, b)`` at every message size;
       * all-or-nothing multi-target split: every edge of a round
         migrates to some earlier round — at most one target round (the
         primary) may raise its max, every other target must already
         hold an edge whose (alpha, priced-bytes*beta) dominates the
         arrival — so the total increase is bounded by the deleted
         round's time at every message size.
     Both moves are provably never slower than the topology-free pass
     for every slot size (not just the probed one); see _compact_armed.
  4. **Dead-slot elision** — message positions whose scatter target is
     ``-1`` (dropped on arrival) and edges that deliver nothing are
     removed from the execution tables (accounting still reads the
     original schedule).
  5. **Scratch-zero elision** — the per-round scratch-row re-zeroing of
     the historical lowering is dropped: every scratch read is masked,
     so the zeroing was dead work.
  6. **Baked tables + masks** — per-round index tables AND the
     ``jnp.where`` gather/scatter masks (plus scratch-safe indices) are
     materialized once (numpy for the simulator, device constants for
     shard_map) instead of per trace.

Both transports route through here (``transport.SimTransport`` /
``ShardMapTransport.run`` are thin lookups).  The executor cache is
keyed by (schedule fingerprint, optimize flag, validation flag,
topology fingerprint) — per-geometry compilations never collide; the
jit layer above adds (shape, dtype, axis_names) exactly once per
combination — ``CompiledExec.trace_count`` counts lowerings so tests
can prove the persistent-collective property: one trace, many steps.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

import jax.numpy as jnp

from repro.core.schedule import (CommRound, CommSchedule, ComputeEvent,  # noqa: F401 (can_fuse/ComputeEvent re-exported: executor is their consumer-facing home)
                                 can_fuse, can_split, split_round,
                                 validate_schedules_enabled)
from repro.core.topology import Topology


def optimize_enabled() -> bool:
    """True unless ``REPRO_EXEC_OPTIMIZE`` disables the peephole passes
    (escape hatch; the unoptimized executor mirrors the historical
    round-by-round lowering and is the fused path's reference)."""
    v = os.environ.get("REPRO_EXEC_OPTIMIZE", "1").strip().lower()
    return v not in ("", "0", "false", "off", "no")


# ---------------------------------------------------------------------------
# edge extraction + compaction (the fusion pass)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Edge:
    """One (src -> dst) message: aligned gather/scatter position vectors
    (position j of the wire payload reads ``gather[j]`` on src and lands
    at ``scatter[j]`` on dst; -1 gathers send zeros).

    ``price_slots`` is the slot count the alpha-beta model charges this
    edge in its *source* round (the round's padded width for dense
    block tables, the per-source ``payload`` count for ragged rounds)
    — the topology-armed pass must preserve it through merges so
    per-port times never move.

    ``orig`` is the index of the *original* (pre-compaction) round the
    edge came from; buckets carry the min over their members so the
    makespan pass can resolve ``ComputeEvent.after_round`` anchors —
    compaction only moves edges earlier, so every final round holding
    content from original rounds <= i has ``min(orig) <= i``."""

    src: int
    dst: int
    gather: np.ndarray           # int, [k_e]
    scatter: np.ndarray          # int, [k_e]; all >= 0 after compression
    has_payload: bool
    price_slots: int = 0
    orig: int = 0

    @property
    def reads(self) -> set:
        return set(int(b) for b in self.gather[self.gather >= 0])

    @property
    def writes(self) -> set:
        return set(int(b) for b in self.scatter[self.scatter >= 0])


def _round_edges(rnd: CommRound, compress: bool, orig: int = 0
                 ) -> list[_Edge]:
    out = []
    for s, d in rnd.perm:
        g = np.asarray(rnd.gather_idx[s], np.int64)
        t = np.asarray(rnd.scatter_idx[d], np.int64)
        if compress:
            keep = t >= 0            # dropped-on-arrival slots are dead
            g, t = g[keep], t[keep]
            if not len(t):           # message delivers nothing: elide
                continue
        if rnd.payload is not None:
            # trimming can only drop dead (dropped-on-arrival) wire
            # slots, so the priced count never grows past the original
            price = min(int(rnd.payload[s]), int((g >= 0).sum()))
        else:
            # dense block tables: the model charges every edge the
            # round's full padded width (padding ships zeros)
            price = rnd.k
        out.append(_Edge(int(s), int(d), g, t,
                         rnd.payload is not None, price, orig))
    return out


class _Bucket:
    """One output round under construction: matching + dataflow state."""

    def __init__(self, reduce: bool):
        self.reduce = reduce
        self.edges: list[_Edge] = []
        self.srcs: set[int] = set()
        self.dsts: set[int] = set()
        self.reads: dict[int, set] = {}    # rank -> rows gathered
        self.writes: dict[int, set] = {}   # rank -> rows scattered

    def add(self, e: _Edge) -> None:
        self.edges.append(e)
        self.srcs.add(e.src)
        self.dsts.add(e.dst)
        self.reads.setdefault(e.src, set()).update(e.reads)
        self.writes.setdefault(e.dst, set()).update(e.writes)

    def remove(self, e: _Edge) -> None:
        """Roll back a tentative placement.  Exact because the matching
        invariant makes e the only edge with src ``e.src`` (sole
        contributor to ``reads[e.src]``) and dst ``e.dst`` (sole
        contributor to ``writes[e.dst]``) in this bucket."""
        self.edges.remove(e)
        self.srcs.discard(e.src)
        self.dsts.discard(e.dst)
        self.reads.pop(e.src, None)
        self.writes.pop(e.dst, None)


def _edge_lo(buckets: list[_Bucket], barrier: int, base_i: int,
             e: _Edge) -> int:
    """Earliest bucket in ``[0, base_i)`` that edge ``e`` may legally
    join — the per-edge hazard lower bound both compaction passes share
    (their union over a round's edges is the src/dst interference DAG):

      * RAW / WAW — a bucket writing rows ``e`` gathers, or rows ``e``
        scatters (``e``'s writes must still land last), forces strictly
        later placement;
      * WAR — a bucket gathering rows ``e`` scatters allows same-round
        placement (fused rounds gather before they scatter);
      * ``barrier`` — nothing crosses the latest reduce round.
    """
    lo = barrier
    for bi in range(base_i):
        b = buckets[bi]
        if (b.writes.get(e.src, _EMPTY) & e.reads
                or b.writes.get(e.dst, _EMPTY) & e.writes):
            lo = max(lo, bi + 1)          # RAW / WAW
        elif b.reads.get(e.dst, _EMPTY) & e.writes:
            lo = max(lo, bi)              # WAR (same-round ok)
    return lo


def _compact(rounds: tuple[CommRound, ...], compress: bool
             ) -> tuple[list[_Bucket], int]:
    """Fuse whole rounds into earlier ones (the fusion pass).

    Each non-reduce round merges — whole, into ONE earlier round —
    when the ``can_fuse`` legality rule holds against that target and
    no intermediate round creates a data hazard.  Whole-round
    single-target merging is the shape that is *provably cost-safe*
    without a topology: src/dst sets stay disjoint, so the merged
    round's per-port costs are the union of the two rounds' and its
    max-priced time is ``max(a, b) <= a + b`` — one alpha strictly
    saved, no beta added.  (Per-edge redistribution was measurably
    harmful: splitting edges that overlapped in one round across
    several can raise several rounds' maxima; an early draft did this
    and regressed real neighbor plans by >25% modeled time.)  Equal
    message width is also required — merging a k=1 round into a k=4
    round would pad the k=1 messages to 4 slots on the wire.

    Legality of merging round j into candidate c (``schedule.can_fuse``
    plus the non-adjacency condition):
      * neither round reduces; reduce rounds are barriers (float
        accumulation order is preserved bit-for-bit);
      * matching — no rank may send or receive in both rounds;
      * RAW/WAW — no round in [c, j) writes rows that j's edges gather,
        and no round in [c, j) writes rows that j's edges scatter
        (j's writes must still land last);
      * WAR — rounds in (c, j) must not gather rows j's edges scatter
        (round c itself may: fused rounds gather before scattering);
      * equal padded width k.
    Returns (buckets, count of edges in fused rounds).
    """
    buckets: list[_Bucket] = []
    barrier = 0
    migrated = 0
    for orig, rnd in enumerate(rounds):
        edges = _round_edges(rnd, compress, orig)
        base = _Bucket(rnd.reduce)
        buckets.append(base)
        for e in edges:
            base.add(e)
        if rnd.reduce:
            barrier = len(buckets)
            continue
        if not edges:
            continue
        base_i = len(buckets) - 1
        # hazard lower bound: the earliest round this whole round may
        # merge into without reordering a read/write pair
        lo = max(_edge_lo(buckets, barrier, base_i, e) for e in edges)
        width = max(len(e.gather) for e in edges)
        for bi in range(lo, base_i):
            b = buckets[bi]
            if b.reduce or not b.edges:
                continue
            if max(len(e.gather) for e in b.edges) != width:
                continue
            if any(e.src in b.srcs or e.dst in b.dsts for e in edges):
                continue
            for e in edges:                        # commit the merge
                base.remove(e)
                b.add(e)
            migrated += len(edges)
            break
    return [b for b in buckets if b.edges], migrated


_EMPTY: frozenset = frozenset()


# ---------------------------------------------------------------------------
# topology-armed compaction (multi-target fusion + antichain packing)
# ---------------------------------------------------------------------------


_REF_SLOT_BYTES = 1024.0     # nominal slot size for greedy *ordering* only
                             # (acceptance tests below are size-independent)


def _edge_link(topo: Topology, e: _Edge):
    """Link model of the edge's wire hop; None for free on-chip copies."""
    return None if e.src == e.dst else topo.link(e.src, e.dst)


def _edge_nominal_time(topo: Topology, e: _Edge) -> float:
    lm = _edge_link(topo, e)
    return 0.0 if lm is None else lm.time(e.price_slots * _REF_SLOT_BYTES)


def _has_dominator(topo: Topology, bucket: _Bucket, e: _Edge) -> bool:
    """True when some edge already in ``bucket`` upper-bounds ``e``'s
    link time at EVERY slot size: alpha_f >= alpha_e and
    slots_f*beta_f >= slots_e*beta_e.  Then max-pricing cannot move, so
    landing ``e`` there is free regardless of message size."""
    lm_e = _edge_link(topo, e)
    if lm_e is None:
        return True                      # on-chip copy: costs nothing
    load_e = e.price_slots * lm_e.beta
    for f in bucket.edges:
        lm_f = _edge_link(topo, f)
        if lm_f is None:
            continue
        if lm_f.alpha >= lm_e.alpha and f.price_slots * lm_f.beta >= load_e:
            return True
    return False


def _intra_round_hazard(edges: list[_Edge]) -> bool:
    """True when one edge of a round scatters rows another edge of the
    SAME round gathers (on one rank).  In-round semantics read pre-round
    state, so such edges may only ever execute concurrently — splitting
    them across different rounds would reorder the write before the
    read.  Rounds with this shape are merge-whole-or-stay."""
    for e1 in edges:
        for e2 in edges:
            if e1 is not e2 and e1.dst == e2.src and e1.writes & e2.reads:
                return True
    return False


def _bucket_orig_lo(bucket: _Bucket) -> int:
    """Earliest original-round index whose content this bucket holds
    (min composes through stacked passes: pass 2 consumes pass 1's
    rebuilt rounds with their per-round ``orig_lo`` fed back in)."""
    return min((e.orig for e in bucket.edges), default=0)


def _compact_armed(rounds: tuple[CommRound, ...], topo: Topology,
                   compress: bool, origs: tuple[int, ...] | None = None
                   ) -> tuple[list[_Bucket], int, int]:
    """Cost-model-armed compaction (run AFTER the topology-free pass).

    The per-edge hazard lower bounds below are exactly the src/dst
    interference DAG of ``can_fuse``-style legality (reduce rounds are
    barriers; RAW/WAW force strictly-later placement; WAR allows
    same-round placement because fused rounds gather before they
    scatter).  Rounds are processed in order and greedily packed into
    the earliest legal antichain — an existing concurrent round priced
    by the max over its links — via two moves, each *pointwise*
    cost-safe (no slower at ANY slot size, not merely at a probe size;
    this is what makes running the armed pass on top of the topology-
    free pass provably never worse than that pass):

      * **whole-round merge** (subsumes the equal-width single-target
        rule): all edges of round j land in one earlier bucket c.
        Legality makes src/dst sets disjoint, and every rank sends at
        most once per round, so each (src, level) injection port
        carries exactly one message — ports of c and j never collide
        and the merged round's time is max(c, j) <= c + j for every
        slot size.  Unequal widths are priced exactly by carrying each
        edge's original width through ``payload`` (see _rebuild_round).
      * **all-or-nothing multi-target split**: every edge of round j
        migrates to SOME earlier bucket; at most one receiving bucket
        (the primary) may raise its max — its increase is bounded by
        round j's own time — and every other receiving bucket must
        already hold a dominating edge (``_has_dominator``), leaving
        its max untouched at every size.  Deleting round j then pays
        for the primary's bounded increase: total time never rises.
        Partial migrations are rolled back whole (the PR 4 lesson:
        redistributing edges without deleting a round only inflates
        other rounds' maxima).

    Returns (buckets, whole-round merges, edges moved by splits).
    """
    buckets: list[_Bucket] = []
    barrier = 0
    merged_rounds = 0
    split_edges = 0
    for i, rnd in enumerate(rounds):
        edges = _round_edges(rnd, compress,
                             i if origs is None else origs[i])
        base = _Bucket(rnd.reduce)
        buckets.append(base)
        for e in edges:
            base.add(e)
        if rnd.reduce:
            barrier = len(buckets)
            continue
        if not edges:
            continue
        base_i = len(buckets) - 1
        # -- move 1: whole-round merge, any widths ----------------------
        lo_all = max(_edge_lo(buckets, barrier, base_i, e) for e in edges)
        merged = False
        for bi in range(lo_all, base_i):
            b = buckets[bi]
            if b.reduce or not b.edges:
                continue
            if any(e.src in b.srcs or e.dst in b.dsts for e in edges):
                continue
            for e in edges:
                base.remove(e)
                b.add(e)
            merged_rounds += 1
            merged = True
            break
        if merged:
            continue
        # -- move 2: all-or-nothing multi-target split ------------------
        if len(edges) < 2 or _intra_round_hazard(edges):
            continue
        placed: list[tuple[_Edge, _Bucket]] = []
        primary: _Bucket | None = None
        ok = True
        # heaviest edges first: the critical edge claims the primary
        # slot, lighter edges then only need dominated (free) homes
        for e in sorted(edges, key=lambda e: -_edge_nominal_time(topo, e)):
            # recomputed per edge: siblings already placed count
            lo = _edge_lo(buckets, barrier, base_i, e)
            home = None
            fallback = None
            for bi in range(lo, base_i):
                b = buckets[bi]
                if b.reduce or not b.edges:
                    continue
                if e.src in b.srcs or e.dst in b.dsts:
                    continue
                if b is primary or _has_dominator(topo, b, e):
                    home = b
                    break
                if fallback is None:
                    fallback = b
            if home is None and primary is None and fallback is not None:
                home = primary = fallback
            if home is None:
                ok = False
                break
            base.remove(e)
            home.add(e)
            placed.append((e, home))
        if ok:
            split_edges += len(placed)
        else:                              # roll the whole round back
            for e, b in placed:
                b.remove(e)
                base.add(e)
    return [b for b in buckets if b.edges], merged_rounds, split_edges


def _rebuild_round(bucket: _Bucket, nranks: int, *,
                   priced: bool = False) -> CommRound:
    """Materialize a bucket as a CommRound.

    With ``priced=True`` (the topology-armed pass) a round whose edges
    carry unequal priced widths gets a ``payload`` so ``modeled_time``
    keeps charging every edge its pre-merge width — unequal-width
    merges must not let padding reprice (or silently discount) edges.
    """
    k = max((len(e.gather) for e in bucket.edges), default=0)
    k = max(k, 1)
    gi = np.full((nranks, k), -1, np.int64)
    si = np.full((nranks, k), -1, np.int64)
    perm = []
    payload = None
    if any(e.has_payload for e in bucket.edges) or (
            priced and any(e.price_slots != k for e in bucket.edges)):
        payload = np.zeros(nranks, np.int64)
    for e in bucket.edges:
        perm.append((e.src, e.dst))
        gi[e.src, : len(e.gather)] = e.gather
        si[e.dst, : len(e.scatter)] = e.scatter
        if payload is not None:
            # priced (armed) rebuilds carry each edge's pre-merge width
            # verbatim; the historical rebuild recomputes the live
            # count but clamps by the original priced width — a fuzzed
            # round whose payload undercuts its live gather count must
            # not get silently repriced upward
            payload[e.src] = (e.price_slots if priced
                              else min(e.price_slots,
                                       int((e.gather >= 0).sum())))
    return CommRound(perm=tuple(perm), gather_idx=gi, scatter_idx=si,
                     reduce=bucket.reduce, payload=payload)


# ---------------------------------------------------------------------------
# makespan model + pipelined pass (pass 3; pricing/planning only)
# ---------------------------------------------------------------------------


_PIPELINE_PROBE_BYTES = (1.0, 4096.0, float(1 << 20))
# alpha-, mixed-, beta-dominated probe sizes for the tail-split rollback
# check (same values the conformance fuzzer probes); the packing moves
# themselves are size-independent, only the split needs the probes as
# defense in depth on top of the per-port alpha precondition.


def _round_level_times(topo: Topology, rnd: CommRound,
                       slot_nbytes: float) -> dict[int, float]:
    """Per-topology-level occupancy of one round: the same per-(src,
    level) injection-port accounting as ``Topology.round_time`` but
    grouped by level instead of collapsed to one max — the channels of
    the makespan model.  ``max(out.values())`` equals ``round_time``
    exactly, so singleton groups reproduce the serial model."""
    if rnd.payload is None:
        per_edge = [float(rnd.k) * slot_nbytes] * len(rnd.perm)
    else:
        per_edge = [rnd.edge_slots(s) * slot_nbytes for s, _ in rnd.perm]
    per_port: dict[tuple[int, int], tuple[int, float]] = {}
    for (s, d), b in zip(rnd.perm, per_edge):
        if s == d:
            continue
        key = (s, topo.link_level(s, d))
        n, tot = per_port.get(key, (0, 0.0))
        per_port[key] = (n + 1, tot + b)
    out: dict[int, float] = {}
    for (s, lvl), (n, tot) in per_port.items():
        t = topo.levels[lvl].link.time(tot, nmsgs=n)
        if t > out.get(lvl, 0.0):
            out[lvl] = t
    return out


def _round_chans(topo: Topology, rnd: CommRound) -> frozenset[int]:
    """Topology levels (channels) a round occupies — size-independent."""
    return frozenset(topo.link_level(s, d)
                     for s, d in rnd.perm if s != d)


def _rounds_commute(a: CommRound, b: CommRound) -> bool:
    """True when executing a and b in either order (or concurrently)
    is bit-identical: neither reduces and no rank sees a RAW, WAR, or
    WAW pair between them.  The makespan packer may co-schedule only
    commuting rounds (events never constrain rounds: they are pure
    readers of a buffer snapshot)."""
    if a.reduce or b.reduce:
        return False
    for r in (a.src_set | a.dst_set) & (b.src_set | b.dst_set):
        if a.writes(r) & (b.reads(r) | b.writes(r)):
            return False
        if a.reads(r) & b.writes(r):
            return False
    return True


# a _pack item is ("r", CommRound) or ("e", seconds, dep_item_index);
# an event's dep is the item index of the round it waits on (-1 = none).


def _pack(items: list[tuple], topo: Topology) -> list[list[tuple]]:
    """Greedy makespan packing: assign items, in order, to concurrency
    groups.  A group runs its members concurrently across channels
    (topology levels + one compute channel) and groups serialize, so

        makespan = sum over groups of
                   max(sum of member event seconds,
                       max over levels of sum of member round times).

    Every placement is *pointwise* cost-safe by construction: a group's
    duration is ``max_c sum d_(j,c) <= sum_j max_c d_(j,c)``, so any
    legal packing's makespan is <= the serial sum (armed modeled_time +
    total event seconds) at every slot size.  Placement rules:

      * a round lands in the earliest group after every round it does
        not commute with (and after the latest reduce barrier), and
        only joins a group whose rounds occupy disjoint channels — the
        DCN/ICI interleave; channel overlap would serialize inside the
        group's sum and hide real occupancy, so it opens a new group;
      * a reduce round is a barrier: its own group, nothing crosses;
      * an event lands in the earliest group strictly after its dep
        round's group (events on one consumer core serialize by
        summing inside a group — co-resident rounds still overlap).
    """
    groups: list[list[tuple]] = []
    chans: list[set[int]] = []          # per group: levels occupied
    has_reduce: list[bool] = []
    group_of: dict[int, int] = {}
    barrier = 0
    for j, it in enumerate(items):
        if it[0] == "r":
            rnd = it[1]
            lo = barrier
            for i in range(j):
                if (items[i][0] == "r"
                        and not _rounds_commute(items[i][1], rnd)):
                    lo = max(lo, group_of[i] + 1)
            if rnd.reduce:
                group_of[j] = len(groups)
                groups.append([it])
                chans.append(set(_round_chans(topo, rnd)))
                has_reduce.append(True)
                barrier = len(groups)
                continue
            rc = _round_chans(topo, rnd)
            g = None
            for gi in range(lo, len(groups)):
                if not has_reduce[gi] and not (chans[gi] & rc):
                    g = gi
                    break
            if g is None:
                g = len(groups)
                groups.append([])
                chans.append(set())
                has_reduce.append(False)
            groups[g].append(it)
            chans[g] |= rc
            group_of[j] = g
        else:
            dep = it[2]
            lo = barrier
            if dep >= 0:
                lo = max(lo, group_of[dep] + 1)
            if lo >= len(groups):
                groups.append([])
                chans.append(set())
                has_reduce.append(False)
            group_of[j] = lo
            groups[lo].append(it)
    return groups


def _groups_makespan(groups: list[list[tuple]], topo: Topology,
                     slot_nbytes: float) -> float:
    total = 0.0
    for grp in groups:
        per_lvl: dict[int, float] = {}
        ev_s = 0.0
        for it in grp:
            if it[0] == "r":
                for lvl, t in _round_level_times(topo, it[1],
                                                 slot_nbytes).items():
                    per_lvl[lvl] = per_lvl.get(lvl, 0.0) + t
            else:
                ev_s += it[1]
        total += max([ev_s] + list(per_lvl.values()))
    return total


# ---------------------------------------------------------------------------
# local_pre fold
# ---------------------------------------------------------------------------


def _bijective_rows(table: np.ndarray, num_slots: int) -> bool:
    if table.shape[1] != num_slots:
        return False
    want = np.arange(num_slots)
    return all(np.array_equal(np.sort(table[r]), want)
               for r in range(table.shape[0]))


def _fold_pre(schedule: CommSchedule):
    """Compose a bijective ``local_pre`` into every round table and the
    final ``local_post`` (relabel-through): logical slot ``i`` of the
    pre-permuted buffer lives at physical slot ``pre[r, i]``, so every
    index is rewritten through ``pre`` and the pre-gather disappears.
    Returns (rounds, local_post, folded?)."""
    pre = schedule.local_pre
    if pre is None or not _bijective_rows(np.asarray(pre),
                                          schedule.num_slots):
        return schedule.rounds, schedule.local_post, False
    pre = np.asarray(pre, np.int64)
    rounds = []
    for rnd in schedule.rounds:
        gi = rnd.gather_idx.copy().astype(np.int64)
        si = rnd.scatter_idx.copy().astype(np.int64)
        for r in range(schedule.nranks):
            gmask = gi[r] >= 0
            gi[r, gmask] = pre[r, gi[r, gmask]]
            smask = si[r] >= 0
            si[r, smask] = pre[r, si[r, smask]]
        rounds.append(CommRound(perm=rnd.perm, gather_idx=gi,
                                scatter_idx=si, reduce=rnd.reduce,
                                payload=rnd.payload))
    if schedule.local_post is None:
        post = pre
    else:
        old = np.asarray(schedule.local_post, np.int64)
        post = np.stack([pre[r, old[r]]
                         for r in range(schedule.nranks)])
    return tuple(rounds), post, True


# ---------------------------------------------------------------------------
# the compiled executor
# ---------------------------------------------------------------------------


class _ExecRound:
    """One compiled round: full per-rank tables (shard_map) plus dense
    per-edge tables (vectorized simulator), baked once."""

    def __init__(self, rnd: CommRound, num_slots: int):
        self.perm = rnd.perm
        self.reduce = rnd.reduce
        self.k = rnd.k
        self.num_slots = num_slots
        self.gather_idx = np.asarray(rnd.gather_idx, np.int32)
        self.scatter_idx = np.asarray(rnd.scatter_idx, np.int32)
        # vectorized-sim tables: one fancy-indexed gather/permute/scatter
        # per round; -1 entries are routed via the scratch row num_slots.
        self.src = np.asarray([s for s, _ in rnd.perm], np.int64)
        self.dst = np.asarray([d for _, d in rnd.perm], np.int64)
        g = self.gather_idx[self.src].astype(np.int64)      # [m, k]
        t = self.scatter_idx[self.dst].astype(np.int64)
        self.g_mask = g >= 0
        self.t_mask = t >= 0
        self.g_safe = np.where(self.g_mask, g, num_slots)
        self.t_safe = np.where(self.t_mask, t, num_slots)
        # duplicate live targets on one rank (only possible with schedule
        # validation off) force unbuffered accumulation for reduce rounds
        self.dup_targets = rnd.reduce and any(
            len(np.unique(row[m])) != int(m.sum())
            for row, m in zip(t, self.t_mask))
        self._jnp = None

    def jnp_tables(self):
        """Device-resident gather/scatter tables AND their ``jnp.where``
        masks, materialized once and reused by every subsequent trace
        (persistent-collective style).  The scratch-safe indices
        (``-1 -> num_slots``) and the validity masks are precomputed
        here as device constants instead of being rebuilt from
        ``table >= 0`` comparisons inside every lowering.
        ``ensure_compile_time_eval`` makes them concrete arrays even
        when first touched from inside a jit/shard_map trace — caching
        a tracer would leak it into later traces.
        Returns (gather_safe, gather_mask, scatter_safe, scatter_mask).
        """
        if self._jnp is None:
            import jax
            nb = self.num_slots
            with jax.ensure_compile_time_eval():
                self._jnp = (
                    jnp.asarray(np.where(self.gather_idx >= 0,
                                         self.gather_idx, nb), np.int32),
                    jnp.asarray(self.gather_idx >= 0),
                    jnp.asarray(np.where(self.scatter_idx >= 0,
                                         self.scatter_idx, nb), np.int32),
                    jnp.asarray(self.scatter_idx >= 0),
                )
        return self._jnp


class CompiledExec:
    """A ``CommSchedule`` lowered for repeated execution.

    With a ``topo`` the compile pass is *armed* with the alpha-beta
    cost model: after the topology-free fusion, ``_compact_armed``
    multi-target-fuses and reorders the surviving rounds (each move
    pointwise cost-safe, so the armed result is never slower than the
    topology-free pass at any message size — the topology-free result
    is the armed pass's input and its fallback: when no armed move
    applies, the rounds pass through bit-identical).

    ``run_sim`` / ``run_shardmap`` are the two backends' steady-state
    entry points; both execute the *same* compiled rounds, so the
    bit-exactness contract between the transports is preserved by
    construction.  Counters: ``trace_count`` (shard_map lowerings —
    one per (shape, dtype, mesh) when the jit layer caches properly),
    ``sim_runs`` (simulator executions).
    """

    def __init__(self, schedule: CommSchedule, optimize: bool,
                 topo: Topology | None = None):
        self.schedule = schedule
        self.optimize = optimize
        self.topo = topo
        self.nranks = schedule.nranks
        self.num_slots = schedule.num_slots
        self.rounds_before = schedule.num_rounds
        self.trace_count = 0
        self.sim_runs = 0
        self.armed_merged_rounds = 0
        self.armed_split_edges = 0
        if optimize:
            rounds, post, self.pre_folded = _fold_pre(schedule)
            folded = CommSchedule(
                nranks=schedule.nranks, num_slots=schedule.num_slots,
                rounds=rounds, name=schedule.name,
                slot_bytes=schedule.slot_bytes,
                local_pre=None if self.pre_folded else schedule.local_pre,
                local_post=post, out_slots=schedule.out_slots,
                out_offsets=schedule.out_offsets)
            buckets, self.migrated_edges = _compact(folded.rounds,
                                                    compress=True)
            compiled_rounds = tuple(_rebuild_round(b, self.nranks)
                                    for b in buckets)
            self.rounds_after_unarmed = len(compiled_rounds)
            origs = tuple(_bucket_orig_lo(b) for b in buckets)
            if topo is not None:
                # armed pass runs ON the topology-free output, so every
                # pointwise-safe move keeps it <= that pass, which is
                # itself <= the unoptimized schedule
                (abuckets, self.armed_merged_rounds,
                 self.armed_split_edges) = _compact_armed(
                     compiled_rounds, topo, compress=True, origs=origs)
                compiled_rounds = tuple(
                    _rebuild_round(b, self.nranks, priced=True)
                    for b in abuckets)
                origs = tuple(_bucket_orig_lo(b) for b in abuckets)
            self._origs = origs
            self.local_pre = folded.local_pre
            self.local_post = post
        else:
            self.pre_folded = False
            self.migrated_edges = 0
            compiled_rounds = schedule.rounds
            self.rounds_after_unarmed = len(compiled_rounds)
            self._origs = tuple(range(len(compiled_rounds)))
            self.local_pre = schedule.local_pre
            self.local_post = schedule.local_post
        self.compiled_schedule = CommSchedule(
            nranks=schedule.nranks, num_slots=schedule.num_slots,
            rounds=compiled_rounds,
            name=schedule.name + ("+fused" if optimize else "+compiled"),
            slot_bytes=schedule.slot_bytes, local_pre=self.local_pre,
            local_post=self.local_post, out_slots=schedule.out_slots,
            out_offsets=schedule.out_offsets)
        self.rounds_after = len(compiled_rounds)
        self._rounds = tuple(_ExecRound(r, self.num_slots)
                             for r in compiled_rounds)
        # pass 3: makespan planning (pricing only; never touches the
        # executed rounds, so every modeled_time/bit-exactness contract
        # above is untouched by construction)
        self._groups: list[list[tuple]] | None = None
        self.pipelined_schedule: CommSchedule | None = None
        self.pipeline_tail_parts = 0
        if optimize and topo is not None:
            self._build_pipeline(compiled_rounds)
        self._pre = (None if self.local_pre is None
                     else np.asarray(self.local_pre, np.int64))
        self._post = (None if self.local_post is None
                      else np.asarray(self.local_post, np.int64))
        self._jnp_pre = None
        self._jnp_post = None

    # -- pass 3: makespan planning + tail-chunk pipelining ----------------
    def _event_deps(self, nrounds: int) -> list[int]:
        """Resolve each ComputeEvent's ``after_round`` anchor (an index
        into the ORIGINAL schedule) onto the compiled rounds: the event
        depends on the LAST compiled round holding content from original
        rounds <= anchor.  Compaction only moves edges earlier and
        buckets carry ``min(orig)``, so ``origs[f] <= anchor`` holds
        exactly for the compiled prefix the anchor's data lives in."""
        deps = []
        for ev in self.schedule.compute_events:
            a = (ev.after_round if ev.after_round >= 0
                 else self.rounds_before - 1)
            dep = -1
            for f in range(nrounds):
                if self._origs[f] <= a:
                    dep = f
            deps.append(dep)
        return deps

    def _build_pipeline(self, compiled_rounds: tuple[CommRound, ...]):
        """The pipelined pass: pack the armed rounds + registered
        compute events into a makespan plan, then try ONE structural
        move — split the tail round into chunks so slices of a
        splittable tail event overlap chunk transfers (the MPIPCL
        partitioned-communication shape).  The split commits only when
        (a) ``can_split`` legality holds, (b) every injection port's
        alpha is <= the per-slice compute (the size-independent
        pointwise-safety precondition: extra alphas hide behind
        compute), and (c) the packed makespan is no worse at every
        probe size — whole-move rollback otherwise (the PR 4 lesson)."""
        events = self.schedule.compute_events
        topo = self.topo
        R = len(compiled_rounds)
        deps = self._event_deps(R)
        base_items: list[tuple] = [("r", r) for r in compiled_rounds]
        for ev, dep in zip(events, deps):
            base_items.append(("e", float(ev.seconds), dep))
        groups = _pack(base_items, topo)
        self._groups = groups
        if R == 0:
            return
        # tail-split candidate: first splittable event anchored on the
        # final compiled round with real compute behind it
        cand = next((i for i, (ev, dep) in enumerate(zip(events, deps))
                     if ev.splittable and dep == R - 1
                     and ev.seconds > 0.0), None)
        if cand is None:
            return
        ev = events[cand]
        tail = compiled_rounds[-1]
        pref = [ev.parts] if ev.parts >= 2 else []
        parts = None
        for p in pref + [8, 4, 2]:
            if not can_split(tail, p):
                continue
            slice_s = ev.seconds / p
            ports = {(s, topo.link_level(s, d))
                     for s, d in tail.perm if s != d}
            if all(topo.levels[lvl].link.alpha <= slice_s
                   for _, lvl in ports):
                parts = p
                break
        if parts is None:
            return
        chunks = split_round(tail, parts)
        split_items: list[tuple] = [("r", r)
                                    for r in compiled_rounds[:-1]]
        c0 = len(split_items)
        split_items.extend(("r", c) for c in chunks)
        for i, (e2, dep) in enumerate(zip(events, deps)):
            if i == cand:
                split_items.extend(
                    ("e", e2.seconds / parts, c0 + ci)
                    for ci in range(parts))
            else:
                d2 = dep if dep < R - 1 else c0 + parts - 1
                split_items.append(("e", float(e2.seconds), d2))
        sgroups = _pack(split_items, topo)
        for s in _PIPELINE_PROBE_BYTES:
            if (_groups_makespan(sgroups, topo, s)
                    > _groups_makespan(groups, topo, s) * (1 + 1e-9)):
                return                     # whole-move rollback
        self._groups = sgroups
        self.pipeline_tail_parts = parts
        # execution artifact: chunks run sequentially, which is
        # bit-identical to the unsplit round (can_split forbids
        # chunk-crossing RAW; live scatter targets are distinct, so
        # chunk writes are disjoint).  Events are model-only and their
        # anchors index the original rounds, so they are dropped here.
        self.pipelined_schedule = CommSchedule(
            nranks=self.nranks, num_slots=self.num_slots,
            rounds=compiled_rounds[:-1] + chunks,
            name=self.schedule.name + "+pipelined",
            slot_bytes=self.schedule.slot_bytes,
            local_pre=self.local_pre, local_post=self.local_post,
            out_slots=self.schedule.out_slots,
            out_offsets=self.schedule.out_offsets)

    def makespan(self, slot_nbytes: float) -> float:
        """Modeled completion time of the packed plan (pass 3): groups
        serialize, members of a group overlap across channels (topology
        levels + the consumer-compute channel).  Pointwise <= the armed
        serial ``modeled_time`` plus total registered event seconds, at
        every slot size — the pipelined arm of the guideline chain."""
        if self._groups is None:
            raise RuntimeError(
                "makespan requires a topology-armed optimized executor "
                "(compile with optimize=True and a topo)")
        return _groups_makespan(self._groups, self.topo, slot_nbytes)

    def chunked_makespan(self, slot_nbytes: float, parts: int,
                         compute_s: float) -> float:
        """Software-pipeline model of ROW-chunked execution — the shape
        ``transport.run_chunked`` + a ``consume`` callback lowers to
        (MPIPCL partitioned communication over the row axis): the whole
        compiled schedule runs once per chunk at ``1/parts`` of the
        bytes, and chunk ``i``'s transfer overlaps chunk ``i-1``'s
        consumer compute.  Complements ``makespan`` (slot-granularity
        tail splitting): row chunking applies to ANY schedule, including
        k=1 rounds the IR-level ``split_round`` must refuse.  Callers
        (the tuner) must compare against ``parts=1`` and keep the min —
        per-chunk alphas are not free and small messages lose."""
        if self._groups is None:
            raise RuntimeError(
                "chunked_makespan requires a topology-armed optimized "
                "executor (compile with optimize=True and a topo)")
        serial = self.compiled_schedule.modeled_time(self.topo,
                                                     slot_nbytes)
        if parts <= 1:
            return serial + compute_s
        c = self.compiled_schedule.modeled_time(
            self.topo, slot_nbytes / float(parts))
        e = compute_s / float(parts)
        return c + (parts - 1) * max(c, e) + e

    # -- numpy backend (vectorized; no per-rank/per-slot Python loops) ----
    def run_sim(self, buf: np.ndarray) -> np.ndarray:
        self.sim_runs += 1
        n = self.nranks
        assert buf.shape[0] == n and buf.shape[1] == self.num_slots, (
            buf.shape, n, self.num_slots)
        rows = np.arange(n)[:, None]
        if self._pre is not None:
            buf = buf[rows, self._pre]
        # one scratch row per rank absorbs -1 routes (same trick as the
        # shard_map lowering, so the two backends share index tables)
        work = np.concatenate(
            [buf, np.zeros((n, 1) + buf.shape[2:], buf.dtype)], axis=1)
        # masking is done with in-place boolean assignment, NOT np.where:
        # np.where(mask, mldtypes_array, python_scalar) corrupts the heap
        # on numpy 2.0.x + ml_dtypes (bfloat16 buffers)
        for rnd in self._rounds:
            payload = work[rnd.src[:, None], rnd.g_safe]     # [m, k, ...]
            payload[~rnd.g_mask] = 0
            if rnd.reduce:
                # live targets are distinct per dst (schedule invariant),
                # so buffered fancy-index accumulation is exact; -1 slots
                # collapse onto the scratch row, which is never read
                payload[~rnd.t_mask] = 0
                idx = (rnd.dst[:, None], rnd.t_safe)
                if rnd.dup_targets:
                    np.add.at(work, idx, payload)
                else:
                    work[idx] = work[idx] + payload
            else:
                work[rnd.dst[:, None], rnd.t_safe] = payload
        out = work[:, : self.num_slots]
        if self._post is not None:
            out = out[rows, self._post]
        return np.ascontiguousarray(out)

    # -- shard_map backend (called inside an ambient shard_map trace) -----
    def run_shardmap(self, buf, rank, axis_arg):
        import jax

        self.trace_count += 1
        nb = self.num_slots
        if self._pre is not None:
            if self._jnp_pre is None:
                with jax.ensure_compile_time_eval():
                    self._jnp_pre = jnp.asarray(self._pre, jnp.int32)
            buf = buf[self._jnp_pre[rank]]
        scratch = jnp.zeros((1,) + buf.shape[1:], buf.dtype)
        x = jnp.concatenate([buf, scratch], axis=0)
        for rnd in self._rounds:
            x = self._shardmap_round(rnd, x, rank, axis_arg, nb)
        out = x[:nb]
        if self._post is not None:
            if self._jnp_post is None:
                with jax.ensure_compile_time_eval():
                    self._jnp_post = jnp.asarray(self._post, jnp.int32)
            out = out[self._jnp_post[rank]]
        return out

    def _shardmap_round(self, rnd: _ExecRound, x, rank, axis_arg, nb):
        import jax

        kdims = (rnd.k,) + (1,) * (x.ndim - 1)
        # safe indices and where-masks are baked device constants
        # (jnp_tables): no per-trace `>= 0` comparisons or -1 clamping
        g_safe, g_mask, t_safe, t_mask = rnd.jnp_tables()
        # Gather payload; -1 slots read the scratch row and are zeroed.
        payload = x[g_safe[rank]]
        payload = jnp.where(g_mask[rank].reshape(kdims), payload, 0)
        recvd = jax.lax.ppermute(payload, axis_arg, list(rnd.perm))
        # Scatter: -1 slots land on the scratch row (index nb).
        if rnd.reduce:
            masked = jnp.where(t_mask[rank].reshape(kdims), recvd, 0)
            x = x.at[t_safe[rank]].add(masked)
        else:
            # distinct targets per slot by construction (schedule invariant)
            x = x.at[t_safe[rank]].set(recvd)
            if not self.optimize:
                # historical lowering re-zeroed the scratch row; the
                # compiled path elides it (every scratch read is masked)
                x = x.at[nb].set(0)
        return x

    # -- reporting --------------------------------------------------------
    def stats(self) -> dict:
        return {
            "name": self.schedule.name,
            "fingerprint": self.schedule.fingerprint(),
            "optimize": self.optimize,
            "topology": (None if self.topo is None
                         else self.topo.fingerprint()),
            "rounds_before": self.rounds_before,
            "rounds_after_unarmed": self.rounds_after_unarmed,
            "rounds_after": self.rounds_after,
            "migrated_edges": self.migrated_edges,
            "armed_merged_rounds": self.armed_merged_rounds,
            "armed_split_edges": self.armed_split_edges,
            "pipeline_groups": (None if self._groups is None
                                else len(self._groups)),
            "pipeline_packed_rounds": (
                None if self._groups is None
                else sum(1 for g in self._groups for it in g
                         if it[0] == "r")),
            "pipeline_tail_parts": self.pipeline_tail_parts,
            "pre_folded": self.pre_folded,
            "trace_count": self.trace_count,
            "sim_runs": self.sim_runs,
        }


# ---------------------------------------------------------------------------
# process-level executor cache (the "persistent" in persistent executor)
# ---------------------------------------------------------------------------


_CACHE: dict[tuple, CompiledExec] = {}
_HITS = {"hits": 0, "misses": 0}


def compile_schedule(schedule: CommSchedule, *,
                     optimize: bool | None = None,
                     topo: Topology | None = None) -> CompiledExec:
    """Lower ``schedule`` to a fresh ``CompiledExec`` (uncached entry;
    use ``get_executor`` for the shared process-level cache).  With a
    ``topo`` the optimization pass is armed with its alpha-beta cost
    model (multi-target fusion + round reordering); without one, only
    the topology-free single-target whole-round rule runs."""
    if optimize is None:
        optimize = optimize_enabled()
    return CompiledExec(schedule, bool(optimize), topo)


def get_executor(schedule: CommSchedule, *,
                 optimize: bool | None = None,
                 topo: Topology | None = None) -> CompiledExec:
    """The persistent-init entry: compile once per (schedule content,
    optimize flag, validation flag, topology geometry), then reuse
    forever.

    Keyed by ``CommSchedule.fingerprint()`` — two independently built
    schedules with identical tables share one executor (and its baked
    device tables and jit traces).  ``REPRO_VALIDATE_SCHEDULES`` is part
    of the key because the compiled rounds are themselves CommRounds:
    flipping validation on must not hand back tables built unchecked.
    The topology's geometry-bearing ``fingerprint()`` joins the key so
    per-geometry armed compilations never collide — the same schedule
    compiled for two link geometries (or with no topology at all) gets
    distinct executors with identical numerics.
    """
    if optimize is None:
        optimize = optimize_enabled()
    key = (schedule.fingerprint(), bool(optimize),
           validate_schedules_enabled(),
           None if topo is None else topo.fingerprint())
    ex = _CACHE.get(key)
    if ex is not None:
        _HITS["hits"] += 1
        return ex
    _HITS["misses"] += 1
    ex = CompiledExec(schedule, bool(optimize), topo)
    _CACHE[key] = ex
    return ex


def clear_cache() -> None:
    """Drop every compiled executor (tests; after env-flag flips)."""
    _CACHE.clear()
    _HITS["hits"] = _HITS["misses"] = 0


def invalidate_topology(fingerprint: str | None) -> int:
    """Scoped eviction: drop only the executors armed with the given
    topology fingerprint, returning how many were dropped.

    This is the drift-healing counterpart of ``clear_cache``: when a
    probe pass moves a link model, only the geometry that changed is
    stale — executors armed with other geometries (and the topology-free
    ones, key slot ``None``) keep their baked tables and jit traces.
    Pass ``None`` to evict the topology-free entries instead.
    """
    doomed = [k for k in _CACHE if k[3] == fingerprint]
    for k in doomed:
        del _CACHE[k]
    return len(doomed)


def cache_stats() -> dict:
    """Aggregate cache + per-executor stats for telemetry/benchmarks."""
    return {
        "size": len(_CACHE),
        "hits": _HITS["hits"],
        "misses": _HITS["misses"],
        "executors": [ex.stats() for ex in _CACHE.values()],
    }
