"""Partitioned communication (paper §2.3, MPIPCL) — TPU adaptation.

MPIPCL channelizes a point-to-point message: one match at init, then the
buffer moves as P independently-committed *partitions*, letting transfer
of ready partitions overlap with production/consumption of the rest
("early-bird" communication).  MPIPCL inserts a progress thread because
MPI may not progress asynchronously; on TPU the compiler provides async
progress (collectives lower to start/done pairs), so the faithful
adaptation is *structural*: split the transfer into P chunks and
interleave chunk transfers with the producing/consuming compute inside
one program, giving XLA's scheduler the freedom the progress thread buys.

Three instantiations, mirroring how partitioned communication is used:

  * ``partitioned_ppermute``          — the raw primitive: chunked
    point-to-point with a per-partition consumer callback (receive-side
    early-bird: partitions are consumed as they arrive).
  * ``allgather_matmul``              — receive-side overlap in a
    collective: ring allgather where every arriving shard immediately
    feeds the MXU (x_aggregate @ w without waiting for the full gather).
  * ``matmul_reduce_scatter``         — send-side overlap ("early-bird
    send"): each output chunk is shipped as soon as it is computed,
    while the next chunk is being produced.
  * ``bucketed_psum``                 — gradient-sync form: a pytree is
    flattened into P buckets reduced independently, so XLA can overlap
    bucket k's all-reduce with the compute producing bucket k+1's grads
    (the classic DDP bucketing trick, expressed as partitioned comm).

All run inside ``shard_map``; all are differentiable (``ppermute``'s
transpose is the inverse permutation, so reverse-mode AD derives the
mirrored pipeline automatically).
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.schedule import CommSchedule, make_round
from repro.core.topology import Topology
from repro.core.transport import _flat_rank

from repro import compat


def _axes_tuple(axis_names):
    return (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)


def _shift_perm(n: int, shift: int = 1) -> list[tuple[int, int]]:
    return [(i, (i + shift) % n) for i in range(n)]


# ---------------------------------------------------------------------------
# partitioned transfers on the unified IR
# ---------------------------------------------------------------------------


def partitioned_schedule(nranks: int, perm: Sequence[tuple[int, int]],
                         partitions: int = 1) -> CommSchedule:
    """A partitioned point-to-point transfer as a ``CommSchedule``.

    The working buffer has ``2 * partitions`` slots per rank: rows
    ``[0, P)`` hold the outgoing chunks, rows ``[P, 2P)`` receive.
    Round ``i`` ships chunk ``i`` along ``perm`` — MPIPCL's P
    independently-committed partitions, expressed in the same IR the
    dense and neighborhood collectives compile to (so the tuner can
    time the partition-count tradeoff like any other schedule).
    """
    P = int(partitions)
    if P < 1:
        raise ValueError(
            f"partitioned_schedule: partitions must be >= 1, got "
            f"{partitions}")
    edges = tuple((int(s), int(d)) for s, d in perm)
    rounds = []
    for i in range(P):
        send = {s: [i] for s, _ in edges}
        recv = {d: [P + i] for _, d in edges}
        rounds.append(make_round(nranks, edges, send, recv))
    return CommSchedule(
        nranks=nranks, num_slots=2 * P, rounds=tuple(rounds),
        name=f"partitioned.shift[p{P}]", out_slots=P,
        out_offsets=np.full(nranks, P, np.int64))


def _chunked_shift(topo: Topology, partitions: int) -> CommSchedule:
    return partitioned_schedule(topo.nranks, _shift_perm(topo.nranks),
                                partitions)


ALGORITHMS = {
    f"p{p}": functools.partial(_chunked_shift, partitions=p)
    for p in (1, 2, 4, 8)
}


# ---------------------------------------------------------------------------
# raw partitioned point-to-point
# ---------------------------------------------------------------------------


def partitioned_ppermute(x: jax.Array, axis_name, perm,
                         partitions: int,
                         consume: Callable[[jax.Array, jax.Array], jax.Array]
                         | None = None,
                         init=None, via: str = "scan"):
    """Send ``x`` along ``perm`` in ``partitions`` chunks (leading dim).

    Without ``consume``: returns the fully received buffer — semantically
    identical to one monolithic ppermute (the 1-partition case *is* the
    monolithic transfer, the paper's "no worse than base pt2pt" claim).
    ``via="schedule"`` lowers this path through the unified
    ``CommSchedule`` IR + ``ShardMapTransport`` instead of a scan
    (identical result; lets the tuner time it like any collective).

    With ``consume(carry, chunk) -> carry``: receive-side early-bird —
    each arriving partition is folded into ``carry`` immediately; chunk
    i+1's transfer overlaps chunk i's consumption (XLA schedules the
    next ppermute-start before the consume of the previous done).
    """
    if partitions <= 0:
        raise ValueError(
            f"partitioned_ppermute: partitions must be >= 1, got "
            f"{partitions}")
    if x.shape[0] % partitions:
        raise ValueError(
            f"partitioned_ppermute: leading dim {x.shape[0]} of input "
            f"shape {tuple(x.shape)} must be divisible by "
            f"partitions={partitions}")
    chunk = x.shape[0] // partitions
    chunks = x.reshape((partitions, chunk) + x.shape[1:])

    if consume is None:
        if via == "schedule":
            from repro.core.transport import ShardMapTransport
            names = _axes_tuple(axis_name)
            n = 1
            for a in names:
                n *= compat.axis_size(a)
            sched = partitioned_schedule(n, perm, partitions)
            buf = jnp.concatenate([chunks, jnp.zeros_like(chunks)], axis=0)
            out = ShardMapTransport(n, names).run(sched, buf)
            return out[partitions:].reshape(x.shape)
        def body(_, c):
            return None, jax.lax.ppermute(c, axis_name, perm)
        _, out = jax.lax.scan(body, None, chunks)
        return out.reshape(x.shape)

    def body(carry, c):
        arrived = jax.lax.ppermute(c, axis_name, perm)
        return consume(carry, arrived), None

    carry, _ = jax.lax.scan(body, init, chunks)
    return carry


# ---------------------------------------------------------------------------
# receive-side overlap: allgather-matmul (collective matmul)
# ---------------------------------------------------------------------------


def allgather_matmul(x: jax.Array, w: jax.Array, axis_name, *,
                     partitions_per_rank: int = 1,
                     precision=None) -> jax.Array:
    """``all_gather(x) @ w`` as a ring pipeline: each ring step's arriving
    shard is matmul'd while the next shard is in flight.

    x: [m_local, k] (this rank's shard of the row dimension)
    w: [k, n] (replicated over ``axis_name``)
    returns [m_local * axis_size, n] — bitwise layout of the unfused op.
    """
    names = _axes_tuple(axis_name)
    n_ranks = 1
    for a in names:
        n_ranks *= compat.axis_size(a)
    axis_arg = names if len(names) > 1 else names[0]
    rank = _flat_rank(names)
    m_local = x.shape[0]
    out = jnp.zeros((n_ranks, m_local, w.shape[1]),
                    jnp.promote_types(x.dtype, w.dtype))
    # ring: at step t we hold the shard of rank (rank + t) mod n
    perm = _shift_perm(n_ranks, -1 % n_ranks)  # pass shards backwards

    def body(carry, t):
        buf, acc = carry
        src = (rank + t) % n_ranks
        prod = _chunked_matmul(buf, w, partitions_per_rank, precision)
        acc = acc.at[src].set(prod.astype(acc.dtype))
        nxt = jax.lax.ppermute(buf, axis_arg, perm)
        return (nxt, acc), None

    (_, out), _ = jax.lax.scan(body, (x, out), jnp.arange(n_ranks))
    return out.reshape(n_ranks * m_local, w.shape[1])


def _chunked_matmul(x, w, parts, precision):
    if parts <= 1 or x.shape[0] % parts:
        return jnp.dot(x, w, precision=precision)
    xs = x.reshape((parts, x.shape[0] // parts) + x.shape[1:])
    return jax.lax.map(
        lambda c: jnp.dot(c, w, precision=precision), xs
    ).reshape(x.shape[0], w.shape[1])


# ---------------------------------------------------------------------------
# send-side overlap: matmul-reduce-scatter
# ---------------------------------------------------------------------------


def matmul_reduce_scatter(x: jax.Array, w: jax.Array, axis_name, *,
                          precision=None) -> jax.Array:
    """``psum_scatter(x @ w)`` as a ring pipeline: output chunk for rank
    r+t is computed at step t and immediately enters the reduction ring
    while the next chunk is being produced (early-bird send).

    x: [m, k_local]  w: [k_local, n]   (k contracted over ``axis_name``)
    returns this rank's [m / n_ranks, n] reduced scatter shard.
    """
    names = _axes_tuple(axis_name)
    n_ranks = 1
    for a in names:
        n_ranks *= compat.axis_size(a)
    axis_arg = names if len(names) > 1 else names[0]
    rank = _flat_rank(names)
    m = x.shape[0]
    assert m % n_ranks == 0
    mc = m // n_ranks
    xs = x.reshape(n_ranks, mc, x.shape[1])
    perm = _shift_perm(n_ranks, 1)

    def body(acc, t):
        # at step t every rank computes + forwards the partial of chunk
        # (rank - t); after n-1 hops the full sum of chunk r sits on rank r.
        idx = (rank - t) % n_ranks
        mine = jnp.dot(xs[idx], w, precision=precision)
        acc = acc + mine
        acc = jax.lax.ppermute(acc, axis_arg, perm)
        return acc, None

    acc = jnp.zeros((mc, w.shape[1]), jnp.promote_types(x.dtype, w.dtype))
    # n-1 compute+shift steps, then a final local compute (own chunk):
    # the traveling accumulator for chunk c starts at rank c+1 and visits
    # the ring in +1 order, so rank r touches chunk (r - t) at step t.
    acc, _ = jax.lax.scan(body, acc, jnp.arange(1, n_ranks))
    acc = acc + jnp.dot(xs[rank], w, precision=precision)
    return acc


# ---------------------------------------------------------------------------
# gradient bucketing (partitioned allreduce over a pytree)
# ---------------------------------------------------------------------------


def bucketed_psum(tree, axis_names, *, buckets: int = 4):
    """psum a pytree in ``buckets`` independent flat buckets.

    Equality with ``jax.tree.map(psum)`` is exact; the point is schedule
    freedom: each bucket's all-reduce is an independent collective XLA
    can overlap with the compute producing later buckets' inputs.
    """
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        return tree
    sizes = [l.size for l in leaves]
    dtype = jnp.result_type(*[l.dtype for l in leaves])
    flat = jnp.concatenate([l.reshape(-1).astype(dtype) for l in leaves])
    total = flat.size
    per = -(-total // buckets)
    pad = per * buckets - total
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), dtype)])
    parts = flat.reshape(buckets, per)
    reduced = [jax.lax.psum(parts[i], _axes_tuple(axis_names))
               for i in range(buckets)]
    flat = jnp.concatenate(reduced)[:total]
    out, off = [], 0
    for l, s in zip(leaves, sizes):
        out.append(flat[off: off + s].reshape(l.shape).astype(l.dtype))
        off += s
    return jax.tree.unflatten(treedef, out)
