"""Alltoall schedules: pairwise | bruck | hierarchical (+ v-variant costing).

Buffer convention: ``num_blocks == nranks``.
  input : slot ``d`` on rank ``r`` holds the data  r -> d
  output: slot ``s`` on rank ``r`` holds the data  s -> r

``hierarchical`` is the TPU adaptation of the collective-optimized
alltoall of Namugwanya et al. [12] (paper §2.1): aggregate everything
headed to a remote pod inside the source pod first (ICI), ship one
R-block bundle per (pod-pair, local-rank) over the DCN, then the bundles
arrive pre-sorted.  DCN message count per pod-pair drops from R^2 to R.

Builders for pairwise/hierarchical simulate content ownership rank-by-rank
and emit block tables, so correctness is by construction (verified against
the numpy oracle in tests); bruck uses the classic fixed-slot argument
with local pre/post rotations.
"""
from __future__ import annotations

import numpy as np

from repro.core.schedule import CommRound, CommSchedule, make_round
from repro.core.topology import Topology

# content id for "data s -> d" with N ranks: s * N + d


def _content(s: int, d: int, n: int) -> int:
    return s * n + d


class OwnershipSim:
    """Rank-by-rank content-ownership simulator emitting block-table rounds.

    Tracks, per rank, a ``content-id -> slot`` map (content ``s*n+d`` is
    the data ``s -> d``); each ``round`` moves listed contents between
    ranks, landing receives in the slots the receiver's own sends
    vacated — so schedules built this way are correct by construction
    and in-place (no separate recv region).  Used by ``hierarchical``
    (2-level) and by the multi-axis ``staged`` builder (staged.py).
    """

    def __init__(self, n: int):
        self.n = n
        # where[r]: content-id -> slot; start: slot d holds r -> d.
        self.where = [{_content(r, d, n): d for d in range(n)}
                      for r in range(n)]
        self.rounds: list[CommRound] = []

    def round(self, edges_payload) -> None:
        """edges_payload: list of (src, dst, [content ids]).  Receiver
        stores incoming contents into the slots its own sends vacated."""
        n, where = self.n, self.where
        # each rank may send and receive at most once per round: the
        # vacated-slot reuse below hands every edge into dst the same
        # slots (and make_round would drop duplicate rows) — a
        # multi-in-degree round would corrupt the table silently
        srcs = [s for s, _, _ in edges_payload]
        dsts = [d for _, d, _ in edges_payload]
        assert len(set(srcs)) == len(srcs), "duplicate src in round"
        assert len(set(dsts)) == len(dsts), "duplicate dst in round"
        edges, send, recv = [], {}, {}
        vacated = {r: [] for r in range(n)}
        for s, d, contents in edges_payload:
            slots = [where[s][c] for c in contents]
            vacated[s] += slots
        for s, d, contents in edges_payload:
            edges.append((s, d))
            send[s] = [where[s][c] for c in contents]
            tgt_slots = vacated[d][: len(contents)]
            assert len(tgt_slots) == len(contents), (
                "receiver must vacate as many slots as it receives")
            recv[d] = tgt_slots
            for c in contents:
                del where[s][c]
        # apply receives after all sends are resolved
        for s, d, contents in edges_payload:
            for c, slot in zip(contents, recv[d]):
                where[d][c] = slot
        self.rounds.append(make_round(n, edges, send, recv))

    def post(self) -> np.ndarray:
        """local_post table: out slot s <- current slot of content s->r."""
        n = self.n
        post = np.zeros((n, n), np.int32)
        for r in range(n):
            for s in range(n):
                post[r, s] = self.where[r][_content(s, r, n)]
        return post


def pairwise(topo: Topology) -> CommSchedule:
    """N-1 rounds; round t: rank r sends r -> (r+t) data, receives from
    (r-t).  One block per message; self block never moves.

    Uses split send/recv regions (blocks [0,n) read-only input, [n,2n)
    receive landing zone) exactly like MPI's sendbuf/recvbuf pair — an
    in-place variant is impossible for general N (slot (r+t) is clobbered
    by the round-(n-t) receive before round t sends it)."""
    n = topo.nranks
    rounds = []
    for t in range(1, n):
        edges, send, recv = [], {}, {}
        for r in range(n):
            dst = (r + t) % n
            src = (r - t) % n
            edges.append((r, dst))
            send[r] = [dst]        # input region: slot d = data r->d
            recv[r] = [n + src]    # recv region: slot n+s = data s->r
        rounds.append(make_round(n, edges, send, recv))
    post = np.zeros((n, 2 * n), np.int32)
    for r in range(n):
        for s in range(n):
            post[r, s] = r if s == r else n + s
        for j in range(n, 2 * n):
            post[r, j] = j
    return CommSchedule(nranks=n, num_slots=2 * n, rounds=tuple(rounds),
                    name="alltoall.pairwise", local_post=post, out_slots=n)


def bruck(topo: Topology) -> CommSchedule:
    """log2(N) rounds of N/2 blocks.  Slot v travels a total distance of
    exactly v (one hop per set bit), so after local_pre places data r->d
    at slot (d-r) mod N, every value lands on its destination; local_post
    restores source order."""
    n = topo.nranks
    pre = np.zeros((n, n), np.int32)
    post = np.zeros((n, n), np.int32)
    for r in range(n):
        for v in range(n):
            pre[r, v] = (r + v) % n          # new slot v <- old slot r+v
        for s in range(n):
            post[r, s] = (r - s) % n         # out slot s <- slot r-s
    rounds = []
    t = 0
    while (1 << t) < n:
        off = 1 << t
        slots = [v for v in range(n) if v & off]
        edges, send, recv = [], {}, {}
        for r in range(n):
            edges.append((r, (r + off) % n))
            send[r] = slots
            recv[(r + off) % n] = slots
        rounds.append(make_round(n, edges, send, recv))
        t += 1
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                    name="alltoall.bruck", local_pre=pre, local_post=post)


def hierarchical(topo: Topology) -> CommSchedule:
    """Two-stage locality-aware alltoall (ownership-simulated tables).

    Stage 1 (intra-pod, pairwise): (p,l) hands (p,l') every block destined
    to local index l' of ANY pod — Q blocks per message.
    Stage 2 (inter-pod, pairwise over pods): (p,l) ships to (p+u,l) the
    R-block bundle {(src=(p,*) -> dst=(p+u,l))} — one DCN message per
    (pod-pair, local rank).
    """
    n, R, Q = topo.nranks, topo.ranks_per_pod, topo.npods
    if Q == 1:
        return pairwise(topo)
    sim = OwnershipSim(n)
    # Stage 1: intra-pod pairwise, bundles of Q (one block per dest pod)
    for t in range(1, R):
        edges_payload = []
        for p in range(Q):
            for l in range(R):
                src = topo.rank(p, l)
                dst = topo.rank(p, (l + t) % R)
                contents = [_content(src, topo.rank(q, (l + t) % R), n)
                            for q in range(Q)]
                edges_payload.append((src, dst, contents))
        sim.round(edges_payload)
    # Stage 2: inter-pod pairwise, bundles of R (pre-sorted per dest rank)
    for u in range(1, Q):
        edges_payload = []
        for p in range(Q):
            for l in range(R):
                src = topo.rank(p, l)
                dstp = (p + u) % Q
                dst = topo.rank(dstp, l)
                contents = [_content(topo.rank(p, ls), dst, n)
                            for ls in range(R)]
                edges_payload.append((src, dst, contents))
        sim.round(edges_payload)
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(sim.rounds),
                    name="alltoall.hierarchical", local_post=sim.post())


# ---------------------------------------------------------------------------
# alltoallv accounting (execution pads to the max block; costs use counts)
# ---------------------------------------------------------------------------


def alltoallv_bytes(kind: str, counts: np.ndarray, topo: Topology,
                    elem_bytes: int = 1) -> dict:
    """Exact per-link-class traffic for an alltoallv with byte matrix
    ``counts[src, dst]`` under each schedule family.

    pairwise:      data s->d crosses its own (s, d) link once.
    hierarchical:  s->d travels s ->(intra) agg ->(DCN) ->(arrived).
    Returns {"ici": bytes, "dcn": bytes, "msgs_ici": int, "msgs_dcn": int}.
    """
    n = topo.nranks
    out = {"ici": 0, "dcn": 0, "msgs_ici": 0, "msgs_dcn": 0}

    def add(src, dst, nbytes):
        key = "ici" if topo.is_local(src, dst) else "dcn"
        out[key] += int(nbytes) * elem_bytes
        out["msgs_" + key] += 1 if nbytes > 0 else 0

    if kind == "pairwise":
        for s in range(n):
            for d in range(n):
                if s != d:
                    add(s, d, counts[s, d])
    elif kind == "hierarchical":
        R, Q = topo.ranks_per_pod, topo.npods
        for p in range(Q):
            for l in range(R):
                src = topo.rank(p, l)
                # stage 1: to each intra-pod peer, its Q-dest bundle
                for l2 in range(R):
                    if l2 == l:
                        continue
                    nb = sum(counts[src, topo.rank(q, l2)] for q in range(Q))
                    add(src, topo.rank(p, l2), nb)
                # stage 2: one bundle per remote pod
                for q in range(Q):
                    if q == p:
                        continue
                    nb = sum(counts[topo.rank(p, ls), topo.rank(q, l)]
                             for ls in range(R))
                    add(src, topo.rank(q, l), nb)
    else:
        raise ValueError(kind)
    return out


ALGORITHMS = {
    "pairwise": pairwise,
    "bruck": bruck,
    "hierarchical": hierarchical,
}
