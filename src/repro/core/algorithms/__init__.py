"""Collective algorithm schedule builders (the MPIX algorithm zoo).

Every builder takes a ``Topology`` and returns a ``Schedule`` executable by
any ``Transport``.  Registries map (collective, algorithm-name) to builder,
mirroring MPI Advance's publicly-selectable algorithm tables.
"""
from repro.core.algorithms import (allgather, allreduce, alltoall,
                                   partitioned, reduce_scatter, staged)

# "staged" (hierarchy-staged through every Topology level; staged.py) is
# merged here rather than into each family's ALGORITHMS dict because
# staged.py imports the families' sub-stage builders.  It must stay
# AFTER the family entries: modeled-time ties (staged == hierarchical on
# 2-level topologies) resolve to the earlier registration.
REGISTRY = {
    coll: {**algos.ALGORITHMS, "staged": staged.ALGORITHMS[coll]}
    for coll, algos in (("allgather", allgather), ("allreduce", allreduce),
                        ("reduce_scatter", reduce_scatter),
                        ("alltoall", alltoall))
}
# chunked point-to-point transfers (MPIPCL partition-count choice);
# timed by the tuner like any CommSchedule, not exposed via mpix_*.
REGISTRY["partitioned"] = partitioned.ALGORITHMS

# Collectives with an mpix_* API entry point (the dense families).
DENSE_COLLECTIVES = ("allgather", "allreduce", "reduce_scatter", "alltoall")
