"""Collective algorithm schedule builders (the MPIX algorithm zoo).

Every builder takes a ``Topology`` and returns a ``Schedule`` executable by
any ``Transport``.  Registries map (collective, algorithm-name) to builder,
mirroring MPI Advance's publicly-selectable algorithm tables.
"""
from repro.core.algorithms import (allgather, allreduce, alltoall,
                                   partitioned, reduce_scatter)

REGISTRY = {
    "allgather": allgather.ALGORITHMS,
    "allreduce": allreduce.ALGORITHMS,
    "reduce_scatter": reduce_scatter.ALGORITHMS,
    "alltoall": alltoall.ALGORITHMS,
    # chunked point-to-point transfers (MPIPCL partition-count choice);
    # timed by the tuner like any CommSchedule, not exposed via mpix_*.
    "partitioned": partitioned.ALGORITHMS,
}

# Collectives with an mpix_* API entry point (the dense families).
DENSE_COLLECTIVES = ("allgather", "allreduce", "reduce_scatter", "alltoall")
