"""Collective algorithm schedule builders (the MPIX algorithm zoo).

Every builder takes a ``Topology`` and returns a ``Schedule`` executable by
any ``Transport``.  Registries map (collective, algorithm-name) to builder,
mirroring MPI Advance's publicly-selectable algorithm tables.
"""
from repro.core.algorithms import allgather, allreduce, alltoall, reduce_scatter

REGISTRY = {
    "allgather": allgather.ALGORITHMS,
    "allreduce": allreduce.ALGORITHMS,
    "reduce_scatter": reduce_scatter.ALGORITHMS,
    "alltoall": alltoall.ALGORITHMS,
}
