"""Allgather schedules: ring | bruck | recursive_doubling | hierarchical.

Buffer convention: ``num_blocks == nranks``; rank ``r`` initially owns
block ``r`` (other slots are garbage/zero); afterwards every rank owns
every block.

``hierarchical`` is the TPU adaptation of the locality-aware Bruck
allgather (Bienz et al. [2] — paper §2.1): gather inside the pod over ICI,
cross the DCN exactly once per block in ``ranks_per_pod``-wide stripes,
then redistribute inside the pod.
"""
from __future__ import annotations

import numpy as np

from repro.core.schedule import (CommRound, CommSchedule, NotApplicable,
                                 make_round)
from repro.core.topology import Topology


# ---------------------------------------------------------------------------
# generic sub-builders over an ordered member list with arbitrary ownership
# ---------------------------------------------------------------------------


def _ring_rounds(nranks: int, members: list[int],
                 owned: list[list[int]]) -> list[CommRound]:
    """Ring allgather among ``members``; members[i] starts owning blocks
    ``owned[i]`` (equal sizes); after M-1 rounds each member owns the union.
    """
    m = len(members)
    rounds = []
    for t in range(m - 1):
        edges, send, recv = [], {}, {}
        for i, r in enumerate(members):
            nxt = members[(i + 1) % m]
            edges.append((r, nxt))
            send[r] = owned[(i - t) % m]
            recv[nxt] = owned[(i - t) % m]
        rounds.append(make_round(nranks, edges, send, recv))
    return rounds


def _bruck_rounds(nranks: int, members: list[int],
                  owned: list[list[int]]) -> list[CommRound]:
    """Dissemination (Bruck) allgather among ``members``: ceil(log2 M)
    rounds; round t, member i sends every set it has to member i - 2^t."""
    m = len(members)
    rounds = []
    t = 0
    while (1 << t) < m:
        off = 1 << t
        cnt = min(off, m - off)  # sets transferred this round
        edges, send, recv = [], {}, {}
        for i, r in enumerate(members):
            dst = members[(i - off) % m]
            edges.append((r, dst))
            blocks = [b for j in range(cnt) for b in owned[(i + j) % m]]
            send[r] = blocks
            recv[dst] = blocks
        rounds.append(make_round(nranks, edges, send, recv))
        t += 1
    return rounds


def _recursive_doubling_rounds(nranks: int, members: list[int],
                               owned: list[list[int]]) -> list[CommRound]:
    m = len(members)
    if m & (m - 1):
        raise NotApplicable("recursive doubling needs power-of-2 members")
    rounds = []
    t = 0
    while (1 << t) < m:
        off = 1 << t
        edges, send, recv = [], {}, {}
        for i, r in enumerate(members):
            j = i ^ off
            p = members[j]
            edges.append((r, p))
            base = (i >> t) << t  # start of my aligned group of size 2^t
            blocks = [b for q in range(base, base + off) for b in owned[q]]
            send[r] = blocks
            recv[p] = blocks
        rounds.append(make_round(nranks, edges, send, recv))
        t += 1
    return rounds


_SUB = {"ring": _ring_rounds, "bruck": _bruck_rounds,
        "recursive_doubling": _recursive_doubling_rounds}


# ---------------------------------------------------------------------------
# round fusion: disjoint groups (pods / stripes) run their stages in parallel
# ---------------------------------------------------------------------------


def _disjoint(a: CommRound, b: CommRound) -> bool:
    sa = {s for s, _ in a.perm} | {d for _, d in a.perm}
    sb = {s for s, _ in b.perm} | {d for _, d in b.perm}
    return not (sa & sb)


def _fuse(a: CommRound, b: CommRound, nranks: int) -> CommRound:
    assert a.reduce == b.reduce
    k = max(a.k, b.k)

    def pad(x):
        if x.shape[1] == k:
            return x
        out = np.full((x.shape[0], k), -1, np.int32)
        out[:, : x.shape[1]] = x
        return out

    sa, ra = pad(a.gather_idx), pad(a.scatter_idx)
    sb, rb = pad(b.gather_idx), pad(b.scatter_idx)
    mask_b = np.zeros(nranks, bool)
    for s, d in b.perm:
        mask_b[s] = True
        mask_b[d] = True
    gather = np.where(mask_b[:, None], sb, sa)
    scatter = np.where(mask_b[:, None], rb, ra)
    return CommRound(perm=a.perm + b.perm, gather_idx=gather,
                     scatter_idx=scatter, reduce=a.reduce)


def parallel_fuse(groups: list[list[CommRound]], nranks: int) -> list[CommRound]:
    """Zip same-index rounds of rank-disjoint groups into single rounds."""
    groups = [g for g in groups if g]
    if not groups:
        return []
    depth = max(len(g) for g in groups)
    out = []
    for i in range(depth):
        stage = [g[i] for g in groups if i < len(g)]
        fused = stage[0]
        for rnd in stage[1:]:
            assert _disjoint(fused, rnd), "parallel groups must be disjoint"
            fused = _fuse(fused, rnd, nranks)
        out.append(fused)
    return out


# ---------------------------------------------------------------------------
# public builders
# ---------------------------------------------------------------------------


def _flat(topo: Topology, kind: str) -> CommSchedule:
    n = topo.nranks
    rounds = _SUB[kind](n, list(range(n)), [[r] for r in range(n)])
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                    name=f"allgather.{kind}")


def ring(topo: Topology) -> CommSchedule:
    return _flat(topo, "ring")


def bruck(topo: Topology) -> CommSchedule:
    return _flat(topo, "bruck")


def recursive_doubling(topo: Topology) -> CommSchedule:
    return _flat(topo, "recursive_doubling")


def hierarchical(topo: Topology, intra: str = "bruck",
                 inter: str = "bruck") -> CommSchedule:
    """Locality-aware 3-stage allgather.

    A) intra-pod allgather of the pod's own blocks         (ICI only)
    B) striped inter-pod allgather: local rank l moves the
       blocks of local index l between pods                (the only DCN)
    C) intra-pod allgather of the received remote stripes  (ICI only)

    Every block crosses the DCN exactly once per remote pod, and DCN
    traffic is balanced across all ranks of the pod (stripes) — the win of
    the locality-aware Bruck algorithm over flat log-step schedules whose
    top rounds ship half the buffer across the slow links.
    """
    n, R, Q = topo.nranks, topo.ranks_per_pod, topo.npods
    if Q == 1:
        return _flat(topo, intra)
    rounds: list[CommRound] = []
    # A: per-pod allgather of local blocks (pods in parallel)
    groups_a = []
    for p in range(Q):
        members = list(topo.pod_ranks(p))
        groups_a.append(_SUB[intra](n, members, [[r] for r in members]))
    rounds += parallel_fuse(groups_a, n)
    # B: per-local-index allgather across pods (stripes in parallel)
    groups_b = []
    for l in range(R):
        members = [topo.rank(q, l) for q in range(Q)]
        groups_b.append(_SUB[inter](n, members, [[r] for r in members]))
    rounds += parallel_fuse(groups_b, n)
    # C: per-pod allgather of remote stripes: local rank l now owns
    # {(q, l) for q != p}; redistribute so everyone owns everything.
    groups_c = []
    for p in range(Q):
        members = list(topo.pod_ranks(p))
        owned = [[topo.rank(q, topo.local(r)) for q in range(Q) if q != p]
                 for r in members]
        groups_c.append(_SUB[intra](n, members, owned))
    rounds += parallel_fuse(groups_c, n)
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                    name=f"allgather.hierarchical[{intra}+{inter}]")


def hierarchical_ring(topo: Topology) -> CommSchedule:
    """Locality-aware variant with ring sub-stages (fewest messages per
    round; better when per-round payload is bandwidth-bound)."""
    return hierarchical(topo, intra="ring", inter="ring")


ALGORITHMS = {
    "ring": ring,
    "bruck": bruck,
    "recursive_doubling": recursive_doubling,
    "hierarchical": hierarchical,
    "hierarchical_ring": hierarchical_ring,
}
