"""Hierarchy-staged schedule builders over the full ``Topology`` stack.

The ``hierarchical`` builders hard-code one pod/local split (2 levels).
These builders generalize the same staging to *every* level of a
multi-level ``Topology`` (DCN over N-D torus axes) through one generic
axis-decomposition engine:

  * **reduce-scatter stages** run innermost -> outermost: at level
    ``l`` the ranks that differ only in their level-``l`` coordinate
    partition their live block set by the *block's* level-``l``
    coordinate, so by the time a stage crosses a slow outer link every
    rank ships exactly the fully-pre-reduced blocks that belong on the
    other side — the outermost (DCN) stage moves single blocks.
  * **allgather stages** run outermost -> innermost: the slow links
    move each rank's own block once (stripe exchange), and the fast
    inner torus axes fan the received stripes out with ever larger
    bundles.  DCN bytes match the 2-level locality-aware Bruck minimum
    (each block crosses once per remote pod) with fewer total rounds.
  * **alltoall stages** process one axis at a time (innermost first)
    via content-ownership simulation: every rank bundles all blocks
    whose destination differs at that axis and ships one message per
    axis peer — locality-aware intermediate aggregation that cuts
    level-``l`` message counts from one-per-(src, dst) pair to
    ``size_l - 1`` per rank.

Both phase families share one ownership formula: at the stage for
level ``l``, a rank owns exactly the blocks whose coordinates match its
own at every level ``>= l``.  On a 1-level topology the builders
degenerate to the flat ring/pairwise schedules; on the canonical
2-level hierarchy the allreduce/reduce-scatter stagings reproduce the
``hierarchical`` builders round-for-round (see test_hierarchical.py).
"""
from __future__ import annotations

from repro.core.schedule import CommRound, CommSchedule
from repro.core.topology import Topology
from repro.core.algorithms import allgather as ag
from repro.core.algorithms import reduce_scatter as rs
from repro.core.algorithms.allgather import parallel_fuse
from repro.core.algorithms.alltoall import OwnershipSim


def _coords_table(topo: Topology) -> list[tuple[int, ...]]:
    """coords(r) for every rank, computed once per builder — the stage
    loops below index it O(n^2) times per level."""
    return [topo.coords(r) for r in range(topo.nranks)]


def level_groups(topo: Topology, lvl: int,
                 coords: list | None = None) -> list[list[int]]:
    """Rank groups that differ only in the level-``lvl`` coordinate,
    each ordered by that coordinate (rank order within a group)."""
    coords = coords if coords is not None else _coords_table(topo)
    groups: dict[tuple, list[int]] = {}
    for r in range(topo.nranks):
        c = coords[r]
        groups.setdefault(c[:lvl] + c[lvl + 1:], []).append(r)
    return [sorted(g) for g in groups.values()]


def _owned_blocks(topo: Topology, rank: int, lvl: int,
                  coords: list | None = None) -> list[int]:
    """Blocks whose coordinates match ``rank``'s at every level >= lvl.

    This is the per-stage ownership set of the staged decomposition:
    the union over a level-``lvl`` group is the set matching at levels
    > ``lvl`` (what each member holds entering an RS stage / owns
    leaving an AG stage), and fixing every level collapses it to the
    rank's own block.
    """
    coords = coords if coords is not None else _coords_table(topo)
    tail = coords[rank][lvl:]
    return [b for b in range(topo.nranks) if coords[b][lvl:] == tail]


def _rs_stages(topo: Topology) -> list[CommRound]:
    """Reduce-scatter staged innermost -> outermost (ring sub-stages)."""
    n = topo.nranks
    coords = _coords_table(topo)
    rounds: list[CommRound] = []
    for lvl in reversed(range(len(topo.levels))):
        groups = []
        for members in level_groups(topo, lvl, coords):
            owned = [_owned_blocks(topo, r, lvl, coords) for r in members]
            groups.append(rs._ring_rs_rounds(n, members, owned))
        rounds += parallel_fuse(groups, n)
    return rounds


def _ag_stages(topo: Topology) -> list[CommRound]:
    """Allgather staged outermost -> innermost (ring sub-stages)."""
    n = topo.nranks
    coords = _coords_table(topo)
    rounds: list[CommRound] = []
    for lvl in range(len(topo.levels)):
        groups = []
        for members in level_groups(topo, lvl, coords):
            owned = [_owned_blocks(topo, r, lvl, coords) for r in members]
            groups.append(ag._ring_rounds(n, members, owned))
        rounds += parallel_fuse(groups, n)
    return rounds


def allgather_staged(topo: Topology) -> CommSchedule:
    """Stripe-staged allgather: cross each level once, slowest first
    with single own blocks, then widen on the faster inner axes."""
    n = topo.nranks
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(_ag_stages(topo)),
                        name="allgather.staged")


def reduce_scatter_staged(topo: Topology) -> CommSchedule:
    """Per-axis reduce-scatter: partition by block coordinate level by
    level so outer links only carry pre-reduced blocks."""
    n = topo.nranks
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(_rs_stages(topo)),
                        name="reduce_scatter.staged")


def allreduce_staged(topo: Topology) -> CommSchedule:
    """Staged allreduce: reduce-scatter down the level stack (innermost
    axis first), then allgather back up — the k-level generalization of
    the 4-stage node-aware allreduce."""
    n = topo.nranks
    rounds = _rs_stages(topo) + _ag_stages(topo)
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                        name="allreduce.staged")


def alltoall_staged(topo: Topology) -> CommSchedule:
    """Axis-staged alltoall (ownership-simulated, in-place).

    Invariant: once the levels in a processed set D are done, the data
    ``s -> d`` sits on the rank whose coordinates match ``d`` on D and
    ``s`` elsewhere.  Processing level ``l`` is a pairwise exchange
    inside each level-``l`` group where offset-``t`` messages bundle
    every held block destined to the peer's level-``l`` coordinate
    (``n / size_l`` blocks per message).  Innermost-first ordering
    aggregates within the pod before a single bundled DCN stage —
    level-``l`` messages drop to ``size_l - 1`` per rank.
    """
    n = topo.nranks
    sim = OwnershipSim(n)
    coords = _coords_table(topo)
    for lvl in reversed(range(len(topo.levels))):
        size = topo.levels[lvl].size
        for t in range(1, size):
            edges_payload = []
            for r in range(n):
                c = list(coords[r])
                c[lvl] = (c[lvl] + t) % size
                dst = topo.rank_of(c)
                contents = [cid for cid in sim.where[r]
                            if coords[cid % n][lvl] == c[lvl]]
                edges_payload.append((r, dst, contents))
            sim.round(edges_payload)
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(sim.rounds),
                        name="alltoall.staged", local_post=sim.post())


def serialized_pod_allgather(topo: Topology) -> CommSchedule:
    """Deliberately NAIVE staged allgather: each pod's intra-pod ring
    stage emitted back-to-back instead of ``parallel_fuse``'d — the
    rank-disjoint per-pod stages a careless staged builder serializes.
    NOT registered: this is the reference foil for the persistent
    executor's fusion pass (core.executor), which must recover the
    parallel form (``npods * (R-1)`` rounds -> ``R-1``).  Shared by
    tests/test_executor.py, tests/device_scripts/check_executor.py and
    benchmarks/bench_transport.py so the corpus entry and its expected
    round counts live in one place."""
    n = topo.nranks
    rounds: list[CommRound] = []
    for p in range(topo.npods):
        members = list(topo.pod_ranks(p))
        rounds += ag._ring_rounds(n, members, [[r] for r in members])
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                        name="allgather.staged_naive")


def _bruck_ag_rounds(n: int, members: list[int]) -> list[CommRound]:
    """Bruck-style allgather inside one rank group: log-step bundled
    shifts with *growing* message widths (1, 2, 4, ... blocks), blocks
    stored in-place at their own slot ids.  The width-staggered foil to
    the equal-width ring sub-stages of ``ag._ring_rounds``."""
    from repro.core.schedule import make_round

    R = len(members)
    rounds: list[CommRound] = []
    d = 1
    while d < R:
        cnt = min(d, R - d)
        edges, send, recv = [], {}, {}
        for i in range(R):
            blocks = [members[(i + t) % R] for t in range(cnt)]
            src, dst = members[i], members[(i - d) % R]
            edges.append((src, dst))
            send[src] = blocks
            recv[dst] = blocks            # land at their own slot ids
        rounds.append(make_round(n, edges, send, recv))
        d *= 2
    return rounds


def staggered_pod_allgather(topo: Topology) -> CommSchedule:
    """Deliberately WIDTH-STAGGERED naive staged allgather: even pods
    run the equal-width ring stage, odd pods a Bruck log-step stage
    whose bundles double in width — so the rank-disjoint per-pod
    stages, serialized back-to-back, can only *partially* re-fuse under
    the topology-free equal-padded-width rule (the wide Bruck rounds
    find no equal-width partner).  The cost-model-armed pass
    (``core.executor._compact_armed``) overlaps them fully via
    unequal-width whole-round merges priced by ``topo.round_time``.
    NOT registered: like ``serialized_pod_allgather`` this is a corpus
    foil, shared by tests/test_executor.py, tests/test_schedule_fuzz.py
    and benchmarks/bench_transport.py."""
    n = topo.nranks
    rounds: list[CommRound] = []
    for p in range(topo.npods):
        members = list(topo.pod_ranks(p))
        if p % 2 == 0:
            rounds += ag._ring_rounds(n, members, [[r] for r in members])
        else:
            rounds += _bruck_ag_rounds(n, members)
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                        name="allgather.staged_staggered")


# Registered per family by repro.core.algorithms.REGISTRY (registering
# here would cycle: this module imports the family modules' sub-stage
# builders).
ALGORITHMS = {
    "allgather": allgather_staged,
    "allreduce": allreduce_staged,
    "reduce_scatter": reduce_scatter_staged,
    "alltoall": alltoall_staged,
}
