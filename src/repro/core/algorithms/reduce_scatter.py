"""Reduce-scatter schedules: ring | recursive_halving | hierarchical.

Buffer convention: ``num_blocks == nranks``; every rank starts with its
full local contribution (all N blocks); rank ``r`` ends owning the fully
reduced block ``r`` (other slots hold stale partials).
"""
from __future__ import annotations

from repro.core.schedule import (CommRound, CommSchedule, NotApplicable,
                                 make_round)
from repro.core.topology import Topology
from repro.core.algorithms.allgather import parallel_fuse


def _ring_rs_rounds(nranks: int, members: list[int],
                    owned: list[list[int]]) -> list[CommRound]:
    """Ring reduce-scatter among ``members``: member i ends owning the
    fully reduced block set ``owned[i]``.  M-1 rounds; round t member i
    sends the traveling partial of set owned[(i - t - 1) % M] to i+1."""
    m = len(members)
    rounds = []
    for t in range(m - 1):
        edges, send, recv = [], {}, {}
        for i, r in enumerate(members):
            nxt = members[(i + 1) % m]
            s = owned[(i - t - 1) % m]
            edges.append((r, nxt))
            send[r] = s
            recv[nxt] = s
        rounds.append(make_round(nranks, edges, send, recv, reduce=True))
    return rounds


def _halving_rounds(nranks: int, members: list[int],
                    owned: list[list[int]]) -> list[CommRound]:
    """Recursive halving among 2^k members; member i ends owning owned[i].

    Round over offsets M/2, M/4, ..., 1: partner i^off; each member sends
    the half of its active sets belonging to the partner's side."""
    m = len(members)
    if m & (m - 1):
        raise NotApplicable("recursive halving needs power-of-2 members")
    active = {i: set(range(m)) for i in range(m)}  # set indices, not blocks
    rounds = []
    off = m // 2
    while off >= 1:
        edges, send, recv = [], {}, {}
        for i, r in enumerate(members):
            j = i ^ off
            p = members[j]
            edges.append((r, p))
            mine = {s for s in active[i] if (s & off) == (i & off)}
            theirs = sorted(active[i] - mine)
            blocks = [b for s in theirs for b in owned[s]]
            send[r] = blocks
            recv[p] = blocks
            active[i] = mine
        rounds.append(make_round(nranks, edges, send, recv, reduce=True))
        off //= 2
    return rounds


def ring(topo: Topology) -> CommSchedule:
    n = topo.nranks
    rounds = _ring_rs_rounds(n, list(range(n)), [[r] for r in range(n)])
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                    name="reduce_scatter.ring")


def recursive_halving(topo: Topology) -> CommSchedule:
    n = topo.nranks
    rounds = _halving_rounds(n, list(range(n)), [[r] for r in range(n)])
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                    name="reduce_scatter.recursive_halving")


def hierarchical(topo: Topology, intra: str = "ring",
                 inter: str = "ring") -> CommSchedule:
    """Locality-aware 2-stage reduce-scatter.

    A) intra-pod RS: local rank l reduces stripe S_l = {(q, l) for all q}
       over its pod (ICI only);
    B) inter-pod RS among same-l ranks over the Q stripe blocks, ending
       with rank (p, l) owning block (p, l) = its own rank id (DCN,
       1/R of the vector per rank — balanced and minimal).
    """
    n, R, Q = topo.nranks, topo.ranks_per_pod, topo.npods
    if Q == 1:
        return ring(topo) if intra == "ring" else recursive_halving(topo)
    sub = {"ring": _ring_rs_rounds, "recursive_halving": _halving_rounds}
    rounds: list[CommRound] = []
    groups_a = []
    for p in range(Q):
        members = list(topo.pod_ranks(p))
        owned = [[topo.rank(q, topo.local(r)) for q in range(Q)]
                 for r in members]
        groups_a.append(sub[intra](n, members, owned))
    rounds += parallel_fuse(groups_a, n)
    groups_b = []
    for l in range(R):
        members = [topo.rank(q, l) for q in range(Q)]
        owned = [[topo.rank(q, l)] for q in range(Q)]
        groups_b.append(sub[inter](n, members, owned))
    rounds += parallel_fuse(groups_b, n)
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                    name=f"reduce_scatter.hierarchical[{intra}+{inter}]")


ALGORITHMS = {
    "ring": ring,
    "recursive_halving": recursive_halving,
    "hierarchical": hierarchical,
}
