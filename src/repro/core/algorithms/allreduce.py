"""Allreduce schedules: ring_rs_ag | recursive_halving_doubling | hierarchical.

Buffer convention: ``num_blocks == nranks`` (the vector is pre-chunked
into N blocks); every rank starts with its full local contribution and
ends with every block fully reduced.

All variants are bandwidth-optimal (2 * V * (N-1)/N bytes per rank); they
differ in round count and in *which link class* the rounds cross — the
hierarchical variant confines all but 2*(Q-1) single-block rounds to the
pod (ICI), the paper's node-aware allreduce story.
"""
from __future__ import annotations

from repro.core.schedule import CommRound, CommSchedule
from repro.core.topology import Topology
from repro.core.algorithms import allgather as ag
from repro.core.algorithms import reduce_scatter as rs
from repro.core.algorithms.allgather import parallel_fuse


def ring_rs_ag(topo: Topology) -> CommSchedule:
    n = topo.nranks
    members = list(range(n))
    singles = [[r] for r in range(n)]
    rounds = (rs._ring_rs_rounds(n, members, singles)
              + ag._ring_rounds(n, members, singles))
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                    name="allreduce.ring_rs_ag")


def recursive_halving_doubling(topo: Topology) -> CommSchedule:
    n = topo.nranks
    members = list(range(n))
    singles = [[r] for r in range(n)]
    rounds = (rs._halving_rounds(n, members, singles)
              + ag._recursive_doubling_rounds(n, members, singles))
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                    name="allreduce.recursive_halving_doubling")


def hierarchical(topo: Topology, intra: str = "ring",
                 inter: str = "ring") -> CommSchedule:
    """4-stage node-aware allreduce:
    A) intra-pod reduce-scatter of stripes   (ICI)
    B) inter-pod reduce-scatter (1 block)    (DCN, minimal + balanced)
    C) inter-pod allgather of stripe blocks  (DCN)
    D) intra-pod allgather of stripes        (ICI)
    """
    n, R, Q = topo.nranks, topo.ranks_per_pod, topo.npods
    if Q == 1:
        return ring_rs_ag(topo)
    rs_sub = {"ring": rs._ring_rs_rounds,
              "recursive_halving": rs._halving_rounds}[intra]
    rounds: list[CommRound] = []
    # A
    groups = []
    for p in range(Q):
        members = list(topo.pod_ranks(p))
        owned = [[topo.rank(q, topo.local(r)) for q in range(Q)]
                 for r in members]
        groups.append(rs_sub(n, members, owned))
    rounds += parallel_fuse(groups, n)
    # B
    groups = []
    for l in range(R):
        members = [topo.rank(q, l) for q in range(Q)]
        owned = [[topo.rank(q, l)] for q in range(Q)]
        groups.append(rs._ring_rs_rounds(n, members, owned))
    rounds += parallel_fuse(groups, n)
    # C
    groups = []
    for l in range(R):
        members = [topo.rank(q, l) for q in range(Q)]
        owned = [[topo.rank(q, l)] for q in range(Q)]
        groups.append(ag._ring_rounds(n, members, owned))
    rounds += parallel_fuse(groups, n)
    # D
    groups = []
    for p in range(Q):
        members = list(topo.pod_ranks(p))
        owned = [[topo.rank(q, topo.local(r)) for q in range(Q)]
                 for r in members]
        groups.append(ag._ring_rounds(n, members, owned))
    rounds += parallel_fuse(groups, n)
    return CommSchedule(nranks=n, num_slots=n, rounds=tuple(rounds),
                    name=f"allreduce.hierarchical[{intra}+{inter}]")


def hierarchical_rh(topo: Topology) -> CommSchedule:
    """Locality-aware variant with recursive-halving intra-pod stages
    (log rounds on ICI; needs power-of-two ranks per pod)."""
    return hierarchical(topo, intra="recursive_halving")


ALGORITHMS = {
    "ring_rs_ag": ring_rs_ag,
    "recursive_halving_doubling": recursive_halving_doubling,
    "hierarchical": hierarchical,
    "hierarchical_rh": hierarchical_rh,
}
