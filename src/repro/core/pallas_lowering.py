"""Device-side Pallas lowering of a ``CompiledExec`` (the paper's
GPU-aware pillar): the WHOLE compiled round sequence as ONE kernel.

Both existing transports lower every compiled ``CommRound`` to a
gather-permute-scatter around ``shard_map``/``ppermute``, so an R-round
schedule pays R XLA collective launches.  This module takes the baked
numpy index tables of a ``CompiledExec`` (``_ExecRound.src/dst/g_safe/
g_mask/t_safe/t_mask`` plus the folded local pre/post permutations) and
embeds them as kernel-resident constants in a single ``pl.pallas_call``
over the *global* slot buffer ``[nranks, num_slots, *slot]``:

  * the buffer is staged once into a VMEM scratch work buffer; every
    slot route is emitted with *static* indices (Pallas kernels cannot
    capture array constants, and static indices are what lets Mosaic
    lower each move as a plain VMEM copy), so ``-1`` routes simply emit
    nothing — no scratch row, unlike the fancy-indexed backends;
  * each round runs in two phases that preserve ppermute semantics
    exactly: phase 1 gathers every edge's payload from the pre-round
    state (reads only — intra-round hazards and (r, r) self-copies are
    safe by construction), phase 2 lands every write
    (``.at[t].set``, or ``.at[t].add`` for reduce rounds, which
    accumulate in scratch instead of materializing an inbox);
  * ``chunks > 1`` tiles the slot row axis onto the Pallas grid — the
    same always-legal row decomposition as ``Transport.run_chunked``
    (rows never mix; the slot-granularity sibling is ``split_round``) —
    and Pallas's grid pipelining double-buffers the block transfers:
    chunk ``i+1``'s HBM->VMEM copy is issued while chunk ``i`` drains
    through the permutation network.  Still one kernel launch.

R rounds -> 1 launch is the whole point: ``PallasExec.launches`` counts
launches so the benchmark can assert the amortization (R -> 1 over the
corpus).  On a CPU/GPU host the kernel runs under the Pallas interpreter
(``kernels.compat.pallas_interpret``), bit-exact vs
``SimTransport.run_reference`` — that is what makes the transport
testable in tier-1 CI.  On real multi-chip TPU topologies the same
structure extends to ``pltpu.make_async_remote_copy`` RDMA rounds
(per-chip local buffers, no global gather); that variant needs device
semaphores the interpreter cannot model and is gated behind actual TPU
presence — see the README "Device-side transport" subsection.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.executor import CompiledExec, get_executor
from repro.core.schedule import CommSchedule, validate_schedules_enabled
from repro.core.topology import Topology
from repro.kernels.compat import pallas_interpret, tpu_compiler_params


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


class PallasExec:
    """One ``CompiledExec`` lowered to a single-kernel Pallas executor.

    ``run(gbuf, chunks=)`` executes the full schedule (local_pre ->
    every compiled round -> local_post) on a global buffer
    ``[nranks, num_slots, *slot]`` and returns the same shape — the
    ``SimTransport`` calling convention, which is what lets the
    ``run_reference`` oracle check it bit-for-bit.  ``launches`` counts
    ``pallas_call`` invocations (one per ``run``, regardless of round
    count R); ``jit_traces`` counts actual lowerings (one per (shape,
    dtype, chunks) thanks to the jit cache — the persistent-collective
    property, same contract as ``CompiledExec.trace_count``).
    """

    def __init__(self, ex: CompiledExec, *, interpret: bool | None = None):
        self.ex = ex
        self.nranks = ex.nranks
        self.num_slots = ex.num_slots
        self.rounds = ex.rounds_after
        self.interpret = (pallas_interpret() if interpret is None
                          else bool(interpret))
        self.launches = 0
        self.jit_traces = 0
        self._jitted: dict = {}

    # -- kernel body ------------------------------------------------------
    def _kernel(self, in_ref, out_ref, work):
        """Executes on refs shaped [n, s, C, F].

        Every index comes from the baked numpy tables as a Python int,
        so the whole routing program is kernel-resident: Pallas kernels
        cannot capture array constants, and static indices are exactly
        what lets Mosaic turn each slot move into a plain VMEM copy
        (no dynamic-gather lowering).  ``-1`` routes (masked slots) are
        simply not emitted — no scratch row is needed here, unlike the
        fancy-indexed numpy/shard_map backends."""
        self.jit_traces += 1
        ex = self.ex
        n = self.nranks
        # stage in + local_pre fold (non-bijective pre survives folding)
        for r in range(n):
            row = in_ref[r]                              # [s, C, F]
            if ex._pre is not None:
                row = jnp.stack([row[int(i)] for i in ex._pre[r]])
            work[r] = row
        zero = jnp.zeros(work.shape[2:], work.dtype)     # one slot block
        for rnd in ex._rounds:
            m = len(rnd.src)
            # phase 1 — gather every edge's payload from the PRE-round
            # state (ppermute semantics: no write is visible to any read
            # of the same round; (r, r) self-pairs and intra-round
            # hazards are correct by construction); masked gathers are
            # send-zeros
            vals = []
            for e in range(m):
                row = work[int(rnd.src[e])]              # [s, C, F]
                vals.append([
                    row[int(rnd.g_safe[e, j])]
                    if rnd.g_mask[e, j] else zero
                    for j in range(rnd.k)])
            # phase 2 — land every write on its destination row; reduce
            # rounds accumulate in the work scratch.  dst values are
            # distinct within a round (perm is a matching), so reading
            # ``work[dst]`` here still sees the pre-round row.  The
            # masked-gather zero adds are kept: bit-parity with run_sim
            # (x + 0.0 normalizes -0.0; chained adds in j order match
            # np.add.at element order even for duplicate targets).
            for e in range(m):
                dst = int(rnd.dst[e])
                cur = work[dst]
                for j in range(rnd.k):
                    if not rnd.t_mask[e, j]:
                        continue                         # dropped slot
                    t = int(rnd.t_safe[e, j])
                    if rnd.reduce:
                        cur = cur.at[t].add(vals[e][j])
                    else:
                        cur = cur.at[t].set(vals[e][j])
                work[dst] = cur
        # local_post + drain
        for r in range(n):
            row = work[r]
            if ex._post is not None:
                row = jnp.stack([row[int(i)] for i in ex._post[r]])
            out_ref[r] = row

    # -- launch -----------------------------------------------------------
    def _build(self, c: int, cb: int, f: int, dtype) -> callable:
        n, s = self.nranks, self.num_slots
        grid = (c // cb,)
        spec = pl.BlockSpec((n, s, cb, f), lambda i: (0, 0, i, 0))
        return pl.pallas_call(
            self._kernel,
            grid=grid,
            in_specs=[spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((n, s, c, f), dtype),
            scratch_shapes=[_vmem((n, s, cb, f), dtype)],
            compiler_params=tpu_compiler_params(
                dimension_semantics=("arbitrary",)),
            interpret=self.interpret,
        )

    def run(self, gbuf, *, chunks: int = 1):
        """Execute the whole schedule as ONE Pallas kernel launch.

        ``gbuf`` is [nranks, num_slots, *slot] (any array-like; returns
        jnp).  ``chunks > 1`` requires slot row axis divisible by
        ``chunks`` and tiles it over the grid (double-buffered block
        pipeline; bit-identical to ``chunks=1``)."""
        gbuf = jnp.asarray(gbuf)
        n, s = self.nranks, self.num_slots
        if gbuf.shape[:2] != (n, s):
            raise ValueError(
                f"PallasExec.run: buffer [{gbuf.shape}] does not match "
                f"[nranks={n}, num_slots={s}, *slot]")
        slot = gbuf.shape[2:]
        if chunks < 1:
            raise ValueError(f"PallasExec.run: chunks must be >= 1, "
                             f"got {chunks}")
        if chunks > 1:
            if not slot or slot[0] % chunks:
                raise ValueError(
                    f"PallasExec.run: slot row axis {slot[:1]} must "
                    f"divide by chunks={chunks}")
            c = slot[0]
            f = int(math.prod(slot[1:])) if len(slot) > 1 else 1
        else:
            c = 1
            f = int(math.prod(slot)) if slot else 1
        cb = c // chunks
        key = (c, cb, f, gbuf.dtype)
        call = self._jitted.get(key)
        if call is None:
            call = jax.jit(self._build(c, cb, max(f, 1), gbuf.dtype))
            self._jitted[key] = call
        self.launches += 1
        out = call(gbuf.reshape(n, s, c, max(f, 1)))
        return out.reshape((n, s) + slot)


# ---------------------------------------------------------------------------
# process-level cache (persistent-collective init, like executor._CACHE)
# ---------------------------------------------------------------------------


_CACHE: dict[tuple, PallasExec] = {}


def get_pallas_exec(schedule: CommSchedule, *,
                    topo: Topology | None = None,
                    optimize: bool | None = None,
                    interpret: bool | None = None) -> PallasExec:
    """Lower once per (schedule content, optimize, validation flag,
    topology geometry, interpret mode), then reuse forever — the same
    key discipline as ``executor.get_executor`` (whose compiled rounds
    this lowering consumes), plus the interpret flag."""
    ex = get_executor(schedule, optimize=optimize, topo=topo)
    mode = pallas_interpret() if interpret is None else bool(interpret)
    key = (schedule.fingerprint(), ex.optimize,
           validate_schedules_enabled(),
           None if topo is None else topo.fingerprint(), mode)
    pex = _CACHE.get(key)
    if pex is None or pex.ex is not ex:      # executor cache was cleared
        pex = PallasExec(ex, interpret=mode)
        _CACHE[key] = pex
    return pex


def clear_cache() -> None:
    """Drop every lowered Pallas executor (tests; after env flips)."""
    _CACHE.clear()
