"""Topology model for the MPIX layer.

MPI Advance's locality-aware algorithms distinguish intra-node from
inter-node links.  The TPU analogue distinguishes:

  * ICI  — intra-pod links (2D/3D torus inside a v5e pod), ~50 GB/s/link
  * DCN  — inter-pod links (data-center network), ~25 GB/s effective

``Topology`` maps a flat rank id (position along one mesh axis, or the
flattened product of several axes) to a (pod, local) coordinate and
classifies each (src, dst) pair.  It also carries the alpha-beta (postal)
link model used by the selector and the path benchmarks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# Hardware constants (TPU v5e target; see EXPERIMENTS.md).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
DCN_BW = 25e9                   # bytes/s per pod-pair (effective)
ICI_LATENCY = 1e-6              # alpha, seconds per message
DCN_LATENCY = 10e-6


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """alpha-beta postal model for one link class."""

    alpha: float  # latency per message (s)
    beta: float   # seconds per byte (1 / bandwidth)

    def time(self, nbytes: float, nmsgs: int = 1) -> float:
        return nmsgs * self.alpha + nbytes * self.beta


ICI_LINK = LinkModel(alpha=ICI_LATENCY, beta=1.0 / ICI_BW)
DCN_LINK = LinkModel(alpha=DCN_LATENCY, beta=1.0 / DCN_BW)


@dataclasses.dataclass(frozen=True)
class Topology:
    """Locality structure of ``nranks`` ranks grouped into equal pods.

    ranks_per_pod == nranks  -> single-pod (all links ICI).
    """

    nranks: int
    ranks_per_pod: int

    def __post_init__(self):
        if self.nranks <= 0:
            raise ValueError("nranks must be positive")
        if self.ranks_per_pod <= 0 or self.nranks % self.ranks_per_pod:
            raise ValueError(
                f"nranks={self.nranks} not divisible by "
                f"ranks_per_pod={self.ranks_per_pod}")

    # -- coordinates ------------------------------------------------------
    @property
    def npods(self) -> int:
        return self.nranks // self.ranks_per_pod

    def pod(self, rank: int) -> int:
        return rank // self.ranks_per_pod

    def local(self, rank: int) -> int:
        return rank % self.ranks_per_pod

    def rank(self, pod: int, local: int) -> int:
        return pod * self.ranks_per_pod + local

    def pod_ranks(self, pod: int) -> range:
        base = pod * self.ranks_per_pod
        return range(base, base + self.ranks_per_pod)

    # -- identity ----------------------------------------------------------
    def fingerprint(self, device_kind: str = "model") -> str:
        """Substrate identity key for persisted tuning tables.

        ``device_kind`` names the physical substrate the timings were
        taken on (e.g. ``"cpu"``, ``"TPU_v5e"``); the reserved kind
        ``"model"`` marks alpha-beta-model-derived tables.
        """
        kind = str(device_kind).strip().replace(" ", "_").replace(":", "_")
        return f"{kind}:n{self.nranks}:rpp{self.ranks_per_pod}"

    # -- link classification ----------------------------------------------
    def is_local(self, src: int, dst: int) -> bool:
        """True when (src, dst) stay inside one pod (ICI link)."""
        return self.pod(src) == self.pod(dst)

    def link(self, src: int, dst: int) -> LinkModel:
        return ICI_LINK if self.is_local(src, dst) else DCN_LINK

    # -- cost model ---------------------------------------------------------
    def round_time(self, edges: Sequence[tuple[int, int]], nbytes: int) -> float:
        """Model one schedule round: all edges fire concurrently; the round
        costs the max over links, with per-link serialization of multiple
        messages sharing the same directed link class at one src."""
        if not edges:
            return 0.0
        # messages per (src, class) serialize on the src's injection port
        per_port: dict[tuple[int, bool], int] = {}
        for s, d in edges:
            key = (s, self.is_local(s, d))
            per_port[key] = per_port.get(key, 0) + 1
        worst = 0.0
        for (s, local_), n in per_port.items():
            lm = ICI_LINK if local_ else DCN_LINK
            worst = max(worst, lm.time(nbytes * n, nmsgs=n))
        return worst


def flat_topology(nranks: int) -> Topology:
    return Topology(nranks=nranks, ranks_per_pod=nranks)
