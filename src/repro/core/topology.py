"""Topology model for the MPIX layer.

MPI Advance's locality-aware algorithms distinguish intra-node from
inter-node links.  The TPU analogue distinguishes:

  * ICI  — intra-pod links (2D/3D torus inside a v5e pod), ~50 GB/s/link
  * DCN  — inter-pod links (data-center network), ~25 GB/s effective

``Topology`` maps a flat rank id (position along one mesh axis, or the
flattened product of several axes) to coordinates along an ordered
multi-level hierarchy of axes (outermost first, row-major — e.g. a DCN
level above two intra-pod torus axes), classifies each (src, dst) pair
by the outermost level where the coordinates differ, and carries the
alpha-beta (postal) link model per level used by the selector, the
tuner, and the path benchmarks.

Back-compat: the historical two-parameter form ``Topology(nranks,
ranks_per_pod)`` still works and canonicalizes to a 1-level (single
pod, all ICI) or 2-level (DCN over ICI) hierarchy; richer geometries
come from ``Topology.from_levels`` / ``torus_topology`` and round-trip
through ``fingerprint()`` / ``Topology.from_fingerprint``.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Sequence

# Hardware constants (TPU v5e target; see EXPERIMENTS.md).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
DCN_BW = 25e9                   # bytes/s per pod-pair (effective)
ICI_LATENCY = 1e-6              # alpha, seconds per message
DCN_LATENCY = 10e-6


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """alpha-beta postal model for one link class."""

    alpha: float  # latency per message (s)
    beta: float   # seconds per byte (1 / bandwidth)

    def __post_init__(self):
        # Probe fits feed straight into here: a NaN/inf/negative
        # coefficient would silently poison every modeled time and the
        # tuned-table fingerprints derived from it, so reject at the
        # source instead.
        for field in ("alpha", "beta"):
            v = getattr(self, field)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                raise ValueError(f"LinkModel.{field} must be a real "
                                 f"number, got {v!r}")
            if not math.isfinite(v) or v < 0:
                raise ValueError(f"LinkModel.{field} must be finite and "
                                 f">= 0, got {v!r}")
            object.__setattr__(self, field, float(v))

    def time(self, nbytes: float, nmsgs: int = 1) -> float:
        return nmsgs * self.alpha + nbytes * self.beta


ICI_LINK = LinkModel(alpha=ICI_LATENCY, beta=1.0 / ICI_BW)
DCN_LINK = LinkModel(alpha=DCN_LATENCY, beta=1.0 / DCN_BW)


@dataclasses.dataclass(frozen=True)
class TopoLevel:
    """One axis of the rank hierarchy (outermost-first, row-major).

    ``dcn=True`` marks an inter-pod level: ranks that differ in any DCN
    coordinate are in different pods.  DCN levels must form an outermost
    prefix of the hierarchy (pods contain torus axes, never vice versa).
    """

    name: str
    size: int
    link: LinkModel = ICI_LINK
    dcn: bool = False

    def __post_init__(self):
        if self.size <= 0:
            raise ValueError(f"level {self.name!r} size must be positive")
        # no "-" (the fingerprint name/size separator), ".", ":" or "]"
        if not re.fullmatch(r"[A-Za-z_][A-Za-z0-9_]*", self.name):
            raise ValueError(f"invalid level name {self.name!r}")


def _inferred_level(name: str, size: int) -> TopoLevel:
    """The level a bare ``name-size`` axis spec decodes to: the ``dcn``
    name prefix selects the DCN class, everything else ICI."""
    dcn = name.startswith("dcn")
    return TopoLevel(name, size, DCN_LINK if dcn else ICI_LINK, dcn)


def _default_levels(nranks: int, ranks_per_pod: int) -> tuple[TopoLevel, ...]:
    """Canonical hierarchy for the historical (nranks, ranks_per_pod)."""
    if ranks_per_pod == nranks:
        return (TopoLevel("ici", nranks, ICI_LINK),)
    return (TopoLevel("dcn", nranks // ranks_per_pod, DCN_LINK, dcn=True),
            TopoLevel("ici", ranks_per_pod, ICI_LINK))


@dataclasses.dataclass(frozen=True)
class Topology:
    """Locality structure of ``nranks`` ranks over an ordered hierarchy.

    ``Topology(nranks, ranks_per_pod)`` — historical 2-parameter form;
    ``ranks_per_pod == nranks`` -> single-pod (all links ICI).
    ``Topology.from_levels(...)``   — explicit multi-level geometry.
    """

    nranks: int
    ranks_per_pod: int
    levels: tuple[TopoLevel, ...] = ()

    def __post_init__(self):
        if self.nranks <= 0:
            raise ValueError("nranks must be positive")
        if self.ranks_per_pod <= 0 or self.nranks % self.ranks_per_pod:
            raise ValueError(
                f"nranks={self.nranks} not divisible by "
                f"ranks_per_pod={self.ranks_per_pod}")
        if not self.levels:
            object.__setattr__(
                self, "levels",
                _default_levels(self.nranks, self.ranks_per_pod))
        levels = tuple(self.levels)
        object.__setattr__(self, "levels", levels)
        if math.prod(lv.size for lv in levels) != self.nranks:
            raise ValueError(
                f"level sizes {[lv.size for lv in levels]} do not "
                f"multiply to nranks={self.nranks}")
        seen_local = False
        intra = 1
        for lv in levels:
            if lv.dcn and seen_local:
                raise ValueError(
                    "DCN levels must form an outermost prefix of the "
                    f"hierarchy, got {[(l.name, l.dcn) for l in levels]}")
            seen_local = seen_local or not lv.dcn
            if not lv.dcn:
                intra *= lv.size
        if intra != self.ranks_per_pod:
            raise ValueError(
                f"intra-pod level sizes multiply to {intra}, but "
                f"ranks_per_pod={self.ranks_per_pod}")

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_levels(cls, levels: Sequence[TopoLevel | tuple]) -> "Topology":
        """Build from an outermost-first axis list.

        Entries are ``TopoLevel``s or ``(name, size)`` tuples; tuple
        entries named ``"dcn"`` (or prefixed ``"dcn"``) become DCN
        levels with the DCN link model, everything else ICI.
        """
        lvls = []
        for lv in levels:
            if not isinstance(lv, TopoLevel):
                name, size = lv
                dcn = str(name).startswith("dcn")
                lvls.append(TopoLevel(str(name), int(size),
                                      DCN_LINK if dcn else ICI_LINK, dcn))
            else:
                lvls.append(lv)
        n = math.prod(lv.size for lv in lvls)
        rpp = math.prod(lv.size for lv in lvls if not lv.dcn)
        return cls(nranks=n, ranks_per_pod=rpp, levels=tuple(lvls))

    # -- coordinates ------------------------------------------------------
    @property
    def npods(self) -> int:
        return self.nranks // self.ranks_per_pod

    def pod(self, rank: int) -> int:
        return rank // self.ranks_per_pod

    def local(self, rank: int) -> int:
        return rank % self.ranks_per_pod

    def rank(self, pod: int, local: int) -> int:
        return pod * self.ranks_per_pod + local

    def pod_ranks(self, pod: int) -> range:
        base = pod * self.ranks_per_pod
        return range(base, base + self.ranks_per_pod)

    def coords(self, rank: int) -> tuple[int, ...]:
        """Mixed-radix decode of ``rank`` along levels (outermost first)."""
        out = []
        for lv in reversed(self.levels):
            out.append(rank % lv.size)
            rank //= lv.size
        return tuple(reversed(out))

    def rank_of(self, coords: Sequence[int]) -> int:
        r = 0
        for lv, c in zip(self.levels, coords):
            r = r * lv.size + c
        return r

    # -- identity ----------------------------------------------------------
    def fingerprint(self, device_kind: str = "model") -> str:
        """Substrate identity key for persisted tuning tables.

        ``device_kind`` names the physical substrate the timings were
        taken on (e.g. ``"cpu"``, ``"TPU_v5e"``); the reserved kind
        ``"model"`` marks alpha-beta-model-derived tables.  Canonical
        1/2-level topologies keep the historical ``kind:nN:rppR`` form;
        richer hierarchies append the per-axis geometry, e.g.
        ``model:n32:rpp16:lv[dcn-2.torus_y-4.torus_x-4]``.

        Levels whose link model or DCN flag cannot be re-inferred from
        the axis name (a custom alpha-beta model, or a dcn flag that
        disagrees with the ``dcn`` name prefix) additionally emit a
        ``lm[i=alpha/beta/dcn;...]`` section so the fingerprint stays a
        loss-free geometry encoding (``from_fingerprint`` round-trips).
        """
        kind = str(device_kind).strip().replace(" ", "_").replace(":", "_")
        base = f"{kind}:n{self.nranks}:rpp{self.ranks_per_pod}"
        if self.levels == _default_levels(self.nranks, self.ranks_per_pod):
            return base
        axes = ".".join(f"{lv.name}-{lv.size}" for lv in self.levels)
        out = f"{base}:lv[{axes}]"
        custom = []
        for i, lv in enumerate(self.levels):
            if lv != _inferred_level(lv.name, lv.size):
                custom.append(f"{i}={lv.link.alpha!r}/{lv.link.beta!r}/"
                              f"{int(lv.dcn)}")
        if custom:
            out += f":lm[{';'.join(custom)}]"
        return out

    @classmethod
    def from_fingerprint(cls, fingerprint: str) -> "Topology":
        """Recover the geometry a ``fingerprint()`` string encodes.

        Link models and DCN flags are restored from the level class
        (``dcn`` name prefix vs ICI) unless the fingerprint carries an
        explicit ``lm[...]`` override section (non-default link models).
        """
        m = re.fullmatch(
            r"[^:]+:n(\d+):rpp(\d+)"
            r"(?::lv\[([^\]]+)\])?(?::lm\[([^\]]+)\])?", fingerprint)
        if not m:
            raise ValueError(f"unparseable topology fingerprint "
                             f"{fingerprint!r}")
        n, rpp, axes, lm = (int(m.group(1)), int(m.group(2)),
                            m.group(3), m.group(4))
        if axes is None:
            if lm is not None:
                raise ValueError(f"lm section without lv section in "
                                 f"{fingerprint!r}")
            return cls(nranks=n, ranks_per_pod=rpp)
        overrides = {}
        for part in (lm.split(";") if lm else ()):
            om = re.fullmatch(r"(\d+)=([^/]+)/([^/]+)/([01])", part)
            if not om:
                raise ValueError(f"bad link spec {part!r} in "
                                 f"{fingerprint!r}")
            overrides[int(om.group(1))] = (
                LinkModel(alpha=float(om.group(2)),
                          beta=float(om.group(3))),
                bool(int(om.group(4))))
        levels = []
        for i, part in enumerate(axes.split(".")):
            am = re.fullmatch(r"([A-Za-z_][A-Za-z0-9_]*)-(\d+)", part)
            if not am:
                raise ValueError(f"bad axis spec {part!r} in {fingerprint!r}")
            name, size = am.group(1), int(am.group(2))
            if i in overrides:
                link, dcn = overrides.pop(i)
                levels.append(TopoLevel(name, size, link, dcn))
            else:
                levels.append(_inferred_level(name, size))
        if overrides:
            raise ValueError(
                f"lm indices {sorted(overrides)} out of range for "
                f"{len(levels)} levels in {fingerprint!r}")
        return cls(nranks=n, ranks_per_pod=rpp, levels=tuple(levels))

    # -- link classification ----------------------------------------------
    def link_level(self, src: int, dst: int) -> int:
        """Index of the outermost level where src and dst differ
        (innermost level if equal — an on-chip/self link)."""
        cs, cd = self.coords(src), self.coords(dst)
        for i, (a, b) in enumerate(zip(cs, cd)):
            if a != b:
                return i
        return len(self.levels) - 1

    def is_local(self, src: int, dst: int) -> bool:
        """True when (src, dst) stay inside one pod (no DCN crossing)."""
        return self.pod(src) == self.pod(dst)

    def link(self, src: int, dst: int) -> LinkModel:
        return self.levels[self.link_level(src, dst)].link

    # -- cost model ---------------------------------------------------------
    def round_time(self, edges: Sequence[tuple[int, int]],
                   nbytes) -> float:
        """Model one schedule round: all edges fire concurrently; the round
        costs the max over links, with per-link serialization of multiple
        messages sharing the same (src, level) injection port.

        ``nbytes`` is a scalar (same payload on every edge) or a
        per-edge sequence aligned with ``edges``.  Self-edges are
        on-chip copies and cost nothing.
        """
        edges = list(edges)
        if not edges:
            return 0.0
        per_edge = ([float(b) for b in nbytes]
                    if hasattr(nbytes, "__len__")
                    else [float(nbytes)] * len(edges))
        # messages per (src, level) serialize on the src's injection port
        per_port: dict[tuple[int, int], tuple[int, float]] = {}
        for (s, d), b in zip(edges, per_edge):
            if s == d:
                continue
            key = (s, self.link_level(s, d))
            n, tot = per_port.get(key, (0, 0.0))
            per_port[key] = (n + 1, tot + b)
        worst = 0.0
        for (s, lvl), (n, tot) in per_port.items():
            worst = max(worst, self.levels[lvl].link.time(tot, nmsgs=n))
        return worst


def flat_topology(nranks: int) -> Topology:
    return Topology(nranks=nranks, ranks_per_pod=nranks)


def torus_topology(npods: int, *axis_sizes: int,
                   axis_names: Sequence[str] | None = None) -> Topology:
    """Multi-level helper: ``npods`` pods over DCN, each an N-D torus of
    ``axis_sizes`` (outermost first) over ICI, e.g.
    ``torus_topology(2, 4, 4)`` = 2 pods of a 4x4 torus (32 ranks)."""
    names = (list(axis_names) if axis_names is not None
             else [f"torus_{'xyzw'[len(axis_sizes) - 1 - i]}"
                   for i in range(len(axis_sizes))])
    if len(names) != len(axis_sizes):
        raise ValueError("axis_names must match axis_sizes")
    levels: list[TopoLevel] = []
    if npods > 1:
        levels.append(TopoLevel("dcn", npods, DCN_LINK, dcn=True))
    levels += [TopoLevel(nm, sz, ICI_LINK)
               for nm, sz in zip(names, axis_sizes)]
    return Topology.from_levels(levels)
