"""Ragged KV-cache block transfers on the unified IR (serving PR).

Disaggregated serving moves paged KV-cache blocks from the prefill pool
to the decode pool: a sparse, ragged, recurring exchange — exactly the
neighborhood-collective shape the paper's persistent plans target
(``MPIX_Neighbor_alltoallv_init``).  This module compiles a batch of
*block moves* into a ``NeighborPlan`` on the gather-permute-scatter IR:

  * each move ships one block row ``(src rank, src row) -> (dst rank,
    dst row)``; the per-edge row indices become the ragged
    (payload-bearing) alltoallv plan;
  * a block needed by several decode ranks (shared prompt prefixes)
    appears on several edges — locality-aware aggregation
    (``build_plan(aggregate=True)``) ships it across DCN once per pod
    pair and fans out on ICI, the Collom et al. optimization;
  * ``aggregate=None`` resolves standard-vs-locality-aware through the
    selection policy ladder (``policy="tuned"`` reads the persisted
    ``TunedTable`` winner for this topology and volume);
  * the compiled ``CommSchedule`` is eligible for every transport
    (sim / shardmap / pallas) and for the ``resilience=`` recovery
    ladder, like any other collective.

Both plan modes land received blocks in the identical recv layout, so
the ``landing`` map (recv row -> decode pool row) is mode-independent
and ``gather_oracle`` is the bit-exactness oracle for every transport.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core.plan import ELEM_BYTES, CommGraph, NeighborPlan, build_plan
from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class BlockMove:
    """One KV block's journey: src pool row -> dst pool row."""

    src: int        # prefill rank
    src_row: int    # block row in src's pool
    dst: int        # decode rank
    dst_row: int    # block row in dst's pool


@dataclasses.dataclass(frozen=True, eq=False)
class KVTransferPlan:
    """A compiled batch of block moves (thin wrapper over NeighborPlan).

    ``landing[d]`` is an ``[k, 2]`` array of ``(recv_row, dst_row)``
    pairs mapping rank d's recv segment rows (plan layout: segments
    ordered by source rank, move order within an edge) to decode-pool
    block rows.
    """

    plan: NeighborPlan
    moves: tuple[BlockMove, ...]
    landing: dict[int, np.ndarray]
    blocks_per_rank: int
    block_bytes: int

    @property
    def schedule(self):
        return self.plan.schedule

    @property
    def topo(self) -> Topology:
        return self.plan.topo

    @property
    def nbytes(self) -> int:
        """Payload bytes the request set asked for (moves x block)."""
        return len(self.moves) * self.block_bytes

    def traffic(self) -> dict:
        """Wire accounting of the *chosen* plan (DCN/ICI bytes+msgs)."""
        return self.plan.traffic(elem_bytes=self.block_bytes)

    def modeled_time(self) -> float:
        return self.plan.modeled_time(elem_bytes=self.block_bytes)


def build_transfer_plan(moves: Sequence[BlockMove], topo: Topology, *,
                        blocks_per_rank: int,
                        aggregate: bool | None = None,
                        policy: str | None = None,
                        block_bytes: int = ELEM_BYTES) -> KVTransferPlan:
    """Compile one batch of block moves into a persistent ragged plan.

    Validates the move set (prefill/decode pools are disjoint so
    ``src != dst``; no two moves may land on the same destination row),
    groups moves into graph edges with stable order, and delegates mode
    selection to ``build_plan`` (``aggregate=None`` = policy ladder).
    """
    if not moves:
        raise ValueError("build_transfer_plan: empty move batch")
    seen_dst: set[tuple[int, int]] = set()
    edge_moves: dict[tuple[int, int], list[BlockMove]] = {}
    for m in moves:
        if m.src == m.dst:
            raise ValueError(f"move {m} stays on one rank; local block "
                             f"copies don't need a transfer plan")
        if not (0 <= m.src_row < blocks_per_rank
                and 0 <= m.dst_row < blocks_per_rank):
            raise ValueError(f"move {m} outside pool of "
                             f"{blocks_per_rank} blocks")
        if (m.dst, m.dst_row) in seen_dst:
            raise ValueError(f"two moves land on dst row "
                             f"({m.dst}, {m.dst_row})")
        seen_dst.add((m.dst, m.dst_row))
        edge_moves.setdefault((m.src, m.dst), []).append(m)
    edges = {k: np.array([m.src_row for m in v], np.int64)
             for k, v in edge_moves.items()}
    graph = CommGraph(nranks=topo.nranks,
                      local_sizes=(blocks_per_rank,) * topo.nranks,
                      edges=edges)
    plan = build_plan(graph, topo, aggregate=aggregate, policy=policy,
                      elem_bytes=block_bytes)
    # recv layout is identical across plan modes: segments ordered by
    # source rank, rows in edge (= move) order -> landing is mode-free
    landing: dict[int, np.ndarray] = {}
    for d in range(topo.nranks):
        pos, pairs = 0, []
        for s, idx in graph.recv_layout(d):
            for j, m in enumerate(edge_moves[(s, d)]):
                pairs.append((pos + j, m.dst_row))
            pos += len(idx)
        if pairs:
            landing[d] = np.asarray(pairs, np.int64)
    return KVTransferPlan(plan=plan, moves=tuple(moves), landing=landing,
                          blocks_per_rank=blocks_per_rank,
                          block_bytes=block_bytes)


def gather_oracle(moves: Sequence[BlockMove], pool: np.ndarray
                  ) -> dict[int, tuple[np.ndarray, np.ndarray]]:
    """Direct-indexing reference: what each decode rank must receive.

    ``pool`` is the global block pool ``[nranks, blocks_per_rank,
    *block]``; returns per-dst ``(dst_rows, values)`` sorted by dst
    row — the oracle every transport's result must match bitwise.
    """
    per_dst: dict[int, list[BlockMove]] = {}
    for m in moves:
        per_dst.setdefault(m.dst, []).append(m)
    out = {}
    for d, ms in per_dst.items():
        ms = sorted(ms, key=lambda m: m.dst_row)
        rows = np.array([m.dst_row for m in ms], np.int64)
        vals = np.stack([pool[m.src, m.src_row] for m in ms])
        out[d] = (rows, vals)
    return out


@dataclasses.dataclass(frozen=True, eq=False)
class TransferResult:
    """One executed transfer batch: per-dst updates + telemetry."""

    updates: dict[int, tuple[np.ndarray, np.ndarray]]  # dst -> (rows, vals)
    seconds: float
    nbytes: int
    plan_name: str
    report: object = None        # DegradationReport when resilience armed


def run_transfer(tp: KVTransferPlan, pool: np.ndarray, *,
                 transport: str = "sim", resilience=None,
                 transports: dict | None = None) -> TransferResult:
    """Execute the plan's schedule on the global block pool.

    ``pool`` is ``[nranks, blocks_per_rank, *block]`` (prefill ranks'
    rows hold the blocks to ship).  ``transport`` picks the substrate
    — ``sim`` (vectorized host), ``reference`` (rank-by-rank oracle
    loop), ``shardmap`` (needs nranks devices), ``pallas`` (single
    kernel).  With ``resilience=`` armed the run goes through
    ``ResilientExec`` instead — verify/retry/fallback ladder, chaos
    injectable via ``transports={rung: wrapped}``.
    """
    from repro.core.transport import (PallasTransport, ShardMapTransport,
                                      SimTransport)

    sched, topo, n = tp.schedule, tp.topo, tp.topo.nranks
    assert pool.shape[0] == n and pool.shape[1] == tp.blocks_per_rank, \
        (pool.shape, n, tp.blocks_per_rank)
    feat = pool.shape[2:]
    gbuf = np.zeros((n, sched.num_slots) + feat, pool.dtype)
    gbuf[:, : tp.blocks_per_rank] = pool
    report = None
    t0 = time.perf_counter()
    if resilience is not None:
        from repro.core.resilient import ResilientExec, resolve_resilience
        ropts = resolve_resilience(resilience)
        ex = ResilientExec(sched, topo, options=ropts,
                           transports=transports or {})
        out, report = ex.run(gbuf)
        out = np.asarray(out)
    elif transport == "sim":
        out = SimTransport(n, topo=topo).run(sched, gbuf)
    elif transport == "reference":
        out = SimTransport(n, topo=topo).run_reference(sched, gbuf)
    elif transport == "shardmap":
        out = np.asarray(
            ShardMapTransport(n, "_kv", topo=topo).run_global(sched, gbuf))
    elif transport == "pallas":
        out = np.asarray(
            PallasTransport(n, topo=topo).run_global(sched, gbuf))
    else:
        raise ValueError(f"unknown transport {transport!r}; expected "
                         f"sim | reference | shardmap | pallas")
    seconds = time.perf_counter() - t0
    updates: dict[int, tuple[np.ndarray, np.ndarray]] = {}
    for d, land in tp.landing.items():
        off = tp.plan.recv_offsets[d]
        recv = np.asarray(out)[d, off: off + tp.plan.recv_sizes[d]]
        order = np.argsort(land[:, 1], kind="stable")
        updates[d] = (land[order, 1].copy(), recv[land[order, 0]])
    return TransferResult(updates=updates, seconds=seconds,
                          nbytes=tp.nbytes, plan_name=tp.plan.name,
                          report=report)


def verify_bitwise(tp: KVTransferPlan, pool: np.ndarray,
                   result: TransferResult) -> bool:
    """True iff ``result`` matches the gather oracle byte-for-byte."""
    want = gather_oracle(tp.moves, pool)
    if sorted(want) != sorted(result.updates):
        return False
    for d, (rows, vals) in want.items():
        got_rows, got_vals = result.updates[d]
        if (rows.tobytes() != got_rows.tobytes()
                or np.ascontiguousarray(vals).tobytes()
                != np.ascontiguousarray(got_vals).tobytes()):
            return False
    return True


def apply_updates(result: TransferResult, pool: np.ndarray) -> None:
    """Land received blocks into the destination rows of ``pool``."""
    for d, (rows, vals) in result.updates.items():
        pool[d, rows] = vals
