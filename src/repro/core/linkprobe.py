"""Wire-measured link models: probe the fabric, not the datasheet.

The tuned tables and the armed/pipelined executor passes price rounds
with the per-level alpha-beta ``LinkModel``s carried by ``Topology`` —
which, until this module, were the ICI/DCN *datasheet constants*
whenever the host could not measure (and even measured tables kept the
model constants inside the executor's cost passes).  The collective-
tuning literature is unambiguous that this is the gap: offline-tuned
tables go stale the moment the fabric degrades (Wickramasinghe &
Lumsdaine's survey names online re-measurement as the open problem;
Hunold's guideline verification gives the repair loop a trigger).

This module is the measurement pass:

  * ``pingpong_schedule`` / ``injection_schedule`` — tiny probe
    ``CommSchedule``s per topology level, built from the same
    ``make_round`` IR every collective uses, so probes execute through
    the existing transports (ShardMap on a live mesh, alpha-beta
    pricing otherwise) and measure exactly the path real rounds take.
  * ``fit_link_model`` — least-squares (alpha, beta) from (nbytes,
    seconds) samples, rejecting non-finite/negative fits at the source.
  * ``probe_links`` — run the probes over a size sweep per level and
    fit one ``LinkModel`` per level; ``measured_topology`` rebuilds the
    ``Topology`` around the fitted links, so ``fingerprint()`` emits
    the ``lm[...]`` override section and every tuned table / executor
    cache entry derived from it is keyed by *measured* geometry.
  * ``drifted_levels`` — noise-tolerant drift detection between two
    probe passes (the ratio rule ``tuner._cell_differs`` uses), the
    trigger for the online healing daemon (runtime.tuning_daemon).

Timers are injectable: ``timer(level, nbytes) -> seconds`` for one
one-way single-message transfer.  ``wire_timer`` measures through
ShardMapTransport; ``model_timer`` prices the same probe schedules
from the alpha-beta model (optionally through a fault injector that
degrades specific levels — the deterministic substrate for drift
tests and the CI healing leg).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import math
from typing import Callable, Mapping, Sequence

import jax

from repro.core.schedule import CommSchedule, make_round
from repro.core.topology import LinkModel, TopoLevel, Topology

# Per-rank probe payloads: one alpha-dominated size and one
# beta-dominated size pin both coefficients of the postal model.
DEFAULT_PROBE_SIZES = (1 << 10, 1 << 20)
_ELEM = 4                        # probe payloads are float32

Timer = Callable[[int, int], float]


# ---------------------------------------------------------------------------
# probe schedules (the unified IR; executed by the shared transports)
# ---------------------------------------------------------------------------


def _level_peer(topo: Topology, level: int, step: int = 1) -> int:
    """Rank differing from rank 0 only at ``level`` (coordinate =
    ``step``) — the canonical single-link partner for that level."""
    coords = [0] * len(topo.levels)
    coords[level] = step
    return topo.rank_of(coords)


def pingpong_schedule(topo: Topology, level: int) -> CommSchedule:
    """Two-round RTT probe across one link of ``level``: rank 0 sends
    slot 0 to its level peer, the peer sends it back.  Half the
    schedule time is one one-way single-message transfer — the classic
    ping-pong microbenchmark, expressed in the collective IR so it
    executes through the exact transport path real rounds take."""
    if not 0 <= level < len(topo.levels):
        raise ValueError(f"level {level} out of range for "
                         f"{len(topo.levels)} levels")
    if topo.levels[level].size < 2:
        raise ValueError(
            f"level {topo.levels[level].name!r} has size "
            f"{topo.levels[level].size}; nothing to probe")
    peer = _level_peer(topo, level)
    n = topo.nranks
    out = make_round(n, [(0, peer)], {0: [0]}, {peer: [0]})
    back = make_round(n, [(peer, 0)], {peer: [0]}, {0: [0]})
    return CommSchedule(
        nranks=n, num_slots=1, rounds=(out, back),
        name=f"probe_pingpong_{topo.levels[level].name}")


def injection_schedule(topo: Topology, level: int,
                       fanout: int = 4) -> CommSchedule:
    """Injection-rate probe: rank 0 ships slot 0 to ``fanout`` distinct
    level peers in consecutive rounds, serializing ``fanout`` messages
    on its injection port.  Each round is one one-way transfer, so the
    schedule contributes ``fanout`` per-message observations to the fit
    (alpha shows up ``fanout`` times — the robust way to pin latency
    without a sub-microsecond clock)."""
    if topo.levels[level].size < 2:
        raise ValueError(
            f"level {topo.levels[level].name!r} has size "
            f"{topo.levels[level].size}; nothing to probe")
    fanout = min(int(fanout), topo.levels[level].size - 1)
    if fanout < 1:
        raise ValueError(f"fanout must be >= 1, got {fanout}")
    n = topo.nranks
    rounds = []
    for i in range(fanout):
        peer = _level_peer(topo, level, step=i + 1)
        rounds.append(make_round(n, [(0, peer)], {0: [0]}, {peer: [0]}))
    return CommSchedule(
        nranks=n, num_slots=1, rounds=tuple(rounds),
        name=f"probe_injection_{topo.levels[level].name}_f{fanout}")


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------


def fit_link_model(samples: Sequence[tuple[float, float]]) -> LinkModel:
    """Least-squares ``(alpha, beta)`` from ``(nbytes, seconds)``
    one-way single-message observations.

    Probe data feeds persisted fingerprints and every cost model
    downstream, so a degenerate fit fails loud instead of propagating:
    fewer than two distinct sizes, non-finite inputs, or a fitted
    coefficient that is negative or non-finite (a clock that ran
    backwards, an overflowed sample) all raise ``ValueError`` — and
    ``LinkModel.__post_init__`` independently enforces the same
    invariant for models constructed anywhere else.
    """
    if len(samples) < 2:
        raise ValueError(f"need >= 2 probe samples, got {len(samples)}")
    xs = [float(s) for s, _ in samples]
    ys = [float(t) for _, t in samples]
    if not all(math.isfinite(v) for v in xs + ys):
        raise ValueError(f"non-finite probe samples: {samples!r}")
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    if sxx == 0:
        raise ValueError(
            f"probe sizes must span >= 2 distinct values, got {xs!r}")
    beta = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / sxx
    alpha = my - beta * mx
    if not (math.isfinite(alpha) and math.isfinite(beta)):
        raise ValueError(f"non-finite fit alpha={alpha!r} beta={beta!r}")
    if alpha < 0 or beta < 0:
        raise ValueError(
            f"negative fit alpha={alpha:.3e} beta={beta:.3e} from "
            f"{samples!r} (noise larger than the signal; widen the "
            f"size sweep or raise repeats)")
    return LinkModel(alpha=alpha, beta=beta)


# ---------------------------------------------------------------------------
# timers
# ---------------------------------------------------------------------------


def model_timer(topo: Topology, fault=None) -> Timer:
    """Deterministic alpha-beta timer: prices the probe's one-way
    transfer from the level's link model, optionally through a fault
    injector (any object with ``apply(level_index, link) -> LinkModel``
    — see ``runtime.fault.LinkFault``).  This is the substrate for
    drift tests: degrade a level in the injector and the probe pass
    observes exactly that degradation, nothing else."""
    def timer(level: int, nbytes: int) -> float:
        link = topo.levels[level].link
        if fault is not None:
            link = fault.apply(level, link)
        return link.time(float(nbytes))
    return timer


def wire_timer(topo: Topology, *, repeats: int = 3) -> Timer:
    """Wall-clock timer: executes the ping-pong probe schedule through
    ShardMapTransport under jit on the live mesh and returns half the
    best-of-``repeats`` RTT.  Requires >= ``topo.nranks`` devices."""
    from repro.core.tuner import measure_schedule

    scheds: dict[int, CommSchedule] = {}

    def timer(level: int, nbytes: int) -> float:
        if level not in scheds:
            scheds[level] = pingpong_schedule(topo, level)
        rtt = measure_schedule(
            scheds[level], topo,
            slot_elems=max(1, int(nbytes) // _ELEM), repeats=repeats)
        return rtt / 2.0
    return timer


def wire_available(topo: Topology) -> bool:
    """True when the host can measure (enough devices for the mesh)."""
    return jax.device_count() >= topo.nranks


class ProbeTimeout(RuntimeError):
    """One level's probe overran its deadline (a hung link, an injected
    chaos stall).  ``probe_links`` converts this into a recorded skip —
    the level keeps its prior link model — so a wedged wire can never
    wedge the tuning daemon with it."""


def _with_deadline(fn, deadline_s: float | None, what: str):
    """Run ``fn()`` with a hard wall-clock bound: the call executes on a
    worker thread and ``TimeoutError`` at the deadline becomes a typed
    ``ProbeTimeout`` — the caller regains control even while the probe
    is still blocked inside the substrate.  The abandoned worker is
    detached (``shutdown(wait=False)``); a probe that eventually
    returns finishes quietly on a dead-end thread."""
    if deadline_s is None:
        return fn()
    if deadline_s <= 0:
        raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
    pool = concurrent.futures.ThreadPoolExecutor(
        max_workers=1, thread_name_prefix="repro-probe")
    fut = pool.submit(fn)
    try:
        return fut.result(timeout=deadline_s)
    except concurrent.futures.TimeoutError:
        raise ProbeTimeout(
            f"{what} exceeded deadline {deadline_s:.3f}s") from None
    finally:
        pool.shutdown(wait=False)


# ---------------------------------------------------------------------------
# the probe pass
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ProbeResult:
    """One measurement pass over every probeable level.

    models:  level index -> fitted ``LinkModel`` (levels that could not
             be probed — size 1, or a rejected fit under
             ``strict=False`` — keep their prior link and are absent).
    samples: level index -> tuple of (nbytes, seconds) observations.
    source:  "wire" (measured on the live mesh) or "model" (priced).
    skipped: level indices left on their prior link, with the reason.
    """

    models: Mapping[int, LinkModel]
    samples: Mapping[int, tuple]
    source: str
    skipped: Mapping[int, str] = dataclasses.field(default_factory=dict)


def probe_links(topo: Topology, *, sizes=DEFAULT_PROBE_SIZES,
                repeats: int = 3, fanout: int = 2,
                timer: Timer | None = None,
                strict: bool = False,
                deadline_s: float | None = None) -> ProbeResult:
    """Probe every topology level and fit a ``LinkModel`` per level.

    Per level: one ping-pong observation per probe size, plus
    ``fanout`` injection-normalized observations at the smallest size
    (each round of the injection schedule is one more one-way sample).
    ``timer`` defaults to the wire timer when the host has enough
    devices, else the deterministic model timer — mirroring the
    measured-vs-model split the tuner already makes.

    ``strict=False`` (the launcher default) keeps a level's prior link
    when its fit is rejected (noisy host clocks can produce a negative
    alpha on a short sweep) and records the reason in ``skipped``;
    ``strict=True`` re-raises — the mode tests use to assert rejection.

    ``deadline_s`` bounds each LEVEL's whole observation sweep on a
    worker thread (``_with_deadline``): a hung wire raises
    ``ProbeTimeout`` internally, the level keeps its prior link, and
    the timeout is recorded in ``skipped`` — under ``strict=True`` it
    re-raises like a rejected fit.  Without it a single wedged link
    would hang ``TuningDaemon.tick`` (and any serving loop that calls
    it) forever.
    """
    if timer is None:
        source = "wire" if wire_available(topo) else "model"
        timer = (wire_timer(topo, repeats=repeats) if source == "wire"
                 else model_timer(topo))
    else:
        source = "custom"
    sizes = tuple(int(s) for s in sizes)
    if len(set(sizes)) < 2:
        raise ValueError(f"need >= 2 distinct probe sizes, got {sizes!r}")
    models: dict[int, LinkModel] = {}
    samples: dict[int, tuple] = {}
    skipped: dict[int, str] = {}
    for i, lv in enumerate(topo.levels):
        if lv.size < 2:
            skipped[i] = "size-1 level (no link to probe)"
            continue

        def observe(i=i, lv=lv):
            obs = [(float(s), timer(i, s)) for s in sizes]
            # injection rounds at the smallest size: fanout more
            # observations of the same one-way transfer (alpha-weighted)
            eff_fanout = min(int(fanout), lv.size - 1)
            obs += [(float(min(sizes)), timer(i, min(sizes)))
                    for _ in range(max(0, eff_fanout - 1))]
            return obs

        try:
            obs = _with_deadline(observe, deadline_s,
                                 f"probe of level {lv.name!r}")
        except ProbeTimeout as e:
            if strict:
                raise
            skipped[i] = f"{e} (kept prior link)"
            continue
        samples[i] = tuple(obs)
        try:
            models[i] = fit_link_model(obs)
        except ValueError as e:
            if strict:
                raise
            skipped[i] = str(e)
    return ProbeResult(models=models, samples=samples, source=source,
                       skipped=skipped)


def measured_topology(topo: Topology, probe: ProbeResult | None = None,
                      **probe_kwargs) -> Topology:
    """Rebuild ``topo`` with probed link models substituted per level.

    Names, sizes, and DCN flags are untouched — only the alpha-beta
    coefficients change — so the geometry stays identical while
    ``fingerprint()`` now emits the ``lm[...]`` override section for
    every measured level: tuned tables and executor-cache entries
    become keyed by measured geometry, which is the whole point.
    """
    if probe is None:
        probe = probe_links(topo, **probe_kwargs)
    levels = tuple(
        TopoLevel(lv.name, lv.size, probe.models.get(i, lv.link), lv.dcn)
        for i, lv in enumerate(topo.levels))
    return Topology(nranks=topo.nranks, ranks_per_pod=topo.ranks_per_pod,
                    levels=levels)


# ---------------------------------------------------------------------------
# drift detection (the healing daemon's trigger)
# ---------------------------------------------------------------------------


def _coeff_drifted(fresh: float, rec: float, tol: float) -> bool:
    """The ``tuner._cell_differs`` ratio rule applied to one link
    coefficient: drifted iff it moved beyond the relative slack in
    either direction.  Coefficients at exactly 0 only match 0."""
    if fresh == rec:
        return False
    if fresh == 0 or rec == 0:
        return True
    return fresh > rec * tol or rec > fresh * tol


def drifted_levels(old: Topology, new: Topology, *,
                   tol: float = 1.25) -> list[int]:
    """Level indices whose link model moved beyond the noise tolerance
    between two probe passes (alpha or beta, ratio rule).  A geometry
    change (different level structure) is not drift — that is a remesh
    and raises so callers never silently compare unlike hierarchies."""
    if [(lv.name, lv.size, lv.dcn) for lv in old.levels] != \
            [(lv.name, lv.size, lv.dcn) for lv in new.levels]:
        raise ValueError(
            f"geometry changed ({old.fingerprint()} -> "
            f"{new.fingerprint()}); use the elastic remesh path, "
            f"not drift healing")
    out = []
    for i, (a, b) in enumerate(zip(old.levels, new.levels)):
        if (_coeff_drifted(b.link.alpha, a.link.alpha, tol)
                or _coeff_drifted(b.link.beta, a.link.beta, tol)):
            out.append(i)
    return out
