"""Persistent neighborhood-collective plans (paper §2.2).

MPI Advance's persistent neighborhood collectives hoist all setup of a
sparse exchange (``MPI_Dist_graph_create_adjacent`` +
``MPIX_Neighbor_alltoallv_init``) into a one-time *plan*, then add a
locality-aware extension: user-supplied unique indices let the library
ship each value across a node boundary once, no matter how many ranks on
the far side need it, and aggregate many small inter-node messages into
one per node pair.

TPU adaptation: the plan is compiled in Python to the same unified
gather-permute-scatter IR the dense collectives use (``CommRound`` /
``CommSchedule``, see schedule.py) and executed by the shared
``SimTransport`` / ``ShardMapTransport`` backends — there are no
neighbor-specific executors.  Two build modes:

  * ``aggregate=False`` — standard: one message per graph edge, rounds
    formed by greedy edge coloring (each round is a partial permutation,
    as ``ppermute`` requires).
  * ``aggregate=True``  — locality-aware: 3 phases.
      A) intra-pod: each source forwards, per remote pod q, the *unique*
         values any rank of q needs to a designated local aggregator
         (striped across the pod by q),
      B) inter-pod: one aggregated DCN message per (src pod, dst pod)
         carried between the stripe aggregators,
      C) intra-pod: the receiving aggregator fans values out to final
         destinations (duplication happens on fast ICI links only).
    Intra-pod graph edges bypass the aggregators (direct, colored).
  * ``aggregate=None``  — select per policy (fixed / model / tuned, see
    selector.select_neighbor): the tuned policy reads the persisted
    standard-vs-locality-aware winner measured by ``tuner.autotune``.

Both modes land received values in an identical recv layout (segments
ordered by source rank), so they are drop-in interchangeable — the
paper's Listing 3 -> Listing 4 replacement.

Working buffer layout per rank (rows of width ``feat``):
    [0, n_local)                local send values (input)
    [n_local, recv_off)         staging region (aggregators only)
    [recv_off, recv_off+n_recv) final recv segments (output)
The transports append one trailing scratch row internally to absorb
masked sends/receives.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.schedule import CommRound, CommSchedule
from repro.core.topology import Topology
from repro.core.transport import ShardMapTransport, SimTransport

# Back-compat alias: neighbor rounds *are* IR rounds since unification.
NeighborRound = CommRound

ELEM_BYTES = 4   # accounting default: float32 rows


# ---------------------------------------------------------------------------
# communication graph
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CommGraph:
    """Sparse exchange: ``edges[(src, dst)]`` = indices into src's local
    value array that dst needs (duplicates allowed across dsts — that is
    exactly what locality-aware aggregation exploits)."""

    nranks: int
    local_sizes: tuple[int, ...]                    # values owned per rank
    edges: dict[tuple[int, int], np.ndarray]

    def __post_init__(self):
        for (s, d), idx in self.edges.items():
            assert 0 <= s < self.nranks and 0 <= d < self.nranks
            assert s != d, "self-edges are local copies, not messages"
            assert len(idx) > 0
            assert idx.max() < self.local_sizes[s]

    def recv_layout(self, rank: int) -> list[tuple[int, np.ndarray]]:
        """Deterministic recv segment order: ascending source rank."""
        return [(s, self.edges[(s, d)])
                for (s, d) in sorted(self.edges) if d == rank]

    def n_recv(self, rank: int) -> int:
        return sum(len(ix) for _, ix in self.recv_layout(rank))

    def total_values(self) -> int:
        """Total value rows the exchange moves (standard-plan volume)."""
        return sum(len(idx) for idx in self.edges.values())

    @staticmethod
    def random(nranks: int, n_local: int, degree: int, rng,
               dup_frac: float = 0.5) -> "CommGraph":
        """Random sparse graph; ``dup_frac`` controls how often the same
        source value is requested by several destinations (the dedupe
        opportunity)."""
        edges: dict[tuple[int, int], np.ndarray] = {}
        for s in range(nranks):
            dsts = rng.permutation(nranks - 1)[:degree]
            dsts = [int(d) if d < s else int(d) + 1 for d in dsts]
            pool = rng.integers(0, n_local, max(1, int(n_local * dup_frac)))
            for d in dsts:
                k = int(rng.integers(1, n_local + 1))
                use_pool = rng.random(k) < dup_frac
                idx = np.where(use_pool,
                               pool[rng.integers(0, len(pool), k)],
                               rng.integers(0, n_local, k))
                edges[(s, d)] = idx.astype(np.int64)
        return CommGraph(nranks=nranks, local_sizes=(n_local,) * nranks,
                         edges=edges)


# ---------------------------------------------------------------------------
# the compiled plan (a CommSchedule plus graph metadata)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NeighborPlan:
    """A compiled persistent neighborhood alltoallv.

    Since the IR unification this is a thin wrapper: ``schedule`` is an
    ordinary ``CommSchedule`` (executable by any Transport, timeable by
    the tuner) and the plan only adds the graph/recv-layout metadata the
    API wrappers need.
    """

    graph: CommGraph
    topo: Topology
    schedule: CommSchedule
    recv_offsets: tuple[int, ...]  # per rank, start of recv region
    recv_sizes: tuple[int, ...]
    name: str = "neighbor"

    @property
    def rounds(self) -> tuple[CommRound, ...]:
        return self.schedule.rounds

    @property
    def buf_rows(self) -> int:        # working rows (excl. scratch)
        return self.schedule.num_slots

    @property
    def num_rounds(self) -> int:
        return self.schedule.num_rounds

    @property
    def num_compiled_rounds(self) -> int:
        """Round count after persistent-executor compilation, armed
        with this plan's topology.  The greedy edge coloring already
        packs rounds tightly, so the topology-free drain pass usually
        leaves the count unchanged; the cost-model-armed pass can
        additionally delete a round by splitting its edges across
        earlier rounds when ``topo.round_time`` proves it free."""
        from repro.core import executor
        return executor.get_executor(self.schedule,
                                     topo=self.topo).rounds_after

    # -- accounting (paper claim: aggregation cuts DCN bytes/messages) ----
    def traffic(self, elem_bytes: int = 1) -> dict:
        return self.schedule.traffic(self.topo, elem_bytes)

    def modeled_time(self, elem_bytes: int = ELEM_BYTES) -> float:
        """alpha-beta time of the exchange with ``elem_bytes``-wide rows."""
        return self.schedule.modeled_time(self.topo, elem_bytes)

    def makespan(self, elem_bytes: int = ELEM_BYTES) -> float:
        """Makespan of the armed executor's packed plan (executor pass
        3): rounds on disjoint topology levels overlap, so a plan whose
        compiled rounds alternate DCN and intra-pod hops is priced below
        the serial ``modeled_time`` — never above it (pointwise)."""
        from repro.core import executor
        return executor.get_executor(self.schedule,
                                     topo=self.topo).makespan(elem_bytes)


# ---------------------------------------------------------------------------
# plan building
# ---------------------------------------------------------------------------


def _edge_color(edges: list[tuple[int, int]]) -> list[list[int]]:
    """Greedy edge coloring: returns rounds as lists of edge indices such
    that within a round every src sends <=1 and every dst receives <=1."""
    src_busy: list[set[int]] = []
    dst_busy: list[set[int]] = []
    rounds: list[list[int]] = []
    # longest-first gives better packing; stable order for determinism
    order = sorted(range(len(edges)), key=lambda i: edges[i])
    for i in order:
        s, d = edges[i]
        for c in range(len(rounds) + 1):
            if c == len(rounds):
                rounds.append([])
                src_busy.append(set())
                dst_busy.append(set())
            if s not in src_busy[c] and d not in dst_busy[c]:
                rounds[c].append(i)
                src_busy[c].add(s)
                dst_busy[c].add(d)
                break
    return rounds


def _mk_round(nranks: int, items: list[tuple[int, int, np.ndarray, np.ndarray]]
              ) -> CommRound:
    """items: (src, dst, gather_rows, scatter_rows) with equal lengths."""
    w = max(1, max(len(g) for _, _, g, _ in items))
    gi = np.full((nranks, w), -1, np.int64)
    si = np.full((nranks, w), -1, np.int64)
    pay = np.zeros(nranks, np.int64)
    perm = []
    for s, d, g, t in items:
        assert len(g) == len(t)
        perm.append((s, d))
        gi[s, : len(g)] = g
        si[d, : len(t)] = t
        pay[s] = len(g)
    return CommRound(perm=tuple(perm), gather_idx=gi, scatter_idx=si,
                     payload=pay)


def build_plan(graph: CommGraph, topo: Topology, *,
               aggregate: bool | None = False,
               policy: str | None = None,
               elem_bytes: int = ELEM_BYTES) -> NeighborPlan:
    """Compile ``graph`` into a persistent plan on the unified IR.

    ``aggregate=None`` resolves the standard-vs-locality-aware choice
    through the selection policy ladder (``policy=None`` uses the
    process default; ``"tuned"`` reads ``tuner.autotune``'s persisted
    winner for this topology and exchange volume).
    """
    n = graph.nranks
    assert topo.nranks == n
    if aggregate is None:
        from repro.core import selector
        mode = selector.resolve_neighbor_mode(
            graph, topo, policy=policy, elem_bytes=elem_bytes)
        if mode is None:
            return model_argmin_plan(graph, topo, elem_bytes=elem_bytes)
        aggregate = mode == "locality_aware"
    # final recv layout (identical across modes)
    recv_off = [0] * n
    recv_size = [graph.n_recv(r) for r in range(n)]
    seg_start: dict[tuple[int, int], int] = {}   # (src, dst) -> recv row
    stage_need = [0] * n

    if not aggregate or topo.npods == 1:
        buf0 = max(graph.local_sizes)
        for r in range(n):
            recv_off[r] = buf0
        for r in range(n):
            pos = recv_off[r]
            for s, idx in graph.recv_layout(r):
                seg_start[(s, r)] = pos
                pos += len(idx)
        edge_list = sorted(graph.edges)
        items_by_round = _edge_color(edge_list)
        rounds = []
        for edge_ids in items_by_round:
            items = []
            for i in edge_ids:
                s, d = edge_list[i]
                idx = graph.edges[(s, d)]
                tgt = seg_start[(s, d)] + np.arange(len(idx))
                items.append((s, d, idx.astype(np.int64), tgt))
            rounds.append(_mk_round(n, items))
        buf_rows = buf0 + max(recv_size, default=0)
        sched = CommSchedule(
            nranks=n, num_slots=buf_rows, rounds=tuple(rounds),
            name="neighbor.standard",
            out_slots=max(recv_size, default=0),
            out_offsets=np.asarray(recv_off, np.int64))
        return NeighborPlan(graph=graph, topo=topo, schedule=sched,
                            recv_offsets=tuple(recv_off),
                            recv_sizes=tuple(recv_size),
                            name="neighbor.standard")

    # ---------------- locality-aware aggregated (3 phases) ----------------
    R, Q = topo.ranks_per_pod, topo.npods

    def agg_out(p: int, q: int) -> int:
        """Aggregator in pod p for traffic headed to pod q (striped)."""
        return topo.rank(p, q % R)

    def agg_in(q: int, p: int) -> int:
        """Aggregator in pod q for traffic arriving from pod p."""
        return topo.rank(q, p % R)

    # unique values per (src rank, dst pod):  U[(s, q)] = sorted unique idx
    U: dict[tuple[int, int], np.ndarray] = {}
    for (s, d), idx in sorted(graph.edges.items()):
        q = topo.pod(d)
        if q == topo.pod(s):
            continue
        key = (s, q)
        U[key] = (np.unique(np.concatenate([U[key], idx]))
                  if key in U else np.unique(idx))

    # staging layout on each aggregator:
    #   out-stage: values collected from own pod (phase A lands here),
    #   in-stage:  values arrived over DCN (phase B lands here).
    # stage_pos[(owner_rank, src_rank, q_or_p, local_idx)] -> staging row
    out_stage_pos: dict[tuple[int, int, int], np.ndarray] = {}
    in_stage_pos: dict[tuple[int, int, int], np.ndarray] = {}
    for (s, q), uniq in sorted(U.items()):
        a = agg_out(topo.pod(s), q)
        base = max(graph.local_sizes) + stage_need[a]
        out_stage_pos[(a, s, q)] = base + np.arange(len(uniq))
        stage_need[a] += len(uniq)
    for (s, q), uniq in sorted(U.items()):
        b = agg_in(q, topo.pod(s))
        base = max(graph.local_sizes) + stage_need[b]
        in_stage_pos[(b, s, q)] = base + np.arange(len(uniq))
        stage_need[b] += len(uniq)

    buf0 = max(graph.local_sizes)
    stage_cap = max(stage_need, default=0)
    for r in range(n):
        recv_off[r] = buf0 + stage_cap
    for r in range(n):
        pos = recv_off[r]
        for s, idx in graph.recv_layout(r):
            seg_start[(s, r)] = pos
            pos += len(idx)

    # Phase A: src s -> aggregator a(pod(s), q), payload U[(s, q)].
    # When s is its own aggregator the staging rows are filled by folding
    # the copy into phase B's gather (gather directly from the value rows).
    phase_a_edges = []   # (s, a, gather_rows, scatter_rows)
    for (s, q), uniq in sorted(U.items()):
        a = agg_out(topo.pod(s), q)
        if a == s:
            continue
        phase_a_edges.append((s, a, uniq.astype(np.int64),
                              out_stage_pos[(a, s, q)]))
    # Phase B: a(p, q) -> agg_in(q, p); bundle = all (s in pod p) segments.
    phase_b_edges = []
    for p in range(Q):
        for q in range(Q):
            if p == q:
                continue
            a, b = agg_out(p, q), agg_in(q, p)
            g_rows, t_rows = [], []
            for s in topo.pod_ranks(p):
                if (s, q) not in U:
                    continue
                uniq = U[(s, q)]
                if s == a:   # folded local copy: gather from value rows
                    g_rows.append(uniq.astype(np.int64))
                else:
                    g_rows.append(out_stage_pos[(a, s, q)])
                t_rows.append(in_stage_pos[(b, s, q)])
            if not g_rows:
                continue
            phase_b_edges.append((a, b, np.concatenate(g_rows),
                                  np.concatenate(t_rows)))
    # Phase C: agg_in(q, p) -> each dst d in pod q: the (src s) segment
    # values d needs, gathered from in-stage rows (duplication on ICI).
    phase_c_edges = []
    for (s, d), idx in sorted(graph.edges.items()):
        q, p = topo.pod(d), topo.pod(s)
        if q == p:
            continue
        b = agg_in(q, p)
        uniq = U[(s, q)]
        lookup = {int(v): int(r) for v, r in
                  zip(uniq, in_stage_pos[(b, s, q)])}
        g = np.array([lookup[int(v)] for v in idx], np.int64)
        t = seg_start[(s, d)] + np.arange(len(idx))
        phase_c_edges.append((b, d, g, t))
    # intra-pod direct edges (any phase; run them with phase A coloring)
    for (s, d), idx in sorted(graph.edges.items()):
        if topo.pod(s) != topo.pod(d):
            continue
        t = seg_start[(s, d)] + np.arange(len(idx))
        phase_a_edges.append((s, d, idx.astype(np.int64), t))

    rounds: list[CommRound] = []
    for phase in (phase_a_edges, phase_b_edges, phase_c_edges):
        # split self-edges (local copies) from real messages
        msgs = [(s, d, g, t) for (s, d, g, t) in phase if s != d]
        selfs = [(s, d, g, t) for (s, d, g, t) in phase if s == d]
        colored = _edge_color([(s, d) for s, d, _, _ in msgs])
        for edge_ids in colored:
            rounds.append(_mk_round(n, [msgs[i] for i in edge_ids]))
        # Local copies cost nothing on the wire: one fused round of (r, r)
        # self-permutations (legal ppermute, stays on-chip); merge multiple
        # self-edges per rank into a single gather/scatter row.
        if selfs:
            merged: dict[int, tuple[list, list]] = {}
            for s, _, g, t in selfs:
                merged.setdefault(s, ([], []))
                merged[s][0].append(g)
                merged[s][1].append(t)
            items = [(r, r, np.concatenate(gs), np.concatenate(ts))
                     for r, (gs, ts) in sorted(merged.items())]
            rounds.append(_mk_round(n, items))

    buf_rows = buf0 + stage_cap + max(recv_size, default=0)
    sched = CommSchedule(
        nranks=n, num_slots=buf_rows, rounds=tuple(rounds),
        name="neighbor.locality_aware",
        out_slots=max(recv_size, default=0),
        out_offsets=np.asarray(recv_off, np.int64))
    return NeighborPlan(graph=graph, topo=topo, schedule=sched,
                        recv_offsets=tuple(recv_off),
                        recv_sizes=tuple(recv_size),
                        name="neighbor.locality_aware")


def model_argmin_plan(graph: CommGraph, topo: Topology, *,
                      elem_bytes: int = ELEM_BYTES) -> NeighborPlan:
    """Model-policy fallback: build both modes once, keep the one with
    the lower alpha-beta time (the single implementation behind both
    ``build_plan(aggregate=None)`` and ``selector.select_neighbor``)."""
    plans = [build_plan(graph, topo, aggregate=agg,
                        elem_bytes=elem_bytes)
             for agg in (False, True)]   # standard first: wins ties
    return min(plans,
               key=lambda p: p.schedule.modeled_time(topo, elem_bytes))


# ---------------------------------------------------------------------------
# execution — thin wrappers over the shared transports
# ---------------------------------------------------------------------------


def run_sim(plan: NeighborPlan, values: Sequence[np.ndarray]) -> list[np.ndarray]:
    """numpy oracle executor: ``values[r]`` = rank r's [n_local_r, feat]
    send values; returns per-rank recv arrays [n_recv_r, feat].
    Delegates to the shared ``SimTransport``."""
    n = plan.graph.nranks
    feat = values[0].shape[1:]
    buf = np.zeros((n, plan.buf_rows) + feat, values[0].dtype)
    for r in range(n):
        buf[r, : values[r].shape[0]] = values[r]
    out = SimTransport(n, topo=plan.topo).run(plan.schedule, buf)
    return [out[r, plan.recv_offsets[r]: plan.recv_offsets[r]
                + plan.recv_sizes[r]] for r in range(n)]


def run_shardmap(plan: NeighborPlan, local_values: jax.Array,
                 axis_names, *, transport: str = "shardmap") -> jax.Array:
    """SPMD executor (call inside shard_map): ``local_values`` is this
    rank's [n_local_max, feat] value rows; returns [n_recv_max, feat]
    (rows beyond this rank's recv_size are zeros).
    Delegates to the shared ``ShardMapTransport`` — or, with
    ``transport="pallas"``, the single-kernel ``PallasTransport``."""
    from repro.core.transport import PallasTransport, _flat_rank

    names = ((axis_names,) if isinstance(axis_names, str)
             else tuple(axis_names))
    n = plan.graph.nranks
    feat = local_values.shape[1:]
    buf = jnp.zeros((plan.buf_rows,) + feat, local_values.dtype)
    buf = buf.at[: local_values.shape[0]].set(local_values)
    cls = PallasTransport if transport == "pallas" else ShardMapTransport
    out = cls(n, names, topo=plan.topo).run(plan.schedule, buf)
    n_recv_max = max(plan.recv_sizes)
    offs = jnp.asarray(plan.recv_offsets)[_flat_rank(names)]
    return jax.lax.dynamic_slice_in_dim(out, offs, n_recv_max, axis=0)
