"""Transport backends that execute a ``CommSchedule`` (see schedule.py).

MPI Advance writes every collective algorithm once, against MPI point-to-
point primitives, and runs it on any substrate.  We keep the same split:
one IR (``CommSchedule``: gather tables -> static permutation -> scatter
tables), two executors:

  * ``SimTransport``      — numpy, rank-by-rank.  Bit-exact execution of
                            a schedule for N simulated ranks on zero
                            devices.  Used by unit/property tests and by
                            the message/byte accounting benchmarks.
  * ``ShardMapTransport`` — the production substrate: each ``CommRound``
                            becomes one ``jax.lax.ppermute`` (the TPU ICI
                            point-to-point primitive) inside ``shard_map``.

Dense collectives, neighborhood alltoallv plans, and partitioned
transfers all execute through these two classes — there is exactly one
execution semantics to keep bit-identical.

Buffers are slot-indexed: the working array has shape
``[num_slots + 1, *slot_shape]`` on every rank — the final slot is a
scratch row that absorbs sends/receives masked out with ``-1`` in the
schedule tables, so execution is fully static (no data-dependent control
flow, as required for TPU lowering).
"""
from __future__ import annotations

import abc
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.schedule import CommRound, CommSchedule

from repro import compat


class Transport(abc.ABC):
    """Executes schedules for a fixed rank count."""

    nranks: int

    @abc.abstractmethod
    def run(self, schedule: CommSchedule, buf):
        """Execute ``schedule`` on a slot-indexed buffer and return it."""


# ---------------------------------------------------------------------------
# numpy simulator
# ---------------------------------------------------------------------------


class SimTransport(Transport):
    """Rank-by-rank numpy execution: ``buf`` is [nranks, num_slots, *slot].

    Exact semantics match ShardMapTransport:
      * a rank that is not a destination in a round receives zeros,
      * gather index -1 sends zeros,
      * scatter index -1 drops the received slot,
      * ``reduce=True`` accumulates (+=) instead of overwriting,
      * (r, r) self-pairs deliver the rank's own payload (on-chip copy).
    """

    def __init__(self, nranks: int):
        self.nranks = nranks

    def run(self, schedule: CommSchedule, buf: np.ndarray) -> np.ndarray:
        assert buf.shape[0] == self.nranks, (buf.shape, self.nranks)
        assert buf.shape[1] == schedule.num_slots
        buf = buf.copy()
        if schedule.local_pre is not None:
            buf = np.stack([buf[r, schedule.local_pre[r]]
                            for r in range(self.nranks)])
        for rnd in schedule.rounds:
            buf = self._round(rnd, buf)
        if schedule.local_post is not None:
            buf = np.stack([buf[r, schedule.local_post[r]]
                            for r in range(self.nranks)])
        return buf

    def _round(self, rnd: CommRound, buf: np.ndarray) -> np.ndarray:
        slot_shape = buf.shape[2:]
        # Everyone starts this round receiving zeros (ppermute semantics).
        inbox = np.zeros((self.nranks, rnd.k) + slot_shape, buf.dtype)
        for src, dst in rnd.perm:
            gather = rnd.gather_idx[src]
            payload = np.zeros((rnd.k,) + slot_shape, buf.dtype)
            valid = gather >= 0
            payload[valid] = buf[src, gather[valid]]
            inbox[dst] = payload
        out = buf.copy()
        dst_set = {d for _, d in rnd.perm}
        for r in range(self.nranks):
            if r not in dst_set:
                continue
            scatter = rnd.scatter_idx[r]
            for slot in range(rnd.k):
                tgt = scatter[slot]
                if tgt < 0:
                    continue
                if rnd.reduce:
                    out[r, tgt] = out[r, tgt] + inbox[r, slot]
                else:
                    out[r, tgt] = inbox[r, slot]
        return out


# ---------------------------------------------------------------------------
# shard_map substrate
# ---------------------------------------------------------------------------


def _flat_rank(axis_names: Sequence[str]):
    """Row-major flattened rank over possibly-multiple mesh axes."""
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * compat.axis_size(name) + jax.lax.axis_index(name)
    return idx


class ShardMapTransport(Transport):
    """Executes schedules with ``ppermute`` inside an ambient ``shard_map``.

    ``run`` must be called from *inside* a shard_map whose manual axes
    include ``axis_names`` (row-major order defines the flat rank, matching
    the CommSchedule's rank numbering).  ``buf`` here is the *local*
    buffer, shape [num_slots, *slot], and one scratch slot is appended
    internally.
    """

    def __init__(self, nranks: int, axis_names: Sequence[str] | str):
        self.nranks = nranks
        self.axis_names = ((axis_names,) if isinstance(axis_names, str)
                           else tuple(axis_names))

    def run(self, schedule: CommSchedule, buf: jax.Array) -> jax.Array:
        assert buf.shape[0] == schedule.num_slots
        rank = _flat_rank(self.axis_names)
        if schedule.local_pre is not None:
            buf = buf[jnp.asarray(schedule.local_pre, jnp.int32)[rank]]
        scratch = jnp.zeros((1,) + buf.shape[1:], buf.dtype)
        x = jnp.concatenate([buf, scratch], axis=0)
        for rnd in schedule.rounds:
            x = self._round(rnd, x, rank, schedule.num_slots)
        out = x[: schedule.num_slots]
        if schedule.local_post is not None:
            out = out[jnp.asarray(schedule.local_post, jnp.int32)[rank]]
        return out

    def _axis_arg(self):
        return self.axis_names if len(self.axis_names) > 1 else self.axis_names[0]

    def _round(self, rnd: CommRound, x: jax.Array, rank, nb: int) -> jax.Array:
        kdims = (rnd.k,) + (1,) * (x.ndim - 1)
        gather_tbl = jnp.asarray(rnd.gather_idx, jnp.int32)  # [nranks, k]
        scatter_tbl = jnp.asarray(rnd.scatter_idx, jnp.int32)
        my_gather = gather_tbl[rank]                          # [k]
        my_scatter = scatter_tbl[rank]
        # Gather payload; -1 slots read the scratch row and are zeroed.
        payload = x[jnp.where(my_gather >= 0, my_gather, nb)]
        payload = jnp.where((my_gather >= 0).reshape(kdims), payload, 0)
        recvd = jax.lax.ppermute(payload, self._axis_arg(), list(rnd.perm))
        # Scatter: -1 slots land on the scratch row (index nb).
        tgt = jnp.where(my_scatter >= 0, my_scatter, nb)
        if rnd.reduce:
            masked = jnp.where((my_scatter >= 0).reshape(kdims), recvd, 0)
            x = x.at[tgt].add(masked)
        else:
            # distinct targets per slot by construction (schedule invariant)
            x = x.at[tgt].set(recvd)
            x = x.at[nb].set(0)
        return x
