"""Transport backends that execute a ``CommSchedule`` (see schedule.py).

MPI Advance writes every collective algorithm once, against MPI point-to-
point primitives, and runs it on any substrate.  We keep the same split:
one IR (``CommSchedule``: gather tables -> static permutation -> scatter
tables), three executors:

  * ``SimTransport``      — numpy, rank-by-rank.  Bit-exact execution of
                            a schedule for N simulated ranks on zero
                            devices.  Used by unit/property tests and by
                            the message/byte accounting benchmarks.
  * ``ShardMapTransport`` — the production substrate: each ``CommRound``
                            becomes one ``jax.lax.ppermute`` (the TPU ICI
                            point-to-point primitive) inside ``shard_map``.
  * ``PallasTransport``   — device-side: the WHOLE compiled schedule as
                            ONE Pallas kernel (core.pallas_lowering) —
                            launch amortization for alpha-dominated
                            message sizes (the paper's GPU-aware pillar).

Dense collectives, neighborhood alltoallv plans, and partitioned
transfers all execute through these classes — there is exactly one
execution semantics to keep bit-identical.

Buffers are slot-indexed: the working array has shape
``[num_slots + 1, *slot_shape]`` on every rank — the final slot is a
scratch row that absorbs sends/receives masked out with ``-1`` in the
schedule tables, so execution is fully static (no data-dependent control
flow, as required for TPU lowering).

Since the persistent-executor compilation (core.executor) both ``run``
methods are thin lookups: the schedule is lowered once to a cached
``CompiledExec`` (tables baked, rounds fused, locals folded) and every
subsequent call — every training step, every tuner repeat — reuses it,
the MPI-4 persistent-collective split.  ``SimTransport.run_reference``
keeps the original rank-by-rank loop as the executor's oracle.
"""
from __future__ import annotations

import abc
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import executor
from repro.core.schedule import CommRound, CommSchedule
from repro.core.topology import Topology

from repro import compat


class TransportError(RuntimeError):
    """A substrate failed to execute a round/schedule (a failed kernel
    launch, a dropped ppermute, an injected chaos fault).  Typed so the
    recovery ladder (``core.resilient``) can distinguish a *transport*
    failure — retryable, degradable to another substrate — from a
    programming error, which must stay loud.

    ``transport`` names the substrate, ``round_idx`` the failing round
    (-1 when the failure is not round-attributable)."""

    def __init__(self, msg: str, *, transport: str = "?",
                 round_idx: int = -1):
        super().__init__(msg)
        self.transport = transport
        self.round_idx = round_idx


class Transport(abc.ABC):
    """Executes schedules for a fixed rank count.

    An optional ``topo`` arms the persistent-executor compile pass with
    the alpha-beta cost model (multi-target fusion + round reordering,
    see core.executor); without one the topology-free single-target
    rule runs.  The executor cache keys on the topology fingerprint, so
    one transport per geometry never collides with another.
    """

    nranks: int
    topo: Topology | None

    @abc.abstractmethod
    def run(self, schedule: CommSchedule, buf):
        """Execute ``schedule`` on a slot-indexed buffer and return it."""


# ---------------------------------------------------------------------------
# numpy simulator
# ---------------------------------------------------------------------------


class SimTransport(Transport):
    """Rank-by-rank numpy execution: ``buf`` is [nranks, num_slots, *slot].

    Exact semantics match ShardMapTransport:
      * a rank that is not a destination in a round receives zeros,
      * gather index -1 sends zeros,
      * scatter index -1 drops the received slot,
      * ``reduce=True`` accumulates (+=) instead of overwriting,
      * (r, r) self-pairs deliver the rank's own payload (on-chip copy).
    """

    def __init__(self, nranks: int, topo: Topology | None = None):
        self.nranks = nranks
        self.topo = topo

    def run(self, schedule: CommSchedule, buf: np.ndarray) -> np.ndarray:
        """Compiled-path execution: one vectorized gather/permute/scatter
        per round through the cached ``CompiledExec`` (no per-rank or
        per-slot Python loops — what keeps ``tuner.autotune`` and the
        bit-exactness sweeps fast)."""
        assert buf.shape[0] == self.nranks, (buf.shape, self.nranks)
        assert buf.shape[1] == schedule.num_slots
        return executor.get_executor(schedule, topo=self.topo).run_sim(buf)

    def run_chunked(self, schedule: CommSchedule, buf: np.ndarray, *,
                    chunks: int, consume=None, init=None):
        """Row-chunked (partitioned) execution: split the slot row axis
        into ``chunks`` equal pieces, run the full schedule per piece,
        and fold each piece's output through ``consume(carry, out, i)``
        as soon as it lands — the MPIPCL shape where chunk ``i+1``'s
        transfer overlaps chunk ``i``'s consumer compute.

        With ``consume=None`` the chunk outputs are reassembled and the
        result is bit-identical to ``run`` (each chunk sees a disjoint
        row slice; schedules never mix rows).  ``buf`` is
        [nranks, num_slots, rows, ...]; ``rows`` must divide by
        ``chunks``."""
        if chunks <= 0:
            raise ValueError(f"run_chunked: chunks must be >= 1, "
                             f"got {chunks}")
        assert buf.ndim >= 3, buf.shape
        rows = buf.shape[2]
        if rows % chunks:
            raise ValueError(
                f"run_chunked: row count {rows} is not divisible by "
                f"chunks={chunks}")
        rc = rows // chunks
        carry = init
        outs = []
        for i in range(chunks):
            piece = np.ascontiguousarray(
                buf[:, :, i * rc:(i + 1) * rc])
            out = self.run(schedule, piece)
            if consume is None:
                outs.append(out)
            else:
                carry = consume(carry, out, i)
        if consume is None:
            return np.concatenate(outs, axis=2)
        return carry

    def run_reference(self, schedule: CommSchedule,
                      buf: np.ndarray) -> np.ndarray:
        """The original rank-by-rank loop — kept as the semantic oracle
        the compiled/fused path is tested bit-exact against."""
        assert buf.shape[0] == self.nranks, (buf.shape, self.nranks)
        assert buf.shape[1] == schedule.num_slots
        buf = buf.copy()
        if schedule.local_pre is not None:
            buf = np.stack([buf[r, schedule.local_pre[r]]
                            for r in range(self.nranks)])
        for rnd in schedule.rounds:
            buf = self._round(rnd, buf)
        if schedule.local_post is not None:
            buf = np.stack([buf[r, schedule.local_post[r]]
                            for r in range(self.nranks)])
        return buf

    def _round(self, rnd: CommRound, buf: np.ndarray) -> np.ndarray:
        slot_shape = buf.shape[2:]
        # Everyone starts this round receiving zeros (ppermute semantics).
        inbox = np.zeros((self.nranks, rnd.k) + slot_shape, buf.dtype)
        for src, dst in rnd.perm:
            gather = rnd.gather_idx[src]
            payload = np.zeros((rnd.k,) + slot_shape, buf.dtype)
            valid = gather >= 0
            payload[valid] = buf[src, gather[valid]]
            inbox[dst] = payload
        out = buf.copy()
        dst_set = {d for _, d in rnd.perm}
        for r in range(self.nranks):
            if r not in dst_set:
                continue
            scatter = rnd.scatter_idx[r]
            for slot in range(rnd.k):
                tgt = scatter[slot]
                if tgt < 0:
                    continue
                if rnd.reduce:
                    out[r, tgt] = out[r, tgt] + inbox[r, slot]
                else:
                    out[r, tgt] = inbox[r, slot]
        return out


# ---------------------------------------------------------------------------
# shard_map substrate
# ---------------------------------------------------------------------------


def _flat_rank(axis_names: Sequence[str]):
    """Row-major flattened rank over possibly-multiple mesh axes."""
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * compat.axis_size(name) + jax.lax.axis_index(name)
    return idx


class ShardMapTransport(Transport):
    """Executes schedules with ``ppermute`` inside an ambient ``shard_map``.

    ``run`` must be called from *inside* a shard_map whose manual axes
    include ``axis_names`` (row-major order defines the flat rank, matching
    the CommSchedule's rank numbering).  ``buf`` here is the *local*
    buffer, shape [num_slots, *slot], and one scratch slot is appended
    internally.
    """

    def __init__(self, nranks: int, axis_names: Sequence[str] | str,
                 topo: Topology | None = None):
        self.nranks = nranks
        self.topo = topo
        self.axis_names = ((axis_names,) if isinstance(axis_names, str)
                           else tuple(axis_names))

    def run(self, schedule: CommSchedule, buf: jax.Array) -> jax.Array:
        """Compiled-path execution: look up the cached ``CompiledExec``
        (tables already on device, rounds fused — cost-model-armed when
        this transport carries a topology) and trace its rounds.  The
        executor's trace counter makes the persistence observable:
        repeated jitted calls with one (shape, dtype) lower exactly
        once."""
        assert buf.shape[0] == schedule.num_slots
        rank = _flat_rank(self.axis_names)
        return executor.get_executor(schedule, topo=self.topo).run_shardmap(
            buf, rank, self._axis_arg())

    def run_chunked(self, schedule: CommSchedule, buf: jax.Array, *,
                    chunks: int, consume=None, init=None):
        """Row-chunked (partitioned) execution under ``lax.scan``: the
        local buffer [num_slots, rows, ...] is split along the row axis
        into ``chunks`` equal pieces and the full schedule runs once per
        piece through ONE cached executor — a single trace regardless of
        chunk count (double-buffered chunk loop; the scheduler overlaps
        chunk ``i+1``'s ppermutes with chunk ``i``'s ``consume``
        compute).  With ``consume=None`` the outputs reassemble to
        exactly ``run``'s result; otherwise the final
        ``consume(carry, out, i)`` carry is returned."""
        if chunks <= 0:
            raise ValueError(f"run_chunked: chunks must be >= 1, "
                             f"got {chunks}")
        assert buf.ndim >= 2, buf.shape
        slots, rows = buf.shape[0], buf.shape[1]
        if rows % chunks:
            raise ValueError(
                f"run_chunked: row count {rows} is not divisible by "
                f"chunks={chunks}")
        rc = rows // chunks
        tail = buf.shape[2:]
        # [slots, rows, ...] -> [chunks, slots, rc, ...] scan leaves
        xs = buf.reshape((slots, chunks, rc) + tail).swapaxes(0, 1)
        if consume is None:
            def body(_, xc):
                return None, self.run(schedule, xc)
            _, ys = jax.lax.scan(body, None, xs)
            return (ys.swapaxes(0, 1)
                    .reshape((slots, rows) + tail))

        def body(carry, xi):
            xc, i = xi
            return consume(carry, self.run(schedule, xc), i), None
        carry, _ = jax.lax.scan(
            body, init, (xs, jnp.arange(chunks, dtype=jnp.int32)))
        return carry

    def run_global(self, schedule: CommSchedule, gbuf) -> jax.Array:
        """Host-side execution of a *global* [nranks, num_slots, *slot]
        buffer: builds a one-axis mesh over the first ``nranks`` local
        devices and runs the schedule inside its own ``shard_map`` —
        the PallasTransport.run_global calling convention on the
        ppermute substrate.  This is the entry the recovery ladder
        (``core.resilient``) and the tuner use when they hold concrete
        buffers rather than traced shards; requires ``nranks`` devices
        (``TransportError`` otherwise, so the ladder can skip the rung
        instead of crashing)."""
        from jax.sharding import PartitionSpec as P

        n = self.nranks
        if jax.device_count() < n:
            raise TransportError(
                f"shardmap run_global needs {n} devices, have "
                f"{jax.device_count()}", transport="shardmap")
        assert gbuf.shape[0] == n, (gbuf.shape, n)
        assert gbuf.shape[1] == schedule.num_slots
        mesh = compat.make_mesh((n,), ("_resil",),
                                devices=jax.devices()[:n])
        tr = ShardMapTransport(n, "_resil", topo=self.topo)
        f = compat.shard_map(
            lambda b: tr.run(schedule, b), mesh=mesh,
            in_specs=P("_resil"), out_specs=P("_resil"), check_vma=False)
        flat = jnp.asarray(gbuf).reshape((n * schedule.num_slots,)
                                         + gbuf.shape[2:])
        out = f(flat)
        return out.reshape((n, schedule.num_slots) + gbuf.shape[2:])

    def _axis_arg(self):
        return self.axis_names if len(self.axis_names) > 1 else self.axis_names[0]


# ---------------------------------------------------------------------------
# device-side Pallas substrate
# ---------------------------------------------------------------------------


class PallasTransport(Transport):
    """Device-side execution: the WHOLE compiled schedule as ONE Pallas
    kernel (core.pallas_lowering), instead of one ppermute launch per
    round.

    The kernel runs on the *global* slot buffer [nranks, num_slots,
    *slot].  Used standalone (``run_global``, the SimTransport calling
    convention — what the bit-exactness sweeps drive), or inside a
    shard_map (``run``, the ShardMapTransport calling convention): the
    local buffers are first combined with a single ``all_gather``, every
    rank executes the kernel on the replicated global buffer
    (deterministic, so all ranks agree bit-for-bit), and each keeps its
    own row.  That trades bandwidth (the gather ships n× the data) for
    launches (1 collective + 1 kernel vs R collectives) — the
    alpha/beta crossover the tuner's ``transport`` policy cell prices
    per size bucket.  On multi-chip TPU topologies the same kernel
    structure extends to RDMA rounds without the gather; that variant
    is TPU-gated (see pallas_lowering).
    """

    def __init__(self, nranks: int,
                 axis_names: Sequence[str] | str | None = None,
                 topo: Topology | None = None):
        self.nranks = nranks
        self.topo = topo
        if axis_names is None:
            self.axis_names = None
        else:
            self.axis_names = ((axis_names,) if isinstance(axis_names, str)
                               else tuple(axis_names))

    def run_global(self, schedule: CommSchedule, gbuf, *, chunks: int = 1):
        """Execute on a global [nranks, num_slots, *slot] buffer — one
        kernel launch; ``chunks > 1`` tiles the slot row axis over the
        Pallas grid (double-buffered block pipeline, bit-identical)."""
        from repro.core.pallas_lowering import get_pallas_exec
        assert gbuf.shape[0] == self.nranks, (gbuf.shape, self.nranks)
        assert gbuf.shape[1] == schedule.num_slots
        return get_pallas_exec(schedule, topo=self.topo).run(
            gbuf, chunks=chunks)

    def run(self, schedule: CommSchedule, buf: jax.Array) -> jax.Array:
        """Called from inside a shard_map over ``axis_names`` with the
        *local* buffer [num_slots, *slot]; returns the local result."""
        if self.axis_names is None:
            raise ValueError(
                "PallasTransport.run needs axis_names (inside shard_map); "
                "use run_global for host-side global-buffer execution")
        # leading gathered axis is row-major over the name tuple — the
        # same order as _flat_rank, so gbuf[r] is rank r's local buffer
        gbuf = jax.lax.all_gather(buf, self._axis_arg())
        gbuf = gbuf.reshape((self.nranks,) + buf.shape)
        out = self.run_global(schedule, gbuf)
        return jax.lax.dynamic_index_in_dim(
            out, _flat_rank(self.axis_names), axis=0, keepdims=False)

    def run_chunked(self, schedule: CommSchedule, buf: jax.Array, *,
                    chunks: int, consume=None, init=None):
        """Row-chunked execution inside shard_map.  With ``consume=None``
        the chunking collapses into the kernel itself (grid tiling — one
        launch, same as ``run``); with a consumer the pieces run through
        a ``lax.scan`` so chunk ``i``'s ``consume`` compute overlaps
        chunk ``i+1``'s gather+kernel, mirroring ShardMapTransport."""
        if chunks <= 0:
            raise ValueError(f"run_chunked: chunks must be >= 1, "
                             f"got {chunks}")
        assert buf.ndim >= 2, buf.shape
        slots, rows = buf.shape[0], buf.shape[1]
        if rows % chunks:
            raise ValueError(
                f"run_chunked: row count {rows} is not divisible by "
                f"chunks={chunks}")
        if consume is None:
            if self.axis_names is None:
                raise ValueError(
                    "PallasTransport.run_chunked needs axis_names")
            gbuf = jax.lax.all_gather(buf, self._axis_arg())
            gbuf = gbuf.reshape((self.nranks,) + buf.shape)
            out = self.run_global(schedule, gbuf, chunks=chunks)
            return jax.lax.dynamic_index_in_dim(
                out, _flat_rank(self.axis_names), axis=0, keepdims=False)
        rc = rows // chunks
        tail = buf.shape[2:]
        xs = buf.reshape((slots, chunks, rc) + tail).swapaxes(0, 1)

        def body(carry, xi):
            xc, i = xi
            return consume(carry, self.run(schedule, xc), i), None
        carry, _ = jax.lax.scan(
            body, init, (xs, jnp.arange(chunks, dtype=jnp.int32)))
        return carry

    def _axis_arg(self):
        return (self.axis_names if len(self.axis_names) > 1
                else self.axis_names[0])
