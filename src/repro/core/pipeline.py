"""Pipeline parallelism over a mesh axis (multi-pod strategy).

The pod boundary is a natural pipeline cut: DCN carries only the
activations of one microbatch per step (tiny vs. gradient allreduce).
This module provides a GPipe-style schedule written once in ``shard_map``
terms: every stage runs the same program; activations advance with a
static ``ppermute``; reverse-mode AD differentiates through the schedule
(the transpose of ``ppermute`` is the reverse shift), so one forward
definition yields the full fwd+bwd pipeline.

The schedule runs T = M + S - 1 ticks for M microbatches over S stages
(classic GPipe bubble of (S-1)/(M+S-1)); stage s computes microbatch m
at tick t = m + s.  Inputs are consumed on stage 0, outputs collected on
stage S-1 (and shipped back to stage 0 if ``return_to_first``).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat


def gpipe(stage_fn: Callable, params, x_ubatches: jax.Array,
          axis_name: str, *, return_to_first: bool = False) -> jax.Array:
    """Run ``stage_fn(params, x) -> y`` as an S-stage pipeline.

    Call inside ``shard_map``; ``axis_name`` is the pipeline axis.
      params:      this stage's parameters (already sharded over stages).
      x_ubatches:  [M, ub, ...] microbatch stream; only stage 0's copy is
                   read (other stages may carry zeros).
    Returns [M, ub, ...] outputs, valid on the last stage (or stage 0 if
    ``return_to_first``); other stages see zeros.
    """
    S = compat.axis_size(axis_name)
    stage = jax.lax.axis_index(axis_name)
    M = x_ubatches.shape[0]
    T = M + S - 1
    fwd = [(i, (i + 1) % S) for i in range(S)]

    state = jnp.zeros_like(x_ubatches[0])          # activation in flight
    ybuf = jnp.zeros((M,) + x_ubatches.shape[1:], x_ubatches.dtype)

    def tick(carry, t):
        state, ybuf = carry
        # stage 0 ingests microbatch t while it still has fresh ones
        m_in = jnp.clip(t, 0, M - 1)
        state = jnp.where(stage == 0, x_ubatches[m_in], state)
        y = stage_fn(params, state)
        # last stage banks microbatch m = t - (S - 1) when in range
        m_out = t - (S - 1)
        take = (stage == S - 1) & (m_out >= 0)
        ybuf = jax.lax.cond(
            take,
            lambda b: jax.lax.dynamic_update_index_in_dim(
                b, y.astype(b.dtype), jnp.clip(m_out, 0, M - 1), 0),
            lambda b: b, ybuf)
        # advance the wavefront (stage S-1 -> 0 wrap carries garbage that
        # stage 0 immediately overwrites with the next ingest)
        state = jax.lax.ppermute(y, axis_name, fwd)
        return (state, ybuf), None

    (_, ybuf), _ = jax.lax.scan(tick, (state, ybuf), jnp.arange(T))
    if return_to_first:
        ybuf = jax.lax.ppermute(ybuf, axis_name, [(S - 1, 0)])
    return ybuf


def stage_params_spec(n_layers: int, n_stages: int) -> list[range]:
    """Contiguous layer ranges per stage (remainder to the last stages)."""
    base, rem = divmod(n_layers, n_stages)
    out, start = [], 0
    for s in range(n_stages):
        k = base + (1 if s >= n_stages - rem else 0)
        out.append(range(start, start + k))
        start += k
    assert start == n_layers
    return out


# ---------------------------------------------------------------------------
# makespan-model view of the GPipe schedule (shared compute_events IR)
# ---------------------------------------------------------------------------


def gpipe_compute_events(n_microbatches: int, n_stages: int,
                         stage_seconds: float) -> tuple:
    """The pipeline's per-tick compute as executor ``ComputeEvent``s:
    tick ``t`` of the T = M + S - 1 wavefront is one opaque costed
    block of ``stage_seconds`` anchored after shift round ``t`` — the
    same vocabulary MoE dispatch and the grad-sync overlap register
    their consumer compute with, so the makespan model prices GPipe
    like any other pipelined schedule."""
    from repro.core.schedule import ComputeEvent

    T = n_microbatches + n_stages - 1
    return tuple(ComputeEvent(f"tick{t}", float(stage_seconds),
                              after_round=t) for t in range(T))


def gpipe_wavefront_schedule(n_microbatches: int, n_stages: int,
                             stage_seconds: float):
    """The GPipe wavefront as a ``CommSchedule`` + compute events.

    One ring-shift round per tick (the ``ppermute`` advancing the
    activation in flight) with a ``ComputeEvent`` per tick for the
    stage compute.  Consecutive shifts reuse the same slot (RAW), so
    no compaction pass may fuse them — the armed executor's makespan
    therefore reproduces the classic pipeline cost
    ``shift + sum(max(shift, compute)) + compute`` instead of the
    serial sum, without any GPipe-specific pricing code."""
    import numpy as np

    from repro.core.schedule import CommSchedule, make_round

    M, S = int(n_microbatches), int(n_stages)
    if M < 1 or S < 1:
        raise ValueError(
            f"gpipe_wavefront_schedule: need n_microbatches >= 1 and "
            f"n_stages >= 1, got {n_microbatches}, {n_stages}")
    T = M + S - 1
    edges = tuple((i, (i + 1) % S) for i in range(S))
    send = {s: [0] for s, _ in edges}
    recv = {d: [0] for _, d in edges}
    rounds = tuple(make_round(S, edges, send, recv) for _ in range(T))
    return CommSchedule(
        nranks=S, num_slots=1, rounds=rounds,
        name=f"gpipe.wavefront[m{M}.s{S}]",
        compute_events=gpipe_compute_events(M, S, stage_seconds))
