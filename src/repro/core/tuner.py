"""Empirical autotuned algorithm selection (the paper's §2.1 future work).

MPI Advance ships one fixed default per collective and names a "more
sophisticated selection process" as future work.  This module is that
process, done the way the collective-tuning literature (Hunold's
performance-guideline verification; the Wickramasinghe–Lumsdaine survey)
says it must be done: *measured*, per (collective, topology, size
bucket), with the resulting table checked against classic performance
guidelines and persisted for reuse.

Pipeline:

  1. ``tune(topo)`` times every registered ``Schedule`` (plus the raw
     XLA substrate) end-to-end through the ``mpix_*`` API under ``jit``
     on the live device mesh — wall clock, min over repeats.  With fewer
     devices than ranks it falls back to the alpha-beta
     ``Schedule.modeled_time`` so a table always exists.
  2. ``verify_guidelines`` checks the table against self-consistency
     guidelines (allreduce <= reduce_scatter + allgather; per-algorithm
     monotonicity in message size; specialized <= generic on multi-pod
     topologies) and records violations *in* the table — a violated
     guideline is a finding about the substrate, not an error.
  3. ``save_table``/``load_table`` persist winners as JSON keyed by a
     substrate fingerprint (device kind, nranks, ranks_per_pod), so
     ``selector.select(..., policy="tuned")`` is a pure lookup at trace
     time — zero run-time cost, like every other selection policy.

Cache location: ``$REPRO_TUNER_CACHE`` or
``~/.cache/repro/tuned_collectives.json``.

Caveat (multi-process SPMD): the winner is resolved from the local
cache file at trace time.  All processes of one job must see the same
cache file (shared filesystem, or ship the table with the job) —
otherwise two processes can bake different algorithms into the same
collective and deadlock.  Tune once, distribute the table, then launch.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path

import numpy as np

import jax

from repro import compat
# the neighbor vocabulary is shared with the selection layer (one source)
from repro.core.schedule import NotApplicable
from repro.core.selector import NEIGHBOR, NEIGHBOR_MODES
from repro.core.topology import Topology

COLLECTIVES = ("allgather", "allreduce", "reduce_scatter", "alltoall")
# non-dense paths tuned through the generic CommSchedule timer
PARTITIONED = "partitioned"
# pipelined compute-comm overlap (row-chunked alltoall + consumer
# compute, priced by the executor's makespan model)
OVERLAP = "overlap"
_OVERLAP_PARTS = (1, 2, 4, 8)
# transport substrate choice per size bucket: one ppermute launch per
# compiled round ("shardmap") vs the whole schedule as one device-side
# Pallas kernel ("pallas", core.pallas_lowering)
TRANSPORT = "transport"
_TRANSPORT_CHOICES = ("shardmap", "pallas")
# one XLA collective/kernel dispatch worth of host-side overhead (s) —
# the per-round alpha the single-kernel lowering amortizes away
_LAUNCH_S = 5e-6
DEFAULT_SIZES = (1 << 10, 1 << 14, 1 << 18, 1 << 22)   # bytes per rank
_AXIS = "tune"          # mesh axis name used for measurement runs
_ELEM = 4               # measurement payloads are float32


def default_cache_path() -> Path:
    env = os.environ.get("REPRO_TUNER_CACHE")
    if env:
        return Path(env).expanduser()
    return Path("~/.cache/repro/tuned_collectives.json").expanduser()


def size_bucket(nbytes: int) -> int:
    """log2 size bucket: bucket b covers (2**(b-1), 2**b] bytes.

    Degenerate 0/1-byte payloads clamp to bucket 0; negative sizes are
    a caller bug (a byte count can never be negative) and raise."""
    if nbytes < 0:
        raise ValueError(
            f"size_bucket: payload size must be >= 0 bytes, got {nbytes}")
    return max(0, int(max(1, nbytes) - 1).bit_length())


def substrate_fingerprint(topo: Topology, *, force_model: bool = False) -> str:
    """Fingerprint of what ``tune`` would measure on right now."""
    kind = "model"
    if not force_model and jax.device_count() >= topo.nranks:
        kind = jax.devices()[0].device_kind
    return topo.fingerprint(kind)


# ---------------------------------------------------------------------------
# table
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TunedTable:
    """Per-(collective, size-bucket) winners for one substrate.

    entries[collective][str(bucket)] = {
        "best": name, "nbytes": probed_size, "times": {name: seconds}}

    ``generation`` counts heal passes: every scoped re-measurement of
    guideline-violating cells (``retune_cells``) bumps it, so consumers
    can tell a freshly tuned table (0) from one that has been repaired.
    """

    fingerprint: str
    source: str                       # "measured" | "model"
    entries: dict
    violations: list = dataclasses.field(default_factory=list)
    generation: int = 0

    def lookup(self, collective: str, nbytes: int) -> str | None:
        """Winner for the bucket nearest to ``nbytes`` (None if absent)."""
        per = self.entries.get(collective)
        if not per:
            return None
        want = size_bucket(nbytes)
        bucket = min(per, key=lambda b: abs(int(b) - want))
        return per[bucket]["best"]

    def time_of(self, collective: str, nbytes: int,
                algorithm: str) -> float | None:
        per = self.entries.get(collective)
        if not per:
            return None
        want = size_bucket(nbytes)
        bucket = min(per, key=lambda b: abs(int(b) - want))
        return per[bucket]["times"].get(algorithm)

    def to_dict(self) -> dict:
        return {"fingerprint": self.fingerprint, "source": self.source,
                "entries": self.entries, "violations": self.violations,
                "generation": self.generation}

    @classmethod
    def from_dict(cls, d: dict) -> "TunedTable":
        return cls(fingerprint=d["fingerprint"], source=d["source"],
                   entries=d["entries"],
                   violations=list(d.get("violations", [])),
                   generation=int(d.get("generation", 0)))


def save_table(table: TunedTable, path: str | Path | None = None) -> Path:
    """Merge ``table`` into the fingerprint-keyed JSON cache file."""
    path = Path(path) if path is not None else default_cache_path()
    blob = {}
    if path.exists():
        try:
            blob = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            blob = {}
    blob[table.fingerprint] = table.to_dict()
    path.parent.mkdir(parents=True, exist_ok=True)
    # pid-unique tmp + atomic replace guards against torn writes and
    # cross-process tmp collisions (concurrent writers still last-win
    # on the whole file — it is a cache, re-tuning is always safe)
    tmp = path.with_suffix(f".{os.getpid()}.tmp")
    tmp.write_text(json.dumps(blob, indent=1, sort_keys=True))
    tmp.replace(path)
    _CACHE[table.fingerprint] = table
    return path


def load_table(fingerprint: str,
               path: str | Path | None = None) -> TunedTable | None:
    cached = _CACHE.get(fingerprint)
    if cached is not None:
        return None if cached is _MISS else cached
    path = Path(path) if path is not None else default_cache_path()
    blob = None
    if path.exists():
        try:
            blob = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            blob = None
    if blob is None or fingerprint not in blob:
        # negative-cache the miss: tuned-policy selection on an untuned
        # substrate must not re-read the file per collective per trace
        _CACHE[fingerprint] = _MISS
        return None
    table = TunedTable.from_dict(blob[fingerprint])
    _CACHE[fingerprint] = table
    return table


_MISS = object()
_CACHE: dict[str, object] = {}


def clear_cache() -> None:
    """Drop the in-process table cache (tests; after cache-file edits)."""
    _CACHE.clear()


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------


def _probe_spec(collective: str, topo: Topology, nbytes: int):
    """(local_rows, out_is_sharded) for a ~nbytes-per-rank payload."""
    n = topo.nranks
    elems = max(1, nbytes // _ELEM)
    if collective in ("allgather", "allreduce"):
        return elems, False
    # reduce_scatter / alltoall need a leading dim divisible by nranks
    return n * max(1, elems // n), True


def _measure(collective: str, algorithm: str, topo: Topology, nbytes: int,
             repeats: int) -> float:
    """Wall clock of one mpix collective under jit on the live mesh."""
    from jax.sharding import PartitionSpec as P
    from repro.core import api

    n = topo.nranks
    mesh = compat.make_mesh((n,), (_AXIS,), devices=jax.devices()[:n])
    rows, sharded_out = _probe_spec(collective, topo, nbytes)
    fn = getattr(api, f"mpix_{collective}")
    body = lambda v: fn(v, _AXIS, algorithm=algorithm, topo=topo)
    f = jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=P(_AXIS),
        out_specs=P(_AXIS) if sharded_out else P(None), check_vma=False))
    x = np.ones((n * rows,), np.float32)
    jax.block_until_ready(f(x))            # compile + warm the caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        best = min(best, time.perf_counter() - t0)
    return best


def _modeled(sched, topo: Topology, nbytes: int) -> float:
    """alpha-beta model of what would actually execute: the *compiled*
    schedule (post fusion, cost-model-armed with ``topo``), so
    model-source tables reward the same round-count cuts the measured
    path enjoys."""
    from repro.core import executor

    block = max(1, nbytes // max(1, sched.num_blocks))
    return executor.get_executor(
        sched, topo=topo).compiled_schedule.modeled_time(topo, block)


def _candidates(collective: str, topo: Topology) -> dict:
    """Buildable schedules for one collective on this topology."""
    from repro.core.algorithms import REGISTRY

    out = {}
    for name, builder in REGISTRY[collective].items():
        try:
            out[name] = builder(topo)
        except NotApplicable:            # e.g. power-of-2-only variants
            continue
    return out


def _compiled_rounds(sched, topo: Topology | None = None) -> dict:
    """Round counts through the persistent-executor compile pass
    (topology-armed when ``topo`` is given, matching the executor the
    measurement path looks up) — recorded next to every timing so the
    table shows *what executed*."""
    from repro.core import executor

    ex = executor.get_executor(sched, topo=topo)
    return {"before": ex.rounds_before, "after": ex.rounds_after}


def _time_cell(collective: str, candidates: dict, topo: Topology,
               nbytes: int, *, measured: bool, repeats: int,
               include_xla: bool) -> dict:
    """Time every candidate for one (collective, size) cell."""
    times: dict = {}
    rounds: dict = {}
    for name, sched in candidates.items():
        if measured:
            times[name] = _measure(collective, name, topo, int(nbytes),
                                   repeats)
        else:
            times[name] = _modeled(sched, topo, int(nbytes))
        rounds[name] = _compiled_rounds(sched, topo)
    if measured and include_xla:
        # the substrate's own lowering — MPI Advance's "system MPI"
        times["xla"] = _measure(collective, "xla", topo, int(nbytes),
                                repeats)
    assert times, (collective, nbytes)
    return {"best": min(times, key=times.get), "nbytes": int(nbytes),
            "times": {k: float(v) for k, v in times.items()},
            "rounds": rounds}


# ---------------------------------------------------------------------------
# generic CommSchedule timing (any path: dense, neighbor, partitioned)
# ---------------------------------------------------------------------------


class MeasurementTimeout(RuntimeError):
    """A timed execution overran its cooperative deadline (a hung
    round, an injected chaos stall).  Typed so probe/tuning callers can
    keep prior measurements and record the skip instead of wedging."""


def measure_schedule(schedule, topo: Topology, *, slot_elems: int = 1,
                     repeats: int = 3, fill=None,
                     deadline_s: float | None = None) -> float:
    """Wall clock of one ``CommSchedule`` executed by ShardMapTransport
    under jit on the live mesh (requires >= topo.nranks devices).

    Works for every schedule the IR can express — dense block tables,
    neighborhood plans, partitioned transfers — which is what lets one
    tuner cover every path.  ``slot_elems`` is the float32 width of one
    buffer slot; ``fill`` optionally seeds the per-rank buffers.

    ``deadline_s`` bounds the WHOLE measurement (compile + warm +
    repeats) cooperatively: overrun raises ``MeasurementTimeout`` at
    the next completion point instead of returning a poisoned sample —
    a hung probe surfaces as a typed skip, not a wedged daemon.  (A
    stall that never returns needs the thread-level timeout in
    ``linkprobe.probe_links``; this check catches the common case where
    the call eventually finishes, far too late to trust.)
    """
    from jax.sharding import PartitionSpec as P
    from repro.core.transport import ShardMapTransport

    n = topo.nranks
    if jax.device_count() < n:
        raise RuntimeError(f"need {n} devices, have {jax.device_count()}")
    if deadline_s is not None and deadline_s <= 0:
        raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
    start = time.perf_counter()

    def check(stage: str) -> None:
        if deadline_s is None:
            return
        dt = time.perf_counter() - start
        if dt > deadline_s:
            raise MeasurementTimeout(
                f"measure_schedule({schedule.name}): {stage} at "
                f"{dt:.3f}s exceeded deadline {deadline_s:.3f}s")

    mesh = compat.make_mesh((n,), (_AXIS,), devices=jax.devices()[:n])
    transport = ShardMapTransport(n, _AXIS, topo=topo)
    f = jax.jit(compat.shard_map(
        lambda b: transport.run(schedule, b), mesh=mesh,
        in_specs=P(_AXIS), out_specs=P(_AXIS), check_vma=False))
    x = (np.ones((n * schedule.num_slots, slot_elems), np.float32)
         if fill is None else fill)
    jax.block_until_ready(f(x))            # compile + warm the caches
    check("warmup")
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        best = min(best, time.perf_counter() - t0)
        check("repeat")
    return best


def schedule_time(schedule, topo: Topology, *, slot_nbytes: int,
                  repeats: int = 3, force_model: bool = False) -> float:
    """Time any CommSchedule: measured on the live mesh when it fits
    (which executes through the compiled/fused path), alpha-beta model
    of the *compiled* schedule otherwise — both branches price the same
    rounds."""
    if not force_model and jax.device_count() >= topo.nranks:
        return measure_schedule(
            schedule, topo, slot_elems=max(1, slot_nbytes // _ELEM),
            repeats=repeats)
    from repro.core import executor
    return executor.get_executor(
        schedule, topo=topo).compiled_schedule.modeled_time(
            topo, slot_nbytes)


def verify_overhead_s(schedule, topo: Topology, *, slot_nbytes: int,
                      verify: str = "canary") -> float:
    """Modeled cost of ``core.resilient``'s per-run integrity check, so
    resilience is priced like any other knob the tuner owns.

    "canary" is verification WITHOUT a second execution: one host pass
    over the result region plus the canary row — ``(result_slots + 1) *
    slot_nbytes`` bytes at HBM bandwidth.  "full" adds one trusted
    reference execution of the schedule (alpha-beta modeled) plus a
    second result-region pass for the bitwise compare.  "off" is free.
    The bench's chaos section gates the modeled canary overhead staying
    a tiny fraction of the schedule's own modeled time.
    """
    from repro.core.topology import HBM_BW
    if verify == "off":
        return 0.0
    scan = (schedule.result_slots + 1) * max(1, int(slot_nbytes)) / HBM_BW
    if verify == "canary":
        return scan
    if verify == "full":
        return (schedule.modeled_time(topo, slot_nbytes) + 2 * scan)
    raise ValueError(f"unknown verify mode {verify!r}; "
                     f"expected off/canary/full")


def tune(topo: Topology, *, collectives=COLLECTIVES, sizes=DEFAULT_SIZES,
         repeats: int = 3, include_xla: bool = True,
         force_model: bool = False, tol: float = 1.10) -> TunedTable:
    """Time every candidate per (collective, size bucket); return the table.

    Measures wall clock on the live device mesh when the host has at
    least ``topo.nranks`` devices, else falls back to the alpha-beta
    model (and records ``source="model"`` so the fingerprint can never
    collide with a measured table).
    """
    measured = (not force_model) and jax.device_count() >= topo.nranks
    entries: dict = {}
    for coll in collectives:
        candidates = _candidates(coll, topo)
        per: dict = {}
        for nbytes in sizes:
            per[str(size_bucket(int(nbytes)))] = _time_cell(
                coll, candidates, topo, int(nbytes), measured=measured,
                repeats=repeats, include_xla=include_xla)
        entries[coll] = per
    table = TunedTable(
        fingerprint=substrate_fingerprint(topo, force_model=force_model),
        source="measured" if measured else "model",
        entries=entries)
    table.violations = verify_guidelines(table, topo, tol=tol)
    return table


# ---------------------------------------------------------------------------
# neighbor + partitioned paths (generic CommSchedule timing)
# ---------------------------------------------------------------------------


def tune_neighbor(topo: Topology, *, sizes=DEFAULT_SIZES, repeats: int = 3,
                  force_model: bool = False, graph=None, n_local: int = 8,
                  dup_frac: float = 0.5) -> dict:
    """Per-size-bucket winners for the standard-vs-locality-aware choice.

    Times both compiled plans of a representative sparse exchange
    (seeded ``CommGraph.random`` unless ``graph`` is given) through the
    shared transports; buckets key on the exchange's total standard-plan
    byte volume, which is what ``selector.select_neighbor`` looks up.
    Returns the ``entries[NEIGHBOR]`` dict.
    """
    from repro.core.plan import CommGraph, build_plan

    n = topo.nranks
    if graph is None:
        rng = np.random.default_rng(0)
        graph = CommGraph.random(n, n_local=n_local,
                                 degree=min(n - 1, 4), rng=rng,
                                 dup_frac=dup_frac)
    total_rows = graph.total_values()
    plans = {mode: build_plan(graph, topo,
                              aggregate=mode == "locality_aware")
             for mode in NEIGHBOR_MODES}
    per: dict = {}
    for nbytes in sizes:
        # max(1, ...) guards degenerate exchanges (a 1-rank topology's
        # random graph has no edges -> zero value rows)
        slot_nbytes = _ELEM * max(1, int(nbytes) // max(1, total_rows * _ELEM))
        times = {
            mode: schedule_time(plan.schedule, topo,
                                slot_nbytes=slot_nbytes, repeats=repeats,
                                force_model=force_model)
            for mode, plan in plans.items()
        }
        # key on the requested probe size (like every other path) so two
        # sizes never collapse into one bucket when slot_nbytes floors
        # on a large graph; "nbytes" records the actual probed volume
        per[str(size_bucket(int(nbytes)))] = {
            "best": min(times, key=times.get),
            "nbytes": total_rows * slot_nbytes,
            "times": {k: float(v) for k, v in times.items()},
            "rounds": {mode: _compiled_rounds(plan.schedule, topo)
                       for mode, plan in plans.items()},
        }
    return per


def tune_partitioned(topo: Topology, *, sizes=DEFAULT_SIZES,
                     repeats: int = 3, force_model: bool = False) -> dict:
    """Per-size-bucket winners for the MPIPCL partition-count choice
    (REGISTRY["partitioned"]: p1/p2/p4/p8 chunked shifts)."""
    from repro.core.algorithms import REGISTRY

    per: dict = {}
    for nbytes in sizes:
        times: dict = {}
        rounds: dict = {}
        for name, builder in REGISTRY[PARTITIONED].items():
            sched = builder(topo)
            chunks = sched.result_slots
            slot_nbytes = max(1, int(nbytes) // chunks)
            times[name] = schedule_time(
                sched, topo, slot_nbytes=slot_nbytes, repeats=repeats,
                force_model=force_model)
            rounds[name] = _compiled_rounds(sched, topo)
        per[str(size_bucket(int(nbytes)))] = {
            "best": min(times, key=times.get),
            "nbytes": int(nbytes),
            "times": {k: float(v) for k, v in times.items()},
            "rounds": rounds,
        }
    return per


def tune_overlap(topo: Topology, *, sizes=DEFAULT_SIZES,
                 repeats: int = 3, force_model: bool = False,
                 compute_ratio: float = 1.0) -> dict:
    """Per-size-bucket chunk counts for pipelined alltoall + consumer
    compute (``mpix_alltoall_overlap``): each candidate pK prices the
    row-chunked software pipeline via the armed executor's
    ``chunked_makespan`` — per-chunk transfer overlapping the previous
    chunk's compute slice — against ``compute_ratio`` * the serial
    transfer time of consumer compute.  p1 is the unpipelined serial
    baseline and wins ties, so the committed choice can never lose to
    it (the ``pipelined <= armed`` guideline below re-verifies this on
    every load).  Pricing is purely the makespan model — overlap is a
    scheduling property the wall clock of a simulated substrate cannot
    observe — so ``repeats``/``force_model`` are accepted only for
    signature uniformity with the other tune_* entries."""
    del repeats, force_model
    from repro.core import executor

    cands = _candidates("alltoall", topo)
    per: dict = {}
    for nbytes in sizes:
        name = min(cands,
                   key=lambda a: _modeled(cands[a], topo, int(nbytes)))
        sched = cands[name]
        block = max(1, int(nbytes) // max(1, sched.num_blocks))
        ex = executor.get_executor(sched, topo=topo)
        compute_s = (ex.compiled_schedule.modeled_time(topo, block)
                     * compute_ratio)
        times = {f"p{p}": float(ex.chunked_makespan(block, p, compute_s))
                 for p in _OVERLAP_PARTS}
        best = min(times, key=lambda k: (times[k], int(k[1:])))
        per[str(size_bucket(int(nbytes)))] = {
            "best": best,
            "nbytes": int(nbytes),
            "times": times,
            "schedule": name,
            "compute_s": float(compute_s),
        }
    return per


def _transport_times(topo: Topology, nbytes: int) -> dict:
    """Model both substrates for one payload size, on the MoE hot path's
    collective (alltoall — the same representative ``tune_overlap``
    prices).

    shardmap: armed modeled transfer time + one launch per compiled
    round.  pallas: one all_gather of the full per-rank buffer (the
    bandwidth cost of replicated execution: block = nbytes, not
    nbytes/n) + one collective launch + one kernel launch.  The
    crossover is real: alpha-dominated small buckets amortize R
    launches into 2, beta-dominated large ones pay the n× gather."""
    from repro.core import executor

    cands = _candidates("alltoall", topo)
    name = min(cands, key=lambda a: _modeled(cands[a], topo, int(nbytes)))
    sched = cands[name]
    ex = executor.get_executor(sched, topo=topo)
    block = max(1, int(nbytes) // max(1, sched.num_blocks))
    t_shard = (ex.compiled_schedule.modeled_time(topo, block)
               + ex.rounds_after * _LAUNCH_S)
    ag = _candidates("allgather", topo)
    t_gather = min(_modeled(ag[a], topo, int(nbytes) * topo.nranks)
                   for a in ag)
    t_pallas = t_gather + 2 * _LAUNCH_S
    return {"schedule": name, "rounds": int(ex.rounds_after),
            "times": {"shardmap": float(t_shard),
                      "pallas": float(t_pallas)}}


def tune_transport(topo: Topology, *, sizes=DEFAULT_SIZES,
                   repeats: int = 3, force_model: bool = False) -> dict:
    """Per-size-bucket transport winners (``transport="auto"`` in the
    mpix_* API).  Pricing is purely the alpha-beta + launch model: on a
    host without the real accelerator the pallas kernel runs under the
    interpreter, whose wall clock measures the interpreter, not the
    device — ``repeats``/``force_model`` are accepted only for
    signature uniformity with the other tune_* entries."""
    del repeats, force_model
    per: dict = {}
    for nbytes in sizes:
        cell = _transport_times(topo, int(nbytes))
        times = cell["times"]
        # ties go to shardmap (never pay the n× gather for free)
        best = min(_TRANSPORT_CHOICES, key=lambda k: (times[k],
                                                      k != "shardmap"))
        per[str(size_bucket(int(nbytes)))] = {
            "best": best,
            "nbytes": int(nbytes),
            "times": times,
            "schedule": cell["schedule"],
            "rounds": cell["rounds"],
        }
    return per


def select_transport(topo: Topology, nbytes: int, *,
                     policy: str | None = None,
                     table: TunedTable | None = None,
                     path: str | Path | None = None) -> str:
    """Substrate for ``transport="auto"``: "shardmap" or "pallas".

    policy "fixed" always returns "shardmap" (the pre-device-side
    default); "tuned" reads the persisted ``TRANSPORT`` winner (falling
    back to the model when no table/section exists); anything else
    prices both substrates with the launch-aware model."""
    if policy == "fixed":
        return "shardmap"
    if policy == "tuned":
        if table is None:
            for fp in (substrate_fingerprint(topo),
                       topo.fingerprint("model")):
                table = load_table(fp, path=path)
                if table is not None:
                    break
        if table is not None:
            name = table.lookup(TRANSPORT, int(nbytes))
            if name in _TRANSPORT_CHOICES:
                return name
        # no table / no TRANSPORT section: fall through to model pricing
    times = _transport_times(topo, int(nbytes))["times"]
    return min(_TRANSPORT_CHOICES, key=lambda k: (times[k],
                                                  k != "shardmap"))


def select_overlap_chunks(topo: Topology, nbytes: int, compute_s: float,
                          *, policy: str | None = None,
                          table: TunedTable | None = None,
                          path: str | Path | None = None) -> int:
    """Chunk count for ``mpix_alltoall_overlap``'s auto mode.

    policy "tuned" reads the persisted ``OVERLAP`` winner for this
    substrate (falling back to model pricing when no table exists);
    "fixed" always returns 1 (unpipelined — the paper-default ladder
    rung); anything else prices the software pipeline with the CALLER's
    ``compute_s`` through ``chunked_makespan`` and returns the argmin
    over p in {1, 2, 4, 8} (ties to the smallest — never pipeline for
    free)."""
    if policy == "fixed":
        return 1
    if policy == "tuned":
        if table is None:
            for fp in (substrate_fingerprint(topo),
                       topo.fingerprint("model")):
                table = load_table(fp, path=path)
                if table is not None:
                    break
        if table is not None:
            name = table.lookup(OVERLAP, int(nbytes))
            if (isinstance(name, str) and len(name) > 1
                    and name[0] == "p" and name[1:].isdigit()):
                return max(1, int(name[1:]))
        # no table / no OVERLAP section: fall through to model pricing
    from repro.core import executor

    cands = _candidates("alltoall", topo)
    name = min(cands, key=lambda a: _modeled(cands[a], topo, int(nbytes)))
    sched = cands[name]
    block = max(1, int(nbytes) // max(1, sched.num_blocks))
    ex = executor.get_executor(sched, topo=topo)
    return min(_OVERLAP_PARTS,
               key=lambda p: (ex.chunked_makespan(block, p, compute_s), p))


def autotune(topo: Topology, *, path: str | Path | None = None,
             sizes=DEFAULT_SIZES, repeats: int = 3,
             force_model: bool = False, tol: float = 1.10,
             include_xla: bool = True) -> TunedTable:
    """Tune every path — dense collectives, the neighborhood
    standard-vs-locality-aware crossover, partitioned chunk counts —
    into one persisted table for this substrate.

    This is the one-stop entry the launchers call: after it returns,
    ``policy="tuned"`` resolves every mpix_* collective *and*
    ``build_plan(..., aggregate=None)`` from measured winners.
    """
    table = tune(topo, sizes=sizes, repeats=repeats,
                 include_xla=include_xla, force_model=force_model, tol=tol)
    table.entries[NEIGHBOR] = tune_neighbor(
        topo, sizes=sizes, repeats=repeats, force_model=force_model)
    table.entries[PARTITIONED] = tune_partitioned(
        topo, sizes=sizes, repeats=repeats, force_model=force_model)
    table.entries[OVERLAP] = tune_overlap(
        topo, sizes=sizes, repeats=repeats, force_model=force_model)
    table.entries[TRANSPORT] = tune_transport(
        topo, sizes=sizes, repeats=repeats, force_model=force_model)
    table.violations = verify_guidelines(table, topo, tol=tol)
    save_table(table, path=path)
    return table


# ---------------------------------------------------------------------------
# performance guidelines (Hunold-style self-consistency checks)
# ---------------------------------------------------------------------------


def _guideline_findings(table: TunedTable, topo: Topology | None = None,
                        *, tol: float = 1.10) -> list:
    """Guideline check core: list of (message, offending-cells) pairs.

    A cell is a ``(collective, bucket)`` key into ``table.entries`` —
    the unit the auto-retune loop re-measures (``retune_cells``).
    """
    out: list = []
    e = table.entries

    def best(coll, bucket):
        rec = e.get(coll, {}).get(bucket)
        return rec["times"][rec["best"]] if rec else None

    # composition: allreduce <= reduce_scatter + allgather, per bucket
    shared = (set(e.get("allreduce", {}))
              & set(e.get("reduce_scatter", {}))
              & set(e.get("allgather", {})))
    for b in sorted(shared, key=int):
        ar, rs, ag = (best("allreduce", b), best("reduce_scatter", b),
                      best("allgather", b))
        if ar is not None and ar > tol * (rs + ag):
            out.append((
                f"allreduce>rs+ag @bucket {b}: {ar:.3e} > "
                f"{rs:.3e}+{ag:.3e} (guideline: composed implementation "
                f"bounds the specialized one)",
                (("allreduce", b), ("reduce_scatter", b),
                 ("allgather", b))))

    # monotonicity in message size, per (collective, algorithm)
    for coll, per in e.items():
        buckets = sorted(per, key=int)
        for lo, hi in zip(buckets, buckets[1:]):
            for name, t_lo in per[lo]["times"].items():
                t_hi = per[hi]["times"].get(name)
                if t_hi is not None and t_lo > tol * t_hi:
                    out.append((
                        f"{coll}.{name} non-monotone: bucket {lo} "
                        f"({t_lo:.3e}s) > bucket {hi} ({t_hi:.3e}s)",
                        ((coll, lo), (coll, hi))))

    # specialized <= generic on multi-pod substrates (largest bucket):
    # the 2-level hierarchical variant on any multi-pod topology, and
    # the fully level-aware staged variant on 3+-level hierarchies.
    if topo is not None and topo.npods > 1:
        from repro.core.selector import _FIXED
        specialized = ["hierarchical"]
        if len(topo.levels) >= 3:
            specialized.append("staged")
        for coll, per in e.items():
            if not per or coll not in _FIXED:
                continue
            b = max(per, key=int)
            times = per[b]["times"]
            flat_default = _FIXED[coll][0]
            for name in specialized:
                if (name in times and flat_default in times
                        and times[name] > tol * times[flat_default]):
                    out.append((
                        f"{coll}.{name} slower than flat "
                        f"{flat_default} @bucket {b} on multi-pod topo "
                        f"({times[name]:.3e} > "
                        f"{times[flat_default]:.3e})",
                        ((coll, b),)))

    # neighbor: aggregate <= standard on multi-pod (largest bucket)
    if topo is not None and topo.npods > 1 and e.get(NEIGHBOR):
        per = e[NEIGHBOR]
        b = max(per, key=int)
        times = per[b]["times"]
        if ("locality_aware" in times and "standard" in times
                and times["locality_aware"] > tol * times["standard"]):
            out.append((
                f"{NEIGHBOR}.locality_aware slower than standard "
                f"@bucket {b} on multi-pod topo "
                f"({times['locality_aware']:.3e} > "
                f"{times['standard']:.3e})",
                ((NEIGHBOR, b),)))

    # overlap: the committed pipelined plan never loses to the serial
    # p1 baseline (pipelined <= armed, the new rung of the chain; pK
    # entries MAY exceed p1 — alpha-dominated sizes lose to chunking
    # and the selection simply keeps p1, which is not a violation)
    for b, rec in sorted(e.get(OVERLAP, {}).items(),
                         key=lambda kv: int(kv[0])):
        t_best = rec["times"].get(rec["best"])
        t_p1 = rec["times"].get("p1")
        if (t_best is not None and t_p1 is not None
                and t_best > tol * t_p1):
            out.append((
                f"{OVERLAP}.{rec['best']} slower than unpipelined p1 "
                f"@bucket {b} ({t_best:.3e} > {t_p1:.3e}) (guideline: "
                f"pipelined <= armed serial)",
                ((OVERLAP, b),)))
    return out


def verify_guidelines(table: TunedTable, topo: Topology | None = None,
                      *, tol: float = 1.10) -> list:
    """Return human-readable violations of classic performance guidelines.

    Checked (each with ``tol`` relative slack):
      * composition:   allreduce(s) <= reduce_scatter(s) + allgather(s)
      * monotonicity:  per algorithm, time never decreases with size
      * specialized <= generic: on multi-pod topologies the
        locality-aware ``hierarchical`` variant (and, on 3+-level
        hierarchies, the ``staged`` variant) should not lose to the
        flat default for the largest probed bucket
      * neighbor aggregation: on multi-pod topologies the
        locality-aware plan should not lose to the standard plan for
        the largest probed bucket (aggregate <= standard)
      * overlap: per bucket, the committed pipelined chunk count never
        loses to the unpipelined p1 baseline (pipelined <= armed)
    """
    return [msg for msg, _ in _guideline_findings(table, topo, tol=tol)]


def violation_cells(table: TunedTable, topo: Topology | None = None,
                    *, tol: float = 1.10) -> list:
    """Unique (collective, bucket) cells implicated in any guideline
    violation, in finding order — the auto-retune work list."""
    cells, seen = [], set()
    for _, cs in _guideline_findings(table, topo, tol=tol):
        for cell in cs:
            if cell not in seen:
                seen.add(cell)
                cells.append(cell)
    return cells


# ---------------------------------------------------------------------------
# selection entry point (used by selector.select(policy="tuned"))
# ---------------------------------------------------------------------------


def tuned_select(collective: str, topo: Topology, nbytes: int,
                 table: TunedTable | None = None,
                 path: str | Path | None = None) -> str | None:
    """Winner from the persisted table, or None when no table applies.

    Tries the measured-substrate fingerprint first, then the model
    fingerprint.  The winner is validated against the live registry (a
    stale table naming a removed algorithm is ignored).
    """
    if table is None:
        for fp in (substrate_fingerprint(topo),
                   topo.fingerprint("model")):
            table = load_table(fp, path=path)
            if table is not None:
                break
    if table is None:
        return None
    name = table.lookup(collective, nbytes)
    if name is None or name == "xla":
        return name
    if collective == NEIGHBOR:
        return name if name in NEIGHBOR_MODES else None
    # registry-membership check only: the fingerprint guarantees the
    # table's topology matches the query, so the winner built for it at
    # tuning time — only a renamed/removed algorithm can be stale here
    from repro.core.algorithms import REGISTRY
    if name not in REGISTRY.get(collective, {}):
        return None
    return name


def stale_cells(table: TunedTable, topo: Topology) -> list:
    """Cells missing a currently-registered candidate: the table was
    tuned before that algorithm landed (or before a neighbor mode /
    partition count was added), so its winners never saw the newcomer.
    These join the heal work list alongside guideline violations.

    Cost discipline: the registry name diff runs first, and only names
    absent from a cell are test-built — a name that raises
    ``NotApplicable`` on this topology (pow2-only variants on odd rank
    counts) is permanently inapplicable, not stale.  A healthy table
    never constructs a full candidate set here."""
    from repro.core.algorithms import REGISTRY

    out = []
    for coll, per in table.entries.items():
        if coll in COLLECTIVES:
            registered = set(REGISTRY[coll])
            buildable: dict = {}          # name -> builds on this topo?
            for bucket, rec in per.items():
                stale = False
                for name in registered - set(rec["times"]):
                    if name not in buildable:
                        try:
                            REGISTRY[coll][name](topo)
                            buildable[name] = True
                        except NotApplicable:
                            buildable[name] = False
                    stale = stale or buildable[name]
                if stale:
                    out.append((coll, bucket))
            continue
        if coll == NEIGHBOR:
            want = set(NEIGHBOR_MODES)
        elif coll == PARTITIONED:
            want = set(REGISTRY[PARTITIONED])
        elif coll == OVERLAP:
            want = {f"p{p}" for p in _OVERLAP_PARTS}
        elif coll == TRANSPORT:
            want = set(_TRANSPORT_CHOICES)
        else:
            continue
        for bucket, rec in per.items():
            if want - set(rec["times"]):
                out.append((coll, bucket))
    return out


def _cell_differs(fresh: dict, rec: dict, tol: float) -> bool:
    """Selection-meaningful difference between two timings of one cell:
    a different winner, a different candidate set, or any timing moved
    by more than the guideline slack ``tol`` (so measurement noise on a
    live substrate does not count a re-confirmed cell as changed)."""
    if fresh["best"] != rec["best"]:
        return True
    if set(fresh["times"]) != set(rec["times"]):
        return True
    for name, t in fresh["times"].items():
        old = rec["times"][name]
        if t > old * tol or old > t * tol:
            return True
    return False


def _model_cell(coll: str, topo: Topology, nbytes: int) -> dict | None:
    """Model-priced timing of one (collective, size) cell under ``topo``
    — the cheap probe ``drift_cells`` uses to ask "would this cell's
    selection change under the new links?" without re-measuring."""
    if coll in COLLECTIVES:
        return _time_cell(coll, _candidates(coll, topo), topo, nbytes,
                          measured=False, repeats=1, include_xla=False)
    if coll == NEIGHBOR:
        tuned = tune_neighbor(topo, sizes=(nbytes,), repeats=1,
                              force_model=True)
    elif coll == PARTITIONED:
        tuned = tune_partitioned(topo, sizes=(nbytes,), repeats=1,
                                 force_model=True)
    elif coll == OVERLAP:
        tuned = tune_overlap(topo, sizes=(nbytes,), repeats=1,
                             force_model=True)
    elif coll == TRANSPORT:
        tuned = tune_transport(topo, sizes=(nbytes,), repeats=1,
                               force_model=True)
    else:
        return None
    return next(iter(tuned.values()))


def drift_cells(table: TunedTable, old_topo: Topology, new_topo: Topology,
                *, tol: float = 1.10) -> list:
    """Cells of ``table`` whose selection the link-model drift from
    ``old_topo`` to ``new_topo`` could plausibly move — the scoped
    re-measurement work list for the online healing daemon.

    Every cell is priced TWICE through the alpha-beta model (cheap —
    the executors are cached), once per geometry, and included iff the
    two pricings differ selection-meaningfully (``_cell_differs``: best
    flipped, candidate set changed, or any timing beyond ``tol``).
    Comparing model-vs-model isolates the drift's effect: comparing a
    fresh model pricing against a recorded *measured* timing would flag
    every cell on every tick.  A beta-only DCN degradation therefore
    leaves alpha-dominated small buckets (and DCN-free collectives) off
    the list entirely — the "no full re-tune" guarantee.
    """
    out = []
    for coll, per in table.entries.items():
        for bucket, rec in sorted(per.items(), key=lambda kv: int(kv[0])):
            nbytes = int(rec["nbytes"])
            old_cell = _model_cell(coll, old_topo, nbytes)
            new_cell = _model_cell(coll, new_topo, nbytes)
            if old_cell is None or new_cell is None:
                continue
            if _cell_differs(new_cell, old_cell, tol):
                out.append((coll, bucket))
    return out


def retune_cells(table: TunedTable, topo: Topology, cells,
                 *, repeats: int = 3, force_model: bool = False,
                 include_xla: bool = True, tol: float = 1.10) -> list:
    """Scoped auto-retune: re-measure ONLY the given (collective,
    bucket) cells of ``table`` in place, at each cell's recorded probe
    size; untouched cells keep their timings.  Re-verifies the
    guidelines and returns the cells whose entries meaningfully changed
    (see ``_cell_differs``); ``generation`` is bumped iff any did — so
    a violation the substrate genuinely exhibits, re-confirmed within
    noise on every heal, is recorded as a finding without inflating the
    generation or churning the persisted file.

    This is the Hunold loop's repair step: a guideline violation is a
    finding about *specific* table cells (stale after a driver update,
    a noisy measurement, a topology drift), so healing re-measures those
    cells instead of throwing away the whole table.
    """
    measured = (not force_model) and jax.device_count() >= topo.nranks
    dense_candidates: dict = {}       # full sets, built once per coll
    retuned: list = []
    for coll, bucket in cells:
        rec = table.entries.get(coll, {}).get(bucket)
        if rec is None:
            continue
        nbytes = int(rec["nbytes"])
        if coll in COLLECTIVES:
            if coll not in dense_candidates:
                dense_candidates[coll] = _candidates(coll, topo)
            fresh = _time_cell(coll, dense_candidates[coll], topo, nbytes,
                               measured=measured, repeats=repeats,
                               include_xla=include_xla)
        elif coll == NEIGHBOR:
            fresh = next(iter(tune_neighbor(
                topo, sizes=(nbytes,), repeats=repeats,
                force_model=force_model).values()))
        elif coll == PARTITIONED:
            fresh = next(iter(tune_partitioned(
                topo, sizes=(nbytes,), repeats=repeats,
                force_model=force_model).values()))
        elif coll == OVERLAP:
            fresh = next(iter(tune_overlap(
                topo, sizes=(nbytes,), repeats=repeats,
                force_model=force_model).values()))
        elif coll == TRANSPORT:
            fresh = next(iter(tune_transport(
                topo, sizes=(nbytes,), repeats=repeats,
                force_model=force_model).values()))
        else:
            continue
        if _cell_differs(fresh, rec, tol):
            table.entries[coll][bucket] = fresh
            retuned.append((coll, bucket))
    if retuned:
        table.generation += 1
    table.violations = verify_guidelines(table, topo, tol=tol)
    return retuned


def heal_table(table: TunedTable, topo: Topology, *,
               path: str | Path | None = None, repeats: int = 3,
               force_model: bool = False, include_xla: bool = True,
               tol: float = 1.10) -> list:
    """Verify-and-repair one loaded table: re-measure only the
    guideline-violating cells plus any cells missing a currently
    registered candidate (``stale_cells`` — tables tuned before a new
    algorithm landed), persisting iff something meaningfully changed.
    Returns the changed cells.  Shared by ``ensure_table`` and the
    launchers' ``--autotune`` reuse path."""
    cells = violation_cells(table, topo, tol=tol)
    seen = set(cells)
    cells += [c for c in stale_cells(table, topo) if c not in seen]
    if not cells:
        return []
    changed = retune_cells(table, topo, cells, repeats=repeats,
                           force_model=force_model,
                           include_xla=include_xla, tol=tol)
    if changed:
        save_table(table, path=path)
    return changed


def ensure_table(topo: Topology, *, path: str | Path | None = None,
                 heal: bool = True, collectives=COLLECTIVES,
                 sizes=DEFAULT_SIZES, repeats: int = 3,
                 include_xla: bool = True, force_model: bool = False,
                 tol: float = 1.10) -> TunedTable:
    """Load the table for the current substrate, tuning once if missing.

    With ``heal=True`` (default) a loaded table is re-verified against
    the performance guidelines (plus candidate coverage); any violation
    triggers ``retune_cells`` on only the offending (collective,
    size-bucket) cells — never a full re-tune — and the healed table is
    persisted with a bumped ``generation``.
    """
    fp = substrate_fingerprint(topo, force_model=force_model)
    table = load_table(fp, path=path)
    if table is None:
        table = tune(topo, collectives=collectives, sizes=sizes,
                     repeats=repeats, include_xla=include_xla,
                     force_model=force_model, tol=tol)
        save_table(table, path=path)
        return table
    if heal:
        heal_table(table, topo, path=path, repeats=repeats,
                   force_model=force_model, include_xla=include_xla,
                   tol=tol)
    return table


# ---------------------------------------------------------------------------
# CLI: PYTHONPATH=src python -m repro.core.tuner --nranks 8 --ranks-per-pod 4
# ---------------------------------------------------------------------------


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(
        description="tune collective algorithm selection for one topology "
                    "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
                    "before running to measure on N host devices)")
    ap.add_argument("--nranks", type=int, default=8)
    ap.add_argument("--ranks-per-pod", type=int, default=None)
    ap.add_argument("--sizes", default=None,
                    help="comma list of per-rank byte counts")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--model", action="store_true",
                    help="force the alpha-beta model (no devices needed)")
    ap.add_argument("--dense-only", action="store_true",
                    help="skip the neighbor/partitioned paths")
    ap.add_argument("--out", default=None, help="cache file to write")
    args = ap.parse_args(argv)

    topo = Topology(nranks=args.nranks,
                    ranks_per_pod=args.ranks_per_pod or args.nranks)
    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else DEFAULT_SIZES)
    if args.dense_only:
        table = tune(topo, sizes=sizes, repeats=args.repeats,
                     force_model=args.model)
        path = save_table(table, path=args.out)
    else:
        table = autotune(topo, path=args.out, sizes=sizes,
                         repeats=args.repeats, force_model=args.model)
        path = default_cache_path() if args.out is None else Path(args.out)
    print(f"fingerprint {table.fingerprint} ({table.source}, "
          f"generation {table.generation}) -> {path}")
    for coll, per in table.entries.items():
        for b in sorted(per, key=int):
            rec = per[b]
            print(f"  {coll:15s} bucket {b:>3s} ({rec['nbytes']:>9d}B) "
                  f"-> {rec['best']:28s} "
                  f"{rec['times'][rec['best']] * 1e6:10.1f} us")
    for v in table.violations:
        print(f"  GUIDELINE VIOLATION: {v}")
    if not table.violations:
        print("  all performance guidelines hold")


if __name__ == "__main__":
    main()
