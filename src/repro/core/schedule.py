"""The unified gather-permute-scatter IR — the "persistent plan" core.

MPI Advance hoists all collective setup into a one-time initialization
(persistent collectives, MPI-4) and writes every optimization — dense
collectives, neighborhood collectives, partitioned transfers — against
one point-to-point substrate.  In JAX the same split is natural and
*mandatory*: ``jax.lax.ppermute`` requires a static permutation, so
every algorithm here compiles — once, in Python, at plan time — to a
``CommSchedule``: a list of ``CommRound``s, each

  * per-rank **gather** indices (which rows of the local working buffer
    are packed into the outgoing message; -1 pads with zeros),
  * a static partial **permutation** of (src, dst) rank pairs,
  * per-rank **scatter** indices (where received slots land; -1 drops),
  * an optional ``reduce`` flag (received slots accumulate instead of
    overwrite).

Dense collectives (allgather/allreduce/reduce_scatter/alltoall — block
tables), neighborhood alltoallv plans (row tables), and partitioned
transfers (chunk tables) all lower to the same IR and are executed by
the same two backends (see transport.py):

  * ``SimTransport``      — numpy rank-by-rank simulator; exact message/
                            byte accounting against a ``Topology``.
  * ``ShardMapTransport`` — the real SPMD executor: ``ppermute`` +
                            gather/scatter-by-``axis_index`` inside
                            ``shard_map``.

Buffers are *slot-indexed*: shape ``[num_slots, slot...]`` per rank.
Rounds move whole slots; ragged (v-variant) payloads are padded to the
max slot and true element counts are carried per round (``payload``)
for accounting.

Invariant validation is O(nranks^2) python per round; it is gated by
the ``REPRO_VALIDATE_SCHEDULES`` env var (off by default so large-mesh
plan builds stay cheap; the test suite turns it on via conftest.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Sequence

import numpy as np

from repro.core.topology import Topology


def validate_schedules_enabled() -> bool:
    """True when CommRound invariants should be checked at build time."""
    v = os.environ.get("REPRO_VALIDATE_SCHEDULES", "0").strip().lower()
    return v not in ("", "0", "false", "off", "no")


class NotApplicable(AssertionError):
    """An algorithm builder cannot serve this topology (e.g. a
    power-of-2-only variant on 12 ranks).  Subclasses AssertionError so
    historical ``except AssertionError`` call sites keep working, while
    coverage-critical loops (CI smoke, the bit-exactness sweep) can
    catch *only* this and let genuine invariant violations fail loud."""


@dataclasses.dataclass(frozen=True)
class ComputeEvent:
    """An opaque costed block of consumer compute attached to a schedule.

    MPIPCL's partitioned communication exists so chunk transfers overlap
    with the compute that produces/consumes them; a ``ComputeEvent`` is
    how a consumer registers that compute with the executor's makespan
    model (core.executor pass 3) without the IR knowing what it is.

    Events are *modeling artifacts*: execution ignores them entirely
    (bit-exactness is untouched); only ``CompiledExec.makespan`` and the
    pipelined pass read them.  An event is a pure consumer — it reads a
    snapshot of the buffer after ``after_round`` and writes nothing, so
    it never constrains round motion, only its own placement.

    after_round: index into the *original* schedule's rounds the event
                 waits on (-1 = after the last round).
    splittable:  the compute can run as equal slices over chunks of its
                 input — the precondition for the tail-chunk overlap
                 move (each slice then waits only for its chunk).
    parts:       preferred slice count when splittable (0 = let the
                 executor choose).
    """

    name: str
    seconds: float
    after_round: int = -1
    splittable: bool = False
    parts: int = 0

    def __post_init__(self):
        if self.seconds < 0:
            raise ValueError(
                f"ComputeEvent {self.name!r}: seconds must be >= 0, "
                f"got {self.seconds}")
        if self.after_round < -1:
            raise ValueError(
                f"ComputeEvent {self.name!r}: after_round must be >= -1, "
                f"got {self.after_round}")
        if self.parts < 0:
            raise ValueError(
                f"ComputeEvent {self.name!r}: parts must be >= 0, "
                f"got {self.parts}")


@dataclasses.dataclass(frozen=True)
class CommRound:
    """One communication round of the unified IR.

    perm:        static list of (src, dst) rank pairs (a partial matching
                 in rank space — each src sends once, each dst receives
                 once; (r, r) self-pairs are legal and model on-chip
                 copies that never touch the wire).
    gather_idx:  int array [nranks, k]; row r = working-buffer rows rank r
                 packs into its outgoing message (-1 entries send zeros).
    scatter_idx: int array [nranks, k]; row r = landing rows for what
                 rank r receives (-1 entries are dropped).
    reduce:      if True received slots are added into the buffer,
                 otherwise they overwrite.
    payload:     optional int array [nranks]; true (unpadded) slot counts
                 per source, for ragged accounting.  Execution always
                 moves k padded slots.
    """

    perm: tuple[tuple[int, int], ...]
    gather_idx: np.ndarray
    scatter_idx: np.ndarray
    reduce: bool = False
    payload: np.ndarray | None = None

    def __post_init__(self):
        if not validate_schedules_enabled():
            return
        assert self.gather_idx.shape == self.scatter_idx.shape
        srcs = [s for s, _ in self.perm]
        dsts = [d for _, d in self.perm]
        assert len(set(srcs)) == len(srcs), "duplicate src in perm"
        assert len(set(dsts)) == len(dsts), "duplicate dst in perm"
        # Non-destination ranks must carry an all -1 scatter row, so that
        # the numpy simulator and the ppermute executor agree bit-for-bit
        # (ppermute hands zeros to non-destinations; the -1 row routes
        # those zeros to the scratch slot instead of clobbering real
        # slots).
        dst_set = set(dsts)
        for r in range(self.scatter_idx.shape[0]):
            if r not in dst_set:
                assert (self.scatter_idx[r] < 0).all(), (
                    f"rank {r} is not a destination this round but has a "
                    f"live scatter row {self.scatter_idx[r]}")
        # A destination's live scatter slots must be distinct (scatter
        # safety: .at[].set with duplicate targets is order-dependent).
        for _, d in self.perm:
            live = self.scatter_idx[d][self.scatter_idx[d] >= 0]
            assert len(set(live.tolist())) == len(live), (
                f"rank {d} has duplicate scatter slots {live}")

    @property
    def k(self) -> int:
        return self.gather_idx.shape[1]

    # historical aliases (block-table vocabulary of the dense stack)
    width = k

    @property
    def send_blocks(self) -> np.ndarray:
        return self.gather_idx

    @property
    def recv_blocks(self) -> np.ndarray:
        return self.scatter_idx

    def edge_slots(self, src: int) -> int:
        """True slots ``src`` ships this round (payload-aware)."""
        if self.payload is not None:
            return int(self.payload[src])
        return int((self.gather_idx[src] >= 0).sum())

    # -- fusion-legality metadata (consumed by core.executor) --------------
    @property
    def src_set(self) -> frozenset[int]:
        return frozenset(s for s, _ in self.perm)

    @property
    def dst_set(self) -> frozenset[int]:
        return frozenset(d for _, d in self.perm)

    def reads(self, rank: int) -> frozenset[int]:
        """Buffer rows rank reads this round (its gather sources, when it
        is a source; empty otherwise)."""
        if rank not in self.src_set:
            return frozenset()
        row = self.gather_idx[rank]
        return frozenset(int(b) for b in row[row >= 0])

    def writes(self, rank: int) -> frozenset[int]:
        """Buffer rows rank overwrites/accumulates this round (its live
        scatter targets, when it is a destination; empty otherwise)."""
        if rank not in self.dst_set:
            return frozenset()
        row = self.scatter_idx[rank]
        return frozenset(int(b) for b in row[row >= 0])


def can_fuse(a: CommRound, b: CommRound) -> bool:
    """True when consecutive rounds ``a`` then ``b`` may execute as one
    ``ppermute`` round with identical semantics.

    Legality (the executor's whole-round peephole; the edge-granular
    compaction in core.executor generalizes it):
      * neither round reduces — a fused round has one accumulate flag,
        and merging around an accumulation reorders float adds;
      * the merged perm must stay a partial matching: no rank may be a
        src in both rounds, or a dst in both rounds;
      * no read-after-write hazard: in the fused round every gather
        reads pre-round state, so rows ``a`` scatters into on some rank
        must not alias rows ``b`` gathers from that rank.
    (Write-after-read needs no check: fused execution gathers before it
    scatters, exactly like the unfused order ``a``-reads-then-writes,
    ``b``-reads-then-writes for disjoint src/dst sets.)
    """
    if a.reduce or b.reduce:
        return False
    if a.src_set & b.src_set or a.dst_set & b.dst_set:
        return False
    for r in a.dst_set & b.src_set:
        if a.writes(r) & b.reads(r):
            return False
    return True


def can_split(rnd: CommRound, parts: int) -> bool:
    """True when ``rnd`` may be partitioned into ``parts`` sequential
    chunk rounds with identical semantics (MPIPCL partitioning on the
    unified IR).

    Legality:
      * ``parts >= 2`` and the round is not a reduce (chunked
        accumulation would reorder float adds relative to concurrent
        delivery);
      * dense tables only (``payload is None``) with ``k % parts == 0``
        — equal chunks are what keeps the chunked alpha-beta time
        provably bounded at every slot size (ceil splits introduce
        size-dependent remainder terms);
      * no scatter->gather aliasing anywhere in the round, INCLUDING a
        single edge whose own writes alias its own reads: in the
        original round every gather reads pre-round state, but chunk i
        scatters before chunk i+1 gathers, so any aliasing would
        reorder a write before a read.
    (Write-after-write needs no check: live scatter targets are
    distinct per destination — a schedule invariant — so chunks write
    disjoint rows.)
    """
    if parts < 2 or rnd.reduce or rnd.payload is not None:
        return False
    if rnd.k % parts:
        return False
    for s1, d1 in rnd.perm:
        for s2, _ in rnd.perm:
            if d1 == s2 and rnd.writes(d1) & rnd.reads(s2):
                return False
    return True


def split_round(rnd: CommRound, parts: int) -> tuple[CommRound, ...]:
    """Partition ``rnd`` into ``parts`` chunk rounds; chunk ``i``
    carries the position-contiguous slice ``[i*k/parts, (i+1)*k/parts)``
    of every edge's gather/scatter vectors.  Executing the chunks in
    order is bit-identical to the original round (``can_split`` is the
    precondition); per port the chunk sequence costs
    ``parts * alpha + k * slot_bytes * beta`` — the alpha-beta price of
    MPIPCL's independently-committed partitions.
    """
    assert can_split(rnd, parts), (rnd.k, parts, rnd.reduce)
    kc = rnd.k // parts
    out = []
    for i in range(parts):
        sl = slice(i * kc, (i + 1) * kc)
        out.append(CommRound(perm=rnd.perm,
                             gather_idx=np.ascontiguousarray(
                                 rnd.gather_idx[:, sl]),
                             scatter_idx=np.ascontiguousarray(
                                 rnd.scatter_idx[:, sl])))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """A compiled communication pattern: rounds + buffer geometry.

    num_slots:   leading axis of the working buffer (excl. the scratch
                 slot transports append internally).
    slot_bytes:  optional per-slot true byte counts [num_slots] for
                 ragged payloads (accounting only; execution is padded).
    local_pre:   optional [nranks, num_slots] slot permutation applied
                 before round 0 (new_buf[s] = buf[local_pre[r, s]]); free
                 — a local shuffle, no messages (Bruck rotation phase).
    local_post:  same, applied after the last round.
    out_slots:   number of slots that constitute the result after
                 local_post (schedules with separate send/recv regions
                 set this < num_slots, like MPI send/recv buffer pairs).
    out_offsets: optional per-rank [nranks] start row of the result
                 region (neighborhood plans land recv segments mid-
                 buffer; dense collectives leave this None = row 0).
    compute_events: optional ``ComputeEvent`` list — opaque costed
                 consumer-compute barriers the executor's makespan
                 model prices and overlaps; execution ignores them.
    """

    nranks: int
    num_slots: int
    rounds: tuple[CommRound, ...]
    name: str = "schedule"
    slot_bytes: np.ndarray | None = None
    local_pre: np.ndarray | None = None
    local_post: np.ndarray | None = None
    out_slots: int | None = None
    out_offsets: np.ndarray | None = None
    compute_events: tuple[ComputeEvent, ...] = ()

    def __post_init__(self):
        if not isinstance(self.compute_events, tuple):
            object.__setattr__(self, "compute_events",
                               tuple(self.compute_events))
        if not validate_schedules_enabled():
            return
        for ev in self.compute_events:
            assert isinstance(ev, ComputeEvent), ev
            assert ev.after_round < len(self.rounds), (
                f"event {ev.name!r} anchored after round {ev.after_round} "
                f"but the schedule has {len(self.rounds)} rounds")

    @property
    def result_slots(self) -> int:
        return self.num_slots if self.out_slots is None else self.out_slots

    def out_offset(self, rank: int) -> int:
        return 0 if self.out_offsets is None else int(self.out_offsets[rank])

    # historical aliases (block vocabulary of the dense stack)
    @property
    def num_blocks(self) -> int:
        return self.num_slots

    @property
    def result_blocks(self) -> int:
        return self.result_slots

    @property
    def block_bytes(self) -> np.ndarray | None:
        return self.slot_bytes

    # -- accounting (validates the paper's message/byte-count claims) ------
    def _edges(self, topo: Topology | None, local: bool | None):
        """Live wire edges (src, dst, true_slots); self-pairs and empty
        payloads never hit the wire and are excluded."""
        for rnd in self.rounds:
            for s, d in rnd.perm:
                if s == d:
                    continue
                slots = rnd.edge_slots(s)
                if slots == 0:
                    continue
                if topo is not None and local is not None:
                    if topo.is_local(s, d) != local:
                        continue
                yield rnd, s, d, slots

    def message_count(self, topo: Topology | None = None,
                      local: bool | None = None) -> int:
        """Total point-to-point messages; filter by link class if asked."""
        return sum(1 for _ in self._edges(topo, local))

    def byte_count(self, elem_bytes: int, topo: Topology | None = None,
                   local: bool | None = None) -> int:
        """Total bytes moved (true counts if slot_bytes/payload set).

        ``slot_bytes`` is authoritative whenever it is set: the per-slot
        true byte widths are summed over the live gather entries of each
        edge (truncated to the round's ``payload`` count when both are
        present — the first ``payload[src]`` live entries are the real
        slots, the rest is padding).  Only slots with no recorded width
        fall back to ``slots * elem_bytes``.
        """
        total = 0
        for rnd, s, d, slots in self._edges(topo, local):
            if self.slot_bytes is not None:
                live = rnd.gather_idx[s][rnd.gather_idx[s] >= 0]
                if rnd.payload is not None:
                    live = live[: slots]
                total += int(sum(int(self.slot_bytes[b]) for b in live))
            else:
                total += slots * elem_bytes
        return total

    def traffic(self, topo: Topology, elem_bytes: int = 1) -> dict:
        """Per-link-class bytes and message counts (the paper's
        aggregation claims: locality-aware plans cut DCN bytes/msgs)."""
        out = {"ici": 0, "dcn": 0, "msgs_ici": 0, "msgs_dcn": 0}
        for rnd, s, d, slots in self._edges(topo, None):
            key = "ici" if topo.is_local(s, d) else "dcn"
            out[key] += slots * elem_bytes
            out["msgs_" + key] += 1
        return out

    def modeled_time(self, topo: Topology, slot_nbytes: int) -> float:
        """alpha-beta model: rounds serialize, edges within a round
        overlap.  Rounds without ``payload`` move k padded slots per
        edge (dense block tables); payload-bearing rounds (ragged
        neighbor exchanges) use true per-source counts."""
        total = 0.0
        for rnd in self.rounds:
            if rnd.payload is None:
                total += topo.round_time(rnd.perm, slot_nbytes * rnd.k)
            else:
                per_edge = [rnd.edge_slots(s) * slot_nbytes
                            for s, _ in rnd.perm]
                total += topo.round_time(rnd.perm, per_edge)
        return total

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    # -- identity -----------------------------------------------------------
    def fingerprint(self) -> str:
        """Content hash of everything execution-relevant (tables, perms,
        flags, geometry) — the executor-cache key, the CommSchedule
        analogue of ``Topology.fingerprint``.  Two independently built
        schedules with identical tables share one fingerprint (and one
        compiled executor); the display ``name`` is excluded.
        """
        memo = getattr(self, "_fingerprint", None)
        if memo is not None:
            return memo
        h = hashlib.sha1()

        def feed(tag: str, arr) -> None:
            h.update(tag.encode())
            if arr is None:
                h.update(b"\x00")
                return
            a = np.ascontiguousarray(arr)
            h.update(str(a.dtype).encode() + str(a.shape).encode())
            h.update(a.tobytes())

        h.update(f"n{self.nranks}:s{self.num_slots}:o{self.out_slots}"
                 .encode())
        feed("slot_bytes", self.slot_bytes)
        feed("pre", self.local_pre)
        feed("post", self.local_post)
        feed("out_offsets", self.out_offsets)
        for rnd in self.rounds:
            h.update(b"R" + (b"+" if rnd.reduce else b"-"))
            feed("perm", np.asarray(rnd.perm, np.int64).reshape(-1, 2)
                 if rnd.perm else np.zeros((0, 2), np.int64))
            feed("g", rnd.gather_idx)
            feed("s", rnd.scatter_idx)
            feed("p", rnd.payload)
        for ev in self.compute_events:
            # events change what the makespan pass produces (groups,
            # tail split), so they are identity-bearing for the
            # executor cache even though execution ignores them
            h.update(f"E{ev.name}|{ev.seconds!r}|{ev.after_round}|"
                     f"{int(ev.splittable)}|{ev.parts}".encode())
        fp = h.hexdigest()
        # memo on the frozen instance (plain attribute, not a field:
        # equality/repr are unaffected and the hash is deterministic)
        object.__setattr__(self, "_fingerprint", fp)
        return fp


# Back-compat aliases: the pre-unification dense stack exported these.
Round = CommRound
Schedule = CommSchedule


def add_canary_slot(schedule: CommSchedule) -> CommSchedule:
    """Derive a schedule with one extra *canary* slot row that no round
    reads, writes, or permutes — it rides through the transports'
    staging buffers untouched.

    Self-verifying execution (``core.resilient``) fills the canary row
    with a seeded pattern before the run and compares it bitwise after:
    buffer-wide data-plane corruption (a stray DMA, a flipped page, an
    injected chaos fault) that lands on the canary is detected in one
    O(slot) pass, without a second execution.  The transform is pure
    geometry: round tables are unchanged (they index slots
    ``< num_slots``, still valid), ``local_pre``/``local_post`` are
    extended with the identity on the canary row, and the result region
    (``out_slots``/``out_offsets``) is pinned to the original
    schedule's, so stripping the canary row recovers the original
    output exactly.  The canary row index is the ORIGINAL
    ``num_slots``; the transports' scratch row moves up by one.
    """
    def extend(perm):
        if perm is None:
            return None
        col = np.full((schedule.nranks, 1), schedule.num_slots,
                      dtype=perm.dtype)
        return np.concatenate([perm, col], axis=1)

    return CommSchedule(
        nranks=schedule.nranks,
        num_slots=schedule.num_slots + 1,
        rounds=schedule.rounds,
        name=schedule.name + "+canary",
        slot_bytes=None if schedule.slot_bytes is None
        else np.concatenate([schedule.slot_bytes, [0]]),
        local_pre=extend(schedule.local_pre),
        local_post=extend(schedule.local_post),
        out_slots=schedule.result_slots,
        out_offsets=schedule.out_offsets,
        compute_events=schedule.compute_events)


def make_round(nranks: int,
               edges: Sequence[tuple[int, int]],
               send_blocks: dict[int, Sequence[int]],
               recv_blocks: dict[int, Sequence[int]],
               reduce: bool = False) -> CommRound:
    """Build a CommRound from per-rank slot lists (ragged -> padded -1)."""
    k = max((len(v) for v in send_blocks.values()), default=0)
    k = max(k, max((len(v) for v in recv_blocks.values()), default=0))
    k = max(k, 1)
    sb = np.full((nranks, k), -1, dtype=np.int32)
    rb = np.full((nranks, k), -1, dtype=np.int32)
    for r, blocks in send_blocks.items():
        sb[r, : len(blocks)] = blocks
    for r, blocks in recv_blocks.items():
        rb[r, : len(blocks)] = blocks
    return CommRound(perm=tuple((int(s), int(d)) for s, d in edges),
                     gather_idx=sb, scatter_idx=rb, reduce=reduce)
