"""Static communication schedules — the "persistent plan" core of the MPIX layer.

MPI Advance hoists all collective setup into a one-time initialization
(persistent collectives, MPI-4).  In JAX the same split is natural and
*mandatory*: ``jax.lax.ppermute`` requires a static permutation, so every
collective algorithm here compiles — once, in Python, at plan time — to a
``Schedule``: a list of ``Round``s, each a static set of (src, dst) pairs
plus per-rank block index tables describing which blocks of the local
buffer are sent and where received blocks land.

The same ``Schedule`` is executed by two backends (see transport.py):

  * ``SimTransport``    — numpy rank-by-rank simulator; exact message/byte
                          accounting against a ``Topology`` (unit tests,
                          benchmarks, the alpha-beta cost model).
  * ``ShardMapTransport`` — the real SPMD executor: ``ppermute`` + gather/
                          scatter-by-``axis_index`` inside ``shard_map``.

Buffers are *block-indexed*: shape ``[num_blocks, block...]``.  Collectives
move whole blocks; ragged (v-variant) payloads are padded to the max block
and true byte counts are carried in the schedule for accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class Round:
    """One communication round.

    perm:        static list of (src, dst) rank pairs (a partial matching in
                 rank space — each src sends once, each dst receives once).
    send_blocks: int array [nranks, k]; row r = block indices rank r sends
                 this round (-1 entries send a zero/dummy block).
    recv_blocks: int array [nranks, k]; row r = destination block slots for
                 what rank r receives (-1 entries are dropped).
    reduce:      if True received blocks are added into the buffer,
                 otherwise they overwrite.
    """

    perm: tuple[tuple[int, int], ...]
    send_blocks: np.ndarray
    recv_blocks: np.ndarray
    reduce: bool = False

    def __post_init__(self):
        assert self.send_blocks.shape == self.recv_blocks.shape
        srcs = [s for s, _ in self.perm]
        dsts = [d for _, d in self.perm]
        assert len(set(srcs)) == len(srcs), "duplicate src in perm"
        assert len(set(dsts)) == len(dsts), "duplicate dst in perm"
        # Non-destination ranks must carry an all -1 recv row, so that the
        # numpy simulator and the ppermute executor agree bit-for-bit
        # (ppermute hands zeros to non-destinations; the -1 row routes those
        # zeros to the scratch slot instead of clobbering real blocks).
        dst_set = set(dsts)
        for r in range(self.recv_blocks.shape[0]):
            if r not in dst_set:
                assert (self.recv_blocks[r] < 0).all(), (
                    f"rank {r} is not a destination this round but has a "
                    f"live recv row {self.recv_blocks[r]}")
        # A destination's live recv slots must be distinct (scatter safety).
        for _, d in self.perm:
            live = self.recv_blocks[d][self.recv_blocks[d] >= 0]
            assert len(set(live.tolist())) == len(live), (
                f"rank {d} has duplicate recv slots {live}")

    @property
    def k(self) -> int:
        return self.send_blocks.shape[1]


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A compiled collective: rounds + buffer geometry.

    num_blocks:  leading axis of the working buffer.
    block_bytes: optional per-block true byte counts [num_blocks] for
                 ragged payloads (accounting only; execution is padded).
    local_pre:   optional [nranks, num_blocks] slot permutation applied
                 before round 0 (new_buf[s] = buf[local_pre[r, s]]); free —
                 a local shuffle, no messages (Bruck rotation phase).
    local_post:  same, applied after the last round.
    out_blocks:  number of leading blocks that constitute the result after
                 local_post (schedules with separate send/recv regions set
                 this < num_blocks, like MPI send/recv buffer pairs).
    """

    nranks: int
    num_blocks: int
    rounds: tuple[Round, ...]
    name: str = "schedule"
    block_bytes: np.ndarray | None = None
    local_pre: np.ndarray | None = None
    local_post: np.ndarray | None = None
    out_blocks: int | None = None

    @property
    def result_blocks(self) -> int:
        return self.num_blocks if self.out_blocks is None else self.out_blocks

    # -- accounting (validates the paper's message/byte-count claims) ------
    def message_count(self, topo: Topology | None = None,
                      local: bool | None = None) -> int:
        """Total point-to-point messages; filter by link class if asked."""
        n = 0
        for rnd in self.rounds:
            for s, d in rnd.perm:
                if topo is not None and local is not None:
                    if topo.is_local(s, d) != local:
                        continue
                n += 1
        return n

    def byte_count(self, elem_bytes: int, topo: Topology | None = None,
                   local: bool | None = None) -> int:
        """Total bytes moved (true counts if block_bytes set)."""
        total = 0
        for rnd in self.rounds:
            for i, (s, d) in enumerate(rnd.perm):
                if topo is not None and local is not None:
                    if topo.is_local(s, d) != local:
                        continue
                blocks = rnd.send_blocks[s]
                for b in blocks:
                    if b < 0:
                        continue
                    if self.block_bytes is not None:
                        total += int(self.block_bytes[b])
                    else:
                        total += elem_bytes
        return total

    def modeled_time(self, topo: Topology, block_nbytes: int) -> float:
        """alpha-beta model: rounds serialize, edges within a round overlap."""
        return sum(topo.round_time(r.perm, block_nbytes * r.k)
                   for r in self.rounds)

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)


def make_round(nranks: int,
               edges: Sequence[tuple[int, int]],
               send_blocks: dict[int, Sequence[int]],
               recv_blocks: dict[int, Sequence[int]],
               reduce: bool = False) -> Round:
    """Build a Round from per-rank block lists (ragged -> padded with -1)."""
    k = max((len(v) for v in send_blocks.values()), default=0)
    k = max(k, max((len(v) for v in recv_blocks.values()), default=0))
    k = max(k, 1)
    sb = np.full((nranks, k), -1, dtype=np.int32)
    rb = np.full((nranks, k), -1, dtype=np.int32)
    for r, blocks in send_blocks.items():
        sb[r, : len(blocks)] = blocks
    for r, blocks in recv_blocks.items():
        rb[r, : len(blocks)] = blocks
    return Round(perm=tuple((int(s), int(d)) for s, d in edges),
                 send_blocks=sb, recv_blocks=rb, reduce=reduce)
