"""Default algorithm selection (paper §2.1).

MPI Advance currently ships a fixed default per collective and lists a
"more sophisticated selection process" as future work.  We implement all
three rungs of that ladder:

  * ``select(..., policy="fixed")``   — the paper-faithful static default.
  * ``select(..., policy="model")``   — alpha-beta-model-driven argmin over
    every registered schedule (the future-work selector), using the exact
    per-round link accounting of ``Schedule.modeled_time``.
  * ``select(..., policy="tuned")``   — empirical: per-(collective,
    topology, size-bucket) winners measured on the live substrate and
    persisted by ``repro.core.tuner``, keyed by a substrate fingerprint.
    Falls back to the model argmin when no table matches.

The selection is made at trace time (static shapes), so it costs nothing
at run time — the chosen schedule is baked into the compiled program,
exactly like a persistent MPI Advance collective.
"""
from __future__ import annotations

import functools

from repro.core.schedule import NotApplicable
from repro.core.topology import Topology

# Paper-faithful fixed defaults: log-step algorithms for small payloads
# would need runtime dispatch; statically we default to the
# bandwidth-optimal variant per collective, hierarchical when multi-pod.
_FIXED = {
    "allgather": ("ring", "hierarchical"),
    "allreduce": ("ring_rs_ag", "hierarchical"),
    "reduce_scatter": ("ring", "hierarchical"),
    "alltoall": ("pairwise", "hierarchical"),
}

# Below this many bytes per rank, latency dominates: prefer log-step.
_SMALL = 64 * 1024
_LOG_STEP = {
    "allgather": "bruck",
    "allreduce": "recursive_halving_doubling",
    "reduce_scatter": "recursive_halving",
    "alltoall": "bruck",
}


POLICIES = ("fixed", "model", "tuned")

# The two build modes of a neighborhood exchange (plan.build_plan).
NEIGHBOR = "neighbor_alltoallv"
NEIGHBOR_MODES = ("standard", "locality_aware")


def select(collective: str, topo: Topology, nbytes: int,
           policy: str = "model", tuned_table=None) -> str:
    if policy not in POLICIES:
        raise ValueError(f"unknown selection policy {policy!r}; "
                         f"expected one of {POLICIES}")
    if policy == "fixed":
        flat, hier = _FIXED[collective]
        if len(topo.levels) >= 3 and topo.npods > 1:
            # 3+ levels (DCN over a multi-axis torus): the 2-level
            # hierarchical builders see only the pod/local split; the
            # staged builders exploit every axis.  Single-pod tori stay
            # on the flat default — with no slow level to avoid, staged
            # store-and-forward only adds bytes.
            return "staged"
        return hier if topo.npods > 1 else flat
    if policy == "tuned":
        from repro.core import tuner  # local: avoid import cycle
        name = tuner.tuned_select(collective, topo, int(nbytes),
                                  table=tuned_table)
        if name is not None:
            return name
        # no persisted table for this substrate: model argmin fallback
    return _model_select(collective, topo, int(nbytes))


def resolve_neighbor_mode(graph, topo: Topology, *,
                          policy: str | None = None, tuned_table=None,
                          elem_bytes: int = 4) -> str | None:
    """Cheap half of the neighbor mode choice: resolve from policy and
    persisted tables alone, WITHOUT compiling any plan.  Returns None
    when the decision needs the alpha-beta model comparison of both
    compiled plans (the caller — ``build_plan`` — already has to build
    the winner, so it builds both and compares, instead of this layer
    compiling and discarding them)."""
    if policy is None:
        from repro.core import api  # local: avoid import cycle
        policy = api.get_default_policy()
    if policy not in POLICIES:
        raise ValueError(f"unknown selection policy {policy!r}; "
                         f"expected one of {POLICIES}")
    if topo.npods == 1:
        return "standard"            # both modes compile identically
    if policy == "fixed":
        return "locality_aware"
    if policy == "tuned":
        from repro.core import tuner
        nbytes = graph.total_values() * elem_bytes
        name = tuner.tuned_select(NEIGHBOR, topo, int(nbytes),
                                  table=tuned_table)
        if name in NEIGHBOR_MODES:
            return name
    return None


def select_neighbor(graph, topo: Topology, *, policy: str | None = None,
                    tuned_table=None, elem_bytes: int = 4) -> str:
    """Standard-vs-locality-aware choice for a neighborhood exchange.

    Same policy ladder as ``select``: "fixed" is the paper default
    (aggregate whenever the topology is multi-pod), "tuned" reads the
    winner ``tuner.autotune`` persisted for this substrate and exchange
    volume, "model" compares the alpha-beta times of both compiled
    plans.  ``policy=None`` uses the process-wide default policy.
    """
    mode = resolve_neighbor_mode(graph, topo, policy=policy,
                                 tuned_table=tuned_table,
                                 elem_bytes=elem_bytes)
    if mode is not None:
        return mode
    from repro.core.plan import model_argmin_plan
    plan = model_argmin_plan(graph, topo, elem_bytes=elem_bytes)
    return ("locality_aware" if plan.name.endswith("locality_aware")
            else "standard")


def _executed_time(sched, topo: Topology, nbytes: int) -> float:
    """alpha-beta time of what would actually run: the *compiled*
    schedule (post executor fusion, cost-model-armed with ``topo`` —
    the same executor the mpix_* transports look up), matching
    ``tuner._modeled`` so the model policy and the tuned tables price
    the same rounds."""
    from repro.core import executor  # local: avoid import cycle

    block_nbytes = max(1, nbytes // max(1, sched.num_blocks))
    return executor.get_executor(
        sched, topo=topo).compiled_schedule.modeled_time(topo, block_nbytes)


@functools.lru_cache(maxsize=None)
def _model_select(collective: str, topo: Topology, nbytes: int) -> str:
    from repro.core.algorithms import REGISTRY  # local: avoid import cycle

    best_name, best_t = None, float("inf")
    for name, builder in REGISTRY[collective].items():
        try:
            sched = builder(topo)
        except NotApplicable:   # e.g. power-of-2-only algorithms
            continue
        t = _executed_time(sched, topo, nbytes)
        if t < best_t:
            best_name, best_t = name, t
    assert best_name is not None
    return best_name


def modeled_times(collective: str, topo: Topology, nbytes: int) -> dict:
    """All candidates' modeled times (for benchmarks / reports)."""
    from repro.core.algorithms import REGISTRY

    out = {}
    for name, builder in REGISTRY[collective].items():
        try:
            sched = builder(topo)
        except NotApplicable:
            continue
        out[name] = _executed_time(sched, topo, nbytes)
    return out
