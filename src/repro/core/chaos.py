"""Deterministic data-plane fault injection for any ``Transport``.

PR 8 made the stack resilient to *performance* faults (link drift,
stragglers, rank loss).  Nothing defended the data plane: a corrupted
slot, a failed kernel launch, or a hung round either silently poisons a
collective's output or wedges the step loop.  This module is the attack
half of closing that gap (``core.resilient`` is the defense): a seeded,
reproducible chaos injector that wraps any transport — Sim, ShardMap,
Pallas — and fires faults at round granularity.

Fault taxonomy (one campaign each, or ``"mixed"``):

  * ``"corrupt"`` — a slot row of the output buffer is corrupted
    (``mode="nan"`` sprays NaN; ``mode="bitflip"`` flips one high
    exponent bit of every element — silent without verification);
  * ``"fail"``    — the round raises ``TransportError`` (a failed
    launch / dropped ppermute — detected, retryable);
  * ``"hang"``    — the run is delayed past a deadline
    (``delay_s`` injected before execution; the result itself is
    correct but *late*).

Determinism: fault placement (round, rank, slot) is drawn from an rng
keyed by ``(seed, campaign, schedule.fingerprint())``, so CI replays
the exact same failure from the recorded seeds.  ``times`` bounds how
many consecutive executions of one schedule fault (transient faults
clear and a retry succeeds); ``times=None`` is a persistent fault the
ladder must degrade around.

``FaultPlan`` also implements the duck-typed *injector protocol* that
``runtime.fault.LinkFault`` pioneered — ``apply(level, link) ->
LinkModel`` plus ``clear()`` — so ``linkprobe.model_timer(fault=...)``
accepts either: a hang campaign inflates the probed alpha (the timer
observes the stall), other campaigns leave links untouched.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.schedule import CommSchedule
from repro.core.topology import LinkModel
from repro.core.transport import TransportError

CAMPAIGNS = ("corrupt", "fail", "hang", "mixed")
CORRUPT_MODES = ("nan", "bitflip")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault, fully placed (replayable record)."""

    kind: str                     # "corrupt" | "fail" | "hang"
    round_idx: int                # round the fault is attributed to
    rank: int = 0                 # corrupt: whose buffer row
    slot: int = 0                 # corrupt: which slot row
    mode: str = "nan"             # corrupt: "nan" | "bitflip"
    delay_s: float = 0.0          # hang: injected stall


def _corrupt(buf, ev: FaultEvent):
    """Deterministically corrupt slot row (rank, slot) of a global
    [nranks, num_slots, *slot] buffer.  jnp throughout so the same code
    corrupts concrete numpy/jax buffers and traced values."""
    x = jnp.asarray(buf)
    row = x[ev.rank, ev.slot]
    if ev.mode == "nan" and jnp.issubdtype(x.dtype, jnp.floating):
        bad = jnp.full_like(row, jnp.nan)
    else:
        # flip a high exponent bit of every element: a large, visible,
        # bit-deterministic perturbation for any fixed-width dtype
        nbits = x.dtype.itemsize * 8
        uint = {8: jnp.uint8, 16: jnp.uint16,
                32: jnp.uint32, 64: jnp.uint64}[nbits]
        w = jax.lax.bitcast_convert_type(row, uint)
        w = w ^ np.asarray(1 << (nbits - 2), w.dtype)
        bad = jax.lax.bitcast_convert_type(w, x.dtype)
    return x.at[ev.rank, ev.slot].set(bad)


class FaultPlan:
    """Seeded, deterministic fault plan: wraps transports via ``wrap``.

    seed/campaign: the replay key.  ``times``: how many consecutive
    executions of each schedule fault before the plan goes quiet for it
    (``None`` = every execution, a persistent fault).  ``max_faults``:
    events injected per faulting execution.  ``match``: optionally
    restrict the plan to schedules whose fingerprint or name equals /
    prefixes this string (lets a test fault only the primary algorithm
    so the refit rung is reachable).
    """

    def __init__(self, seed: int, campaign: str, *, times: int | None = 1,
                 max_faults: int = 1, mode: str | None = None,
                 delay_s: float = 0.05, alpha_scale: float = 200.0,
                 match: str | None = None):
        if campaign not in CAMPAIGNS:
            raise ValueError(f"unknown chaos campaign {campaign!r}; "
                             f"expected one of {CAMPAIGNS}")
        if mode is not None and mode not in CORRUPT_MODES:
            raise ValueError(f"unknown corruption mode {mode!r}; "
                             f"expected one of {CORRUPT_MODES}")
        if times is not None and times < 0:
            raise ValueError(f"times must be >= 0 or None, got {times}")
        if max_faults < 1:
            raise ValueError(f"max_faults must be >= 1, got {max_faults}")
        if not (np.isfinite(delay_s) and delay_s >= 0):
            raise ValueError(f"delay_s must be finite >= 0, got {delay_s}")
        self.seed = int(seed)
        self.campaign = campaign
        self.times = times
        self.max_faults = int(max_faults)
        self.mode = mode
        self.delay_s = float(delay_s)
        self.alpha_scale = float(alpha_scale)
        self.match = match
        self._fired: dict[str, int] = {}

    # -- deterministic placement ------------------------------------------
    def _rng(self, schedule: CommSchedule) -> np.random.Generator:
        key = f"{self.seed}:{self.campaign}:{schedule.fingerprint()}"
        digest = hashlib.sha1(key.encode()).digest()
        return np.random.default_rng(int.from_bytes(digest[:8], "little"))

    def events_for(self, schedule: CommSchedule) -> tuple[FaultEvent, ...]:
        """The exact faults this plan injects into one execution of
        ``schedule`` — a pure function of (seed, campaign, schedule),
        independent of firing state, so reports and tests can replay."""
        rng = self._rng(schedule)
        nrounds = max(1, schedule.num_rounds)
        events = []
        for _ in range(self.max_faults):
            kind = (self.campaign if self.campaign != "mixed"
                    else ("corrupt", "fail", "hang")[rng.integers(3)])
            mode = self.mode or ("nan", "bitflip")[rng.integers(2)]
            events.append(FaultEvent(
                kind=kind,
                round_idx=int(rng.integers(nrounds)),
                rank=int(rng.integers(schedule.nranks)),
                # any slot row, staging/canary rows included — the
                # memory-spray model verification must stand up to
                slot=int(rng.integers(max(1, schedule.num_slots))),
                mode=mode,
                delay_s=self.delay_s if kind == "hang" else 0.0))
        return tuple(events)

    def _matches(self, schedule: CommSchedule) -> bool:
        if self.match is None:
            return True
        return (schedule.fingerprint().startswith(self.match)
                or schedule.name.startswith(self.match))

    def take(self, schedule: CommSchedule) -> tuple[FaultEvent, ...]:
        """Events to inject for the NEXT execution of ``schedule``
        (advances the transient-fault counter; empty once ``times``
        executions have faulted)."""
        if not self._matches(schedule):
            return ()
        fp = schedule.fingerprint()
        fired = self._fired.get(fp, 0)
        if self.times is not None and fired >= self.times:
            return ()
        self._fired[fp] = fired + 1
        return self.events_for(schedule)

    def reset(self) -> None:
        """Rewind the transient-fault counters (replay a campaign)."""
        self._fired.clear()

    # -- duck-typed injector protocol (shared with runtime.fault.LinkFault;
    #    consumed by linkprobe.model_timer) ---------------------------------
    def apply(self, level: int, link: LinkModel) -> LinkModel:
        """A hang campaign is visible to a link probe as inflated
        latency; data-plane campaigns don't move the link model."""
        if self.campaign == "hang":
            return LinkModel(alpha=link.alpha * self.alpha_scale,
                             beta=link.beta)
        return link

    def clear(self) -> None:
        self.reset()


class ChaosTransport:
    """A transport wrapped with a ``FaultPlan``.

    Delegates everything to the inner transport; ``run`` /
    ``run_global`` / ``run_reference`` consult the plan first and
    inject: hang -> host stall before execution, fail ->
    ``TransportError`` (round-attributed), corrupt -> deterministic
    slot corruption of the produced buffer.  ``run_chunked`` funnels
    through the faulted ``run`` via the inner implementation's own
    chunk loop only when no fault fires (chunk loops re-enter ``run``).
    """

    def __init__(self, inner, plan: FaultPlan):
        self.inner = inner
        self.plan = plan

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def _execute(self, schedule, buf, call):
        events = self.plan.take(schedule)
        for ev in events:
            if ev.kind == "hang":
                time.sleep(ev.delay_s)
            elif ev.kind == "fail":
                raise TransportError(
                    f"chaos[seed={self.plan.seed}]: injected failure in "
                    f"round {ev.round_idx} of {schedule.name}",
                    transport=type(self.inner).__name__,
                    round_idx=ev.round_idx)
        out = call(buf)
        for ev in events:
            if ev.kind == "corrupt":
                out = _corrupt(out, ev)
        return out

    def run(self, schedule, buf):
        return self._execute(schedule, buf,
                             lambda b: self.inner.run(schedule, b))

    def run_global(self, schedule, gbuf, **kw):
        return self._execute(
            schedule, gbuf,
            lambda b: self.inner.run_global(schedule, b, **kw))

    def run_reference(self, schedule, buf):
        return self._execute(
            schedule, buf,
            lambda b: self.inner.run_reference(schedule, b))


def wrap(transport, plan: FaultPlan | None):
    """Wrap ``transport`` with ``plan`` (None = passthrough)."""
    return transport if plan is None else ChaosTransport(transport, plan)
