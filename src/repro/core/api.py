"""MPIX-style user API (paper Listings 2/4): drop-in collectives with a
publicly selectable ``algorithm=`` argument.

    y = mpix_allreduce(x, ("pod", "data"))                   # default select
    y = mpix_allreduce(x, ("pod", "data"), algorithm="hierarchical")
    y = mpix_allgather(x, "model", algorithm="bruck")
    y = mpix_allreduce(x, "data", policy="tuned")            # empirical table

All functions must be called *inside* ``shard_map`` whose manual axes
include ``axis_names``; ``algorithm="xla"`` routes to the substrate
(XLA's native lowering — the analogue of calling the system MPI), every
other name routes to a persistent ``Schedule`` executed over ``ppermute``.

Schedules are built once per (collective, algorithm, topology) and cached
— MPI Advance's "persistent" initialization-time setup — and execute
through the process-level compiled-executor cache (``core.executor``):
tables baked on device once, rounds fused, one jit trace per (schedule,
shape, dtype).  ``executor_cache_stats()`` / ``clear_executor_cache()``
expose that layer.
"""
from __future__ import annotations

import time
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.topology import (DCN_LINK, ICI_LINK, TopoLevel, Topology)
from repro.core.transport import (PallasTransport, ShardMapTransport,
                                  TransportError, _flat_rank)
from repro.core.schedule import NotApplicable
from repro.core.resilient import (Attempt, DegradationReport,
                                  UnrecoverableError, resolve_resilience)
from repro.core import chaos as _chaos
from repro.core import selector
from repro.core.algorithms import REGISTRY

from repro import compat


def _axes_tuple(axis_names) -> tuple[str, ...]:
    return (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)


def topology_from_axes(axis_names: Sequence[str]) -> Topology:
    """Topology for the flat rank space of ``axis_names`` (row-major).

    Convention: if the first axis is named ``"pod"`` it is the DCN axis and
    everything after it is intra-pod; otherwise the whole space is one pod.
    A single intra-pod axis canonicalizes to the historical 1/2-level
    form (stable fingerprints for every existing call site); two or more
    intra-pod axes are kept as distinct ICI levels, giving the tuner
    per-axis-geometry (torus-aware) fingerprints.
    Must be called inside shard_map (uses static axis sizes).
    """
    names = _axes_tuple(axis_names)
    sizes = [compat.axis_size(n) for n in names]
    nranks = 1
    for s in sizes:
        nranks *= s
    has_pod = names[0] == "pod" and len(names) > 1
    intra = list(zip(names, sizes))[1:] if has_pod else list(
        zip(names, sizes))
    if len(intra) <= 1:
        return Topology(nranks=nranks,
                        ranks_per_pod=nranks // sizes[0] if has_pod
                        else nranks)
    levels = []
    if has_pod:
        levels.append(TopoLevel("dcn", sizes[0], DCN_LINK, dcn=True))
    levels += [TopoLevel(nm, sz, ICI_LINK) for nm, sz in intra]
    return Topology.from_levels(levels)


# plan cache: (collective, algorithm, topo) -> CommSchedule.  A plain
# dict rather than lru_cache so drift healing / elastic swaps can evict
# by topology (``invalidate_topology``) instead of all-or-nothing.
_SCHEDULES: dict = {}


def _schedule(collective: str, algorithm: str, topo: Topology):
    key = (collective, algorithm, topo)
    sched = _SCHEDULES.get(key)
    if sched is None:
        sched = REGISTRY[collective][algorithm](topo)
        # warm the persistent-executor cache at plan time (MPI-4
        # persistent init): by the first traced call the tables are
        # already baked and the topology-armed fusion/reordering pass
        # has run
        from repro.core import executor
        executor.get_executor(sched, topo=topo)
        _SCHEDULES[key] = sched
    return sched


def invalidate_topology(topo: Topology | str) -> dict:
    """Scoped cache eviction for one geometry (drift heal / elastic
    swap): drop the cached plans built against ``topo`` (a ``Topology``
    or its fingerprint string) and the compiled executors armed with
    its fingerprint.  Plans and executors for every other geometry —
    including the new measured one about to take over — are untouched.
    Returns ``{"plans": n, "executors": m}`` eviction counts.
    """
    from repro.core import executor
    fp = topo if isinstance(topo, str) else topo.fingerprint()
    doomed = [k for k in _SCHEDULES if k[2].fingerprint() == fp]
    for k in doomed:
        del _SCHEDULES[k]
    return {"plans": len(doomed),
            "executors": executor.invalidate_topology(fp)}


def executor_cache_stats() -> dict:
    """Compiled-executor cache telemetry: size, hit/miss counts, and per
    executor (rounds before/after fusion, trace/sim-run counters)."""
    from repro.core import executor
    return executor.cache_stats()


def clear_executor_cache() -> None:
    """Drop every compiled executor (tests; after env-flag flips)."""
    from repro.core import executor
    executor.clear_cache()


# Selection policy used when algorithm="auto" and no per-call ``policy=``
# is given: "fixed" (paper defaults), "model" (alpha-beta argmin) or
# "tuned" (persisted empirical table; see repro.core.tuner).
_DEFAULT_POLICY = "model"


def set_default_policy(policy: str) -> None:
    """Set the process-wide selection policy for algorithm="auto"."""
    if policy not in selector.POLICIES:
        raise ValueError(f"unknown selection policy {policy!r}; "
                         f"expected one of {selector.POLICIES}")
    global _DEFAULT_POLICY
    _DEFAULT_POLICY = policy


def get_default_policy() -> str:
    return _DEFAULT_POLICY


def ensure_tuned(topo: Topology, *, path=None, heal: bool = True,
                 set_policy: bool = True, **tune_kwargs):
    """Init-time entry for ``policy="tuned"`` (persistent-MPI style).

    Loads (tuning once if missing) the empirical table for ``topo``'s
    substrate; with ``heal=True`` any performance-guideline violation in
    a cached table triggers a scoped re-measure of only the offending
    (collective, size-bucket) cells and persists a bumped generation —
    see ``tuner.ensure_table``.  With ``set_policy=True`` the process
    default policy flips to "tuned", so every later ``algorithm="auto"``
    collective resolves from the (healed) table.  Returns the table.
    """
    from repro.core import tuner  # local: avoid import cycle
    table = tuner.ensure_table(topo, path=path, heal=heal, **tune_kwargs)
    if set_policy:
        set_default_policy("tuned")
    return table


def _resolve(collective: str, algorithm: str, topo: Topology, nbytes: int,
             policy: str | None = None):
    if algorithm == "auto":
        algorithm = selector.select(collective, topo, nbytes,
                                    policy=policy or _DEFAULT_POLICY)
    if algorithm == "xla":
        return "xla", None
    return algorithm, _schedule(collective, algorithm, topo)


# Transport substrates selectable per call: "shardmap" (one ppermute per
# compiled round), "pallas" (whole schedule as one device-side kernel;
# see core.pallas_lowering), or "auto" (the tuner's ``transport`` policy
# cell prices the two per size bucket).
TRANSPORTS = ("shardmap", "pallas", "auto")


def _check_transport(transport: str) -> None:
    """Name check only — callable before any axis/topology resolution,
    so a typo'd transport fails loudly even outside shard_map."""
    if transport not in TRANSPORTS:
        raise ValueError(f"unknown transport {transport!r}; "
                         f"expected one of {TRANSPORTS}")


def _resolve_transport(transport: str, topo: Topology, nbytes: int,
                       policy: str | None = None) -> str:
    """Validate + resolve a transport name to a concrete substrate."""
    _check_transport(transport)
    if transport == "auto":
        from repro.core import tuner  # local: avoid import cycle
        transport = tuner.select_transport(
            topo, nbytes, policy=policy or _DEFAULT_POLICY)
    return transport


# Process-wide chaos plan (``core.chaos.FaultPlan``): when set, every
# transport the api constructs is wrapped so seeded faults fire on the
# real mpix_* execution paths.  Test/CI-only; None in production.
_CHAOS_PLAN = None


def set_chaos(plan) -> None:
    """Install (or clear, with None) the process-wide fault plan; all
    subsequently constructed mpix_* transports are chaos-wrapped."""
    global _CHAOS_PLAN
    _CHAOS_PLAN = plan


def get_chaos():
    return _CHAOS_PLAN


def _transport_instance(kind: str, topo: Topology, names):
    cls = PallasTransport if kind == "pallas" else ShardMapTransport
    return _chaos.wrap(cls(topo.nranks, names, topo=topo), _CHAOS_PLAN)


def _make_transport(transport: str, topo: Topology, names, nbytes: int,
                    policy: str | None = None):
    kind = _resolve_transport(transport, topo, nbytes, policy)
    return _transport_instance(kind, topo, names)


# Degradation telemetry: every mpix_* call that needed the recovery
# ladder appends its DegradationReport here; ``FaultTolerantLoop``
# drains the list each step so a degraded mesh is *visible*, not silent.
_DEGRADATIONS: list = []


def last_degradation():
    """The most recent DegradationReport (None when nothing degraded)."""
    return _DEGRADATIONS[-1] if _DEGRADATIONS else None


def take_degradations() -> list:
    """Drain and return all accumulated DegradationReports."""
    out = list(_DEGRADATIONS)
    _DEGRADATIONS.clear()
    return out


def _execute(collective: str, run, *, algorithm: str, policy,
             topo: Topology, nbytes: int, transport: str, resilience,
             xla_ok: bool = True):
    """Shared execution path of every mpix_* collective.

    ``run(kind, algo)`` closes over the collective's buffers and does
    one full attempt on transport ``kind`` ("shardmap"/"pallas", or
    "xla" when ``algo == "xla"``).  Without ``resilience`` this is a
    zero-overhead passthrough (today's behavior).  With it, the TRACE-
    TIME recovery ladder runs: detected faults — a raised
    ``TransportError`` (failed launch, injected chaos failure), an
    ``NotApplicable`` refit miss, or a wall-clock deadline overrun (an
    injected hang burns host time during tracing) — are retried with
    exponential backoff, degraded to the other ppermute/pallas
    substrate, refitted down the selector's algorithm ladder, and
    finally routed to the substrate's native lowering
    (``algorithm="xla"``, the system-MPI analogue) before a typed
    ``UnrecoverableError`` is raised.

    Honest taxonomy: values here are *traced*, so data-dependent
    verification (canary/checksum) is impossible at this layer —
    silent corruption is caught by the host-level ``ResilientExec``
    (core.resilient), which the chaos registry sweep drives over all
    three transports.  This layer recovers every *detected* fault.
    """
    _check_transport(transport)
    if algorithm == "auto":
        algorithm = selector.select(collective, topo, nbytes,
                                    policy=policy or _DEFAULT_POLICY)
    opts = resolve_resilience(resilience)
    if algorithm == "xla":
        return run("xla", "xla")
    kind = _resolve_transport(transport, topo, nbytes, policy)
    if opts is None:
        return run(kind, algorithm)

    report = DegradationReport(schedule=f"{collective}.{algorithm}",
                               verify="off")

    def finish(out, rung):
        report.recovered_with = rung
        if report.degraded:
            _DEGRADATIONS.append(report)
        return out

    kinds = [kind] + [k for k in ("shardmap", "pallas") if k != kind]
    for k in kinds:
        delay = opts.backoff_s
        for attempt in range(opts.max_retries + 1):
            t0 = time.perf_counter()
            try:
                out = run(k, algorithm)
            except TransportError as e:
                report.attempts.append(Attempt(
                    rung=k, algorithm=algorithm, attempt=attempt,
                    outcome="fault", detail=str(e),
                    seconds=time.perf_counter() - t0))
                time.sleep(delay)
                delay *= opts.backoff_mult
                continue
            dt = time.perf_counter() - t0
            if opts.deadline_s is not None and dt > opts.deadline_s:
                report.attempts.append(Attempt(
                    rung=k, algorithm=algorithm, attempt=attempt,
                    outcome="timeout", seconds=dt,
                    detail=f"{dt:.4f}s > deadline {opts.deadline_s:.4f}s"))
                time.sleep(delay)
                delay *= opts.backoff_mult
                continue
            report.attempts.append(Attempt(
                rung=k, algorithm=algorithm, attempt=attempt,
                outcome="ok", seconds=dt))
            return finish(out, k)
    if opts.refit:
        ladder = [a for a in selector._FIXED.get(collective, ())
                  if a != algorithm]
        ladder += [a for a in REGISTRY.get(collective, {})
                   if a != algorithm and a not in ladder]
        for cand in ladder:
            try:
                out = run(kinds[0], cand)
            except (TransportError, NotApplicable) as e:
                report.attempts.append(Attempt(
                    rung="refit", algorithm=cand, attempt=0,
                    outcome="fault" if isinstance(e, TransportError)
                    else "skipped", detail=str(e) or type(e).__name__))
                continue
            report.attempts.append(Attempt(
                rung="refit", algorithm=cand, attempt=0, outcome="ok"))
            report.refit_algorithm = cand
            return finish(out, kinds[0])
    if xla_ok:
        try:
            out = run("xla", "xla")
        except Exception as e:  # native lowering is best-effort terminal
            report.attempts.append(Attempt(
                rung="xla", algorithm="xla", attempt=0,
                outcome="fault", detail=str(e)))
        else:
            report.attempts.append(Attempt(
                rung="xla", algorithm="xla", attempt=0, outcome="ok"))
            report.refit_algorithm = "xla"
            return finish(out, "xla")
    raise UnrecoverableError(
        f"{collective} could not be recovered on any transport or "
        f"algorithm", report)


def _pad_to(x: jax.Array, mult: int):
    flat = x.reshape(-1)
    rem = (-flat.size) % mult
    if rem:
        flat = jnp.concatenate([flat, jnp.zeros((rem,), x.dtype)])
    return flat


# ---------------------------------------------------------------------------


def mpix_allgather(x: jax.Array, axis_names, *, algorithm: str = "auto",
                   policy: str | None = None,
                   topo: Topology | None = None,
                   transport: str = "shardmap",
                   resilience=None) -> jax.Array:
    """Tiled allgather of the local shard along its leading dim."""
    names = _axes_tuple(axis_names)
    _check_transport(transport)
    topo = topo or topology_from_axes(names)
    nbytes = x.size * x.dtype.itemsize
    n = topo.nranks

    def run(kind, algo):
        if algo == "xla":
            return jax.lax.all_gather(x, names, tiled=True)
        sched = _schedule("allgather", algo, topo)
        tr = _transport_instance(kind, topo, names)
        buf = jnp.zeros((n,) + x.shape, x.dtype)
        buf = buf.at[_flat_rank(names)].set(x)
        out = tr.run(sched, buf)
        return out.reshape((n * x.shape[0],) + x.shape[1:])

    return _execute("allgather", run, algorithm=algorithm, policy=policy,
                    topo=topo, nbytes=nbytes, transport=transport,
                    resilience=resilience)


def mpix_allreduce(x: jax.Array, axis_names, *, algorithm: str = "auto",
                   policy: str | None = None,
                   topo: Topology | None = None,
                   transport: str = "shardmap",
                   resilience=None) -> jax.Array:
    names = _axes_tuple(axis_names)
    _check_transport(transport)
    topo = topo or topology_from_axes(names)
    nbytes = x.size * x.dtype.itemsize
    n = topo.nranks

    def run(kind, algo):
        if algo == "xla":
            return jax.lax.psum(x, names)
        sched = _schedule("allreduce", algo, topo)
        tr = _transport_instance(kind, topo, names)
        flat = _pad_to(x, n)
        out = tr.run(sched, flat.reshape(n, -1))
        return out.reshape(-1)[: x.size].reshape(x.shape)

    return _execute("allreduce", run, algorithm=algorithm, policy=policy,
                    topo=topo, nbytes=nbytes, transport=transport,
                    resilience=resilience)


def mpix_reduce_scatter(x: jax.Array, axis_names, *,
                        algorithm: str = "auto",
                        policy: str | None = None,
                        topo: Topology | None = None,
                        transport: str = "shardmap",
                        resilience=None) -> jax.Array:
    """Reduce along axes; scatter over the leading dim (must divide)."""
    names = _axes_tuple(axis_names)
    _check_transport(transport)
    topo = topo or topology_from_axes(names)
    nbytes = x.size * x.dtype.itemsize
    n = topo.nranks
    if x.shape[0] % n:
        raise ValueError(
            f"mpix_reduce_scatter: leading dim {x.shape[0]} of input "
            f"shape {tuple(x.shape)} must be divisible by nranks={n} "
            f"(one scatter block per rank)")

    def run(kind, algo):
        if algo == "xla":
            return jax.lax.psum_scatter(x, names, scatter_dimension=0,
                                        tiled=True)
        sched = _schedule("reduce_scatter", algo, topo)
        tr = _transport_instance(kind, topo, names)
        blocks = x.reshape((n, x.shape[0] // n) + x.shape[1:])
        out = tr.run(sched, blocks)
        return out[_flat_rank(names)]

    return _execute("reduce_scatter", run, algorithm=algorithm,
                    policy=policy, topo=topo, nbytes=nbytes,
                    transport=transport, resilience=resilience)


def mpix_alltoall(x: jax.Array, axis_names, *, algorithm: str = "auto",
                  policy: str | None = None,
                  topo: Topology | None = None,
                  transport: str = "shardmap",
                  resilience=None) -> jax.Array:
    """Alltoall over the leading dim: in block d = data for rank d;
    out block s = data from rank s.  Leading dim must divide by nranks."""
    names = _axes_tuple(axis_names)
    _check_transport(transport)
    topo = topo or topology_from_axes(names)
    nbytes = x.size * x.dtype.itemsize
    n = topo.nranks
    if x.shape[0] % n:
        raise ValueError(
            f"mpix_alltoall: leading dim {x.shape[0]} of input shape "
            f"{tuple(x.shape)} must be divisible by nranks={n} "
            f"(one block per destination rank)")

    def run(kind, algo):
        if algo == "xla":
            # tiled alltoall: leading dim split into n segments; segment
            # s of the output came from rank s.
            return jax.lax.all_to_all(x, names, split_axis=0,
                                      concat_axis=0, tiled=True)
        sched = _schedule("alltoall", algo, topo)
        tr = _transport_instance(kind, topo, names)
        blocks = x.reshape((n, x.shape[0] // n) + x.shape[1:])
        if sched.num_blocks > n:  # schedules with a separate recv region
            pad = jnp.zeros((sched.num_blocks - n,) + blocks.shape[1:],
                            x.dtype)
            blocks = jnp.concatenate([blocks, pad], axis=0)
        out = tr.run(sched, blocks)
        return out[: sched.result_blocks].reshape(x.shape)

    return _execute("alltoall", run, algorithm=algorithm, policy=policy,
                    topo=topo, nbytes=nbytes, transport=transport,
                    resilience=resilience)


def mpix_alltoall_overlap(x: jax.Array, axis_names, consume, init, *,
                          chunks: int = 0, compute_s: float = 0.0,
                          algorithm: str = "auto",
                          policy: str | None = None,
                          topo: Topology | None = None,
                          transport: str = "shardmap",
                          resilience=None):
    """Partitioned (pipelined) alltoall: the exchange runs in row
    chunks and each chunk's output is folded through
    ``consume(carry, out_chunk, i) -> carry`` as soon as it lands, so
    chunk ``i+1``'s transfer overlaps chunk ``i``'s consumer compute
    (MPIPCL early-bird receive on the MoE dispatch path).

    ``out_chunk`` is the alltoall of the matching row slice of every
    block: shape [(n * rows/chunks), ...] with the usual alltoall block
    order.  ``chunks=0`` lets the tuner pick (``select_overlap_chunks``
    prices the software pipeline against ``compute_s`` seconds of
    consumer compute; policy "tuned" reads the persisted table);
    ``chunks=1`` degenerates to one ``mpix_alltoall`` + one ``consume``
    call — always a legal fallback.  Explicit ``chunks>1`` must divide
    the per-block row count."""
    names = _axes_tuple(axis_names)
    _check_transport(transport)
    topo = topo or topology_from_axes(names)
    nbytes = x.size * x.dtype.itemsize
    n = topo.nranks
    if x.shape[0] % n:
        raise ValueError(
            f"mpix_alltoall_overlap: leading dim {x.shape[0]} of input "
            f"shape {tuple(x.shape)} must be divisible by nranks={n} "
            f"(one block per destination rank)")
    if chunks < 0:
        raise ValueError(
            f"mpix_alltoall_overlap: chunks must be >= 0, got {chunks}")
    rows = x.shape[0] // n
    if chunks == 0:
        from repro.core import tuner  # local: avoid import cycle
        chunks = tuner.select_overlap_chunks(
            topo, x.size * x.dtype.itemsize, compute_s,
            policy=policy or _DEFAULT_POLICY)
        while rows % chunks:          # auto-picked: clamp to a divisor
            chunks -= 1
    elif chunks > 1 and rows % chunks:
        raise ValueError(
            f"mpix_alltoall_overlap: per-block row count {rows} must "
            f"be divisible by chunks={chunks}")
    if chunks <= 1:
        return consume(init, mpix_alltoall(x, names, algorithm=algorithm,
                                           policy=policy, topo=topo,
                                           transport=transport,
                                           resilience=resilience), 0)
    rc = rows // chunks
    nchunks = chunks

    def run(kind, algo):
        if algo == "xla":
            blocks = x.reshape((n, nchunks, rc) + x.shape[1:])

            def body(carry, xi):
                xc, i = xi
                out = jax.lax.all_to_all(
                    xc.reshape((n * rc,) + x.shape[1:]), names,
                    split_axis=0, concat_axis=0, tiled=True)
                return consume(carry, out, i), None

            carry, _ = jax.lax.scan(
                body, init, (blocks.swapaxes(0, 1),
                             jnp.arange(nchunks, dtype=jnp.int32)))
            return carry
        sched = _schedule("alltoall", algo, topo)
        tr = _transport_instance(kind, topo, names)
        blocks = x.reshape((n, rows) + x.shape[1:])
        if sched.num_blocks > n:  # schedules with a separate recv region
            pad = jnp.zeros((sched.num_blocks - n,) + blocks.shape[1:],
                            x.dtype)
            blocks = jnp.concatenate([blocks, pad], axis=0)

        def fold(carry, out_c, i):
            out = (out_c[: sched.result_blocks]
                   .reshape((n * rc,) + x.shape[1:]))
            return consume(carry, out, i)

        return tr.run_chunked(sched, blocks, chunks=nchunks, consume=fold,
                              init=init)

    return _execute("alltoall", run, algorithm=algorithm, policy=policy,
                    topo=topo, nbytes=nbytes, transport=transport,
                    resilience=resilience)


# ---------------------------------------------------------------------------
# neighborhood collectives (paper §2.2, Listing 3/4)
# ---------------------------------------------------------------------------


def make_neighbor_plan(graph, topo: Topology, *,
                       aggregate: bool | None = None,
                       policy: str | None = None,
                       elem_bytes: int | None = None):
    """Compile a persistent neighborhood-alltoallv plan (init-time, not
    traced).  ``aggregate=None`` resolves standard-vs-locality-aware via
    the selection policy ladder (process default when ``policy=None``;
    "tuned" reads the winner persisted by ``tuner.autotune``).
    ``elem_bytes`` is the byte width of one value row (feat * itemsize)
    — it anchors the model comparison and the tuned-table lookup, so
    pass it whenever rows are wider than one float32."""
    from repro.core.plan import ELEM_BYTES, build_plan
    return build_plan(graph, topo, aggregate=aggregate,
                      policy=policy or _DEFAULT_POLICY,
                      elem_bytes=ELEM_BYTES if elem_bytes is None
                      else elem_bytes)


def mpix_neighbor_alltoallv(x: jax.Array, axis_names, plan, *,
                            transport: str = "shardmap",
                            resilience=None) -> jax.Array:
    """Execute a compiled ``NeighborPlan`` (call inside shard_map).

    ``x`` is this rank's [n_local_max, feat] value rows; returns
    [n_recv_max, feat] (rows past this rank's recv size are zeros)."""
    from repro.core.plan import run_shardmap
    names = _axes_tuple(axis_names)
    nbytes = x.size * x.dtype.itemsize

    def run(kind, algo):
        return run_shardmap(plan, x, names, transport=kind)

    return _execute("neighbor_alltoallv", run, algorithm=plan.name,
                    policy=None, topo=plan.topo, nbytes=nbytes,
                    transport=transport, resilience=resilience,
                    xla_ok=False)


# ---------------------------------------------------------------------------
# compute-fused terminal rounds
# ---------------------------------------------------------------------------


def mpix_allreduce_rmsnorm(x: jax.Array, axis_names, scale: jax.Array, *,
                           eps: float = 1e-6, gemma_style: bool = False,
                           algorithm: str = "auto",
                           policy: str | None = None,
                           topo: Topology | None = None,
                           transport: str = "pallas",
                           resilience=None) -> jax.Array:
    """Allreduce ``x`` over ``axis_names``, then rmsnorm the result —
    with the reduction's terminal round fused INTO the rmsnorm kernel.

    On the pallas transport the partial activations are combined with a
    single ``all_gather`` and the summation happens inside the rmsnorm
    Pallas kernel itself (``kernels.rmsnorm.rmsnorm_allreduce``): the
    reduced tensor is never materialized in HBM, saving one full
    write+read round trip vs allreduce-then-normalize (the modeled win
    gated in BENCH_transport.json).  ``x`` is [..., d] with rmsnorm over
    the last dim; summation is in f32 regardless of dtype, so results
    match psum+rmsnorm to float tolerance (NOT bit-exact — the add
    order differs from a ring reduction's).  On "shardmap" it falls
    back to ``mpix_allreduce`` followed by the plain kernel."""
    names = _axes_tuple(axis_names)
    _check_transport(transport)
    topo = topo or topology_from_axes(names)
    from repro.kernels.rmsnorm import ops as rms_ops
    kind = _resolve_transport(transport, topo, x.size * x.dtype.itemsize,
                              policy)
    if kind == "pallas":
        try:
            parts = jax.lax.all_gather(
                x, names if len(names) > 1 else names[0])
            parts = parts.reshape((topo.nranks,) + x.shape)
            return rms_ops.rmsnorm_allreduce(parts, scale, eps,
                                             gemma_style)
        except TransportError as e:
            if resolve_resilience(resilience) is None:
                raise
            # degrade the fused kernel to allreduce-then-normalize
            # (resilient itself) and surface the decision
            report = DegradationReport(
                schedule="allreduce_rmsnorm.fused", verify="off")
            report.attempts.append(Attempt(
                rung="pallas", algorithm="fused", attempt=0,
                outcome="fault", detail=str(e)))
            report.recovered_with = "shardmap"
            _DEGRADATIONS.append(report)
    y = mpix_allreduce(x, names, algorithm=algorithm, policy=policy,
                       topo=topo, resilience=resilience)
    return rms_ops.rmsnorm(y, scale, eps, gemma_style)


__all__ = [
    "mpix_allgather", "mpix_allreduce", "mpix_reduce_scatter",
    "mpix_alltoall", "mpix_alltoall_overlap", "mpix_allreduce_rmsnorm",
    "mpix_neighbor_alltoallv", "make_neighbor_plan",
    "topology_from_axes", "set_default_policy", "get_default_policy",
    "ensure_tuned", "executor_cache_stats", "clear_executor_cache",
    "invalidate_topology", "TRANSPORTS",
    "set_chaos", "get_chaos", "last_degradation", "take_degradations",
    "UnrecoverableError", "DegradationReport",
]
