"""Self-verifying, self-healing execution of compiled schedules.

The defense half of the chaos story (``core.chaos`` is the attack
half): ``ResilientExec`` wraps the armed/pipelined ``CompiledExec`` run
path with the recovery ladder

    verify -> retry/backoff -> transport fallback -> algorithm refit
           -> typed ``UnrecoverableError``

so a misbehaving substrate degrades a collective to a slower-but-
correct path instead of wedging the loop or silently returning wrong
data.  The acceptance oracle is metamorphic: under any seeded fault
campaign the recovered output is **bitwise identical** to the
fault-free run, or a typed error is raised — never a silent mismatch.

Integrity checking (the ``verify=`` knob):

  * ``"off"``    — no checks; faults must be *detected* (raised
    ``TransportError``, deadline overrun) to trigger recovery.
  * ``"canary"`` — one O(result) pass, NO second execution: a canary
    slot row (``schedule.add_canary_slot``) seeded with a deterministic
    pattern rides through the transport's staging buffer and is
    compared bitwise after the run; the input buffer's checksum is
    re-verified; and (finite inputs) the result region is scanned for
    non-finite values.  Catches NaN sprays and canary-hitting
    corruption.
  * ``"full"``   — additionally compares the result region bitwise
    against ONE ``SimTransport.run_reference`` execution of the
    original schedule (computed once per call, shared across retries —
    the Hunold continuous-verification mode).  Catches everything,
    costs one reference execution; ``tuner.verify_overhead_s`` prices
    both modes.

Transport fallback walks ``ladder`` (default pallas -> shardmap -> sim
-> sim-reference); a rung the host cannot serve (shardmap without
enough devices) is skipped with a recorded reason.  Algorithm refit
reuses the selector's ``NotApplicable`` ladder (the PR 8 elastic-swap
machinery): when every rung fails for the current schedule, the next
algorithm for the same collective is built and the ladder re-runs.
Every decision lands in a ``DegradationReport``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time

import numpy as np

import jax

from repro.core.schedule import (CommSchedule, NotApplicable,
                                 add_canary_slot)
from repro.core.topology import Topology
from repro.core.transport import (PallasTransport, ShardMapTransport,
                                  SimTransport, TransportError)

VERIFY_MODES = ("off", "canary", "full")
RUNGS = ("pallas", "shardmap", "sim", "reference")


@dataclasses.dataclass(frozen=True)
class ResilienceOptions:
    """Knobs of the recovery ladder (``resilience=`` everywhere).

    verify:       "off" | "canary" | "full" (see module docstring).
    max_retries:  extra attempts per rung after the first.
    backoff_s:    first retry delay; each retry multiplies by
                  ``backoff_mult`` (exponential backoff).
    deadline_s:   per-attempt wall-clock bound; an attempt past it is
                  a timeout fault even if the result arrived (None =
                  no deadline).
    ladder:       transport rungs, tried in order.
    refit:        when every rung fails, walk the selector's algorithm
                  ladder (requires the collective name to be known).
    """

    verify: str = "canary"
    max_retries: int = 2
    backoff_s: float = 1e-3
    backoff_mult: float = 2.0
    deadline_s: float | None = None
    ladder: tuple = RUNGS
    refit: bool = True

    def __post_init__(self):
        if self.verify not in VERIFY_MODES:
            raise ValueError(f"verify must be one of {VERIFY_MODES}, "
                             f"got {self.verify!r}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if not (np.isfinite(self.backoff_s) and self.backoff_s >= 0):
            raise ValueError(f"backoff_s must be finite >= 0, "
                             f"got {self.backoff_s}")
        if not (np.isfinite(self.backoff_mult) and self.backoff_mult >= 1):
            raise ValueError(f"backoff_mult must be finite >= 1, "
                             f"got {self.backoff_mult}")
        if self.deadline_s is not None and not (
                np.isfinite(self.deadline_s) and self.deadline_s > 0):
            raise ValueError(f"deadline_s must be finite > 0 or None, "
                             f"got {self.deadline_s}")
        object.__setattr__(self, "ladder", tuple(self.ladder))
        if not self.ladder:
            raise ValueError("ladder must name at least one rung")
        for rung in self.ladder:
            if rung not in RUNGS:
                raise ValueError(f"unknown ladder rung {rung!r}; "
                                 f"expected rungs from {RUNGS}")


def resolve_resilience(resilience) -> ResilienceOptions | None:
    """Normalize the public ``resilience=`` argument: None/False = off
    entirely (zero overhead), True = defaults, a verify-mode string, a
    dict of option overrides, or a ``ResilienceOptions``."""
    if resilience is None or resilience is False:
        return None
    if resilience is True:
        return ResilienceOptions()
    if isinstance(resilience, ResilienceOptions):
        return resilience
    if isinstance(resilience, str):
        if resilience not in VERIFY_MODES:
            raise ValueError(
                f"unknown resilience preset {resilience!r}; expected a "
                f"verify mode from {VERIFY_MODES}, a ResilienceOptions, "
                f"or a dict of its fields")
        return ResilienceOptions(verify=resilience)
    if isinstance(resilience, dict):
        return ResilienceOptions(**resilience)
    raise ValueError(f"cannot interpret resilience={resilience!r}")


@dataclasses.dataclass(frozen=True)
class Attempt:
    """One ladder step (telemetry row of the DegradationReport)."""

    rung: str                     # transport rung (or "refit")
    algorithm: str                # schedule/algorithm attempted
    attempt: int                  # 0-based retry index within the rung
    outcome: str                  # ok|fault|timeout|corrupt|skipped
    detail: str = ""
    seconds: float = 0.0


@dataclasses.dataclass
class DegradationReport:
    """What the ladder did for one call: every attempt, every checksum
    verdict, where (if anywhere) recovery landed."""

    schedule: str
    verify: str
    attempts: list = dataclasses.field(default_factory=list)
    verdicts: list = dataclasses.field(default_factory=list)
    recovered_with: str | None = None    # rung that produced the output
    refit_algorithm: str | None = None   # set when the refit rung won

    @property
    def degraded(self) -> bool:
        """True when the call did not succeed first-try on the first
        available rung."""
        return (self.refit_algorithm is not None
                or any(a.outcome not in ("ok", "skipped")
                       for a in self.attempts))

    @property
    def retries(self) -> int:
        return sum(1 for a in self.attempts
                   if a.outcome in ("fault", "timeout", "corrupt"))

    def summary(self) -> str:
        path = " -> ".join(f"{a.rung}[{a.outcome}]" for a in self.attempts)
        return (f"{self.schedule}: {path}; recovered_with="
                f"{self.recovered_with} refit={self.refit_algorithm}")


class UnrecoverableError(RuntimeError):
    """Every rung and every refit candidate failed; the attached
    ``report`` records the full ladder walk."""

    def __init__(self, msg: str, report: DegradationReport):
        super().__init__(msg + " | " + report.summary())
        self.report = report


def canary_pattern(schedule: CommSchedule, dtype, slot_shape) -> np.ndarray:
    """Deterministic per-rank canary rows [nranks, 1, *slot] — seeded by
    the schedule fingerprint so replays and reports agree."""
    digest = hashlib.sha1(
        ("canary:" + schedule.fingerprint()).encode()).digest()
    rng = np.random.default_rng(int.from_bytes(digest[:8], "little"))
    shape = (schedule.nranks, 1) + tuple(slot_shape)
    dt = np.dtype(dtype)
    vals = rng.integers(1, 100, size=shape)
    if not np.issubdtype(dt, np.integer):
        vals = vals.astype(np.float64)
    return np.asarray(vals).astype(dt)


def _checksum(buf) -> str:
    a = np.ascontiguousarray(np.asarray(buf))
    return hashlib.sha1(a.tobytes()).hexdigest()


class ResilientExec:
    """The recovery-ladder engine for one compiled schedule.

    Host-level: ``run(gbuf)`` takes a concrete global
    [nranks, num_slots, *slot] buffer (the SimTransport /
    ``run_global`` calling convention every bit-exactness sweep
    drives) and returns ``(output, DegradationReport)``.

    ``transports`` optionally overrides rung construction with
    ready-made transport instances — the chaos tests inject
    ``chaos.wrap``-ped rungs there; anything not overridden is built
    clean.  ``collective``/``algorithm`` name the plan for the refit
    rung (omit them and refit is skipped).
    """

    def __init__(self, schedule: CommSchedule, topo: Topology | None = None,
                 *, options: ResilienceOptions | None = None,
                 collective: str | None = None,
                 algorithm: str | None = None,
                 transports: dict | None = None):
        self.schedule = schedule
        self.topo = topo
        self.options = options or ResilienceOptions()
        self.collective = collective
        self.algorithm = algorithm
        self.transports = dict(transports or {})
        self._canary: CommSchedule | None = None

    # -- rung plumbing ----------------------------------------------------
    def _transport(self, rung: str):
        tr = self.transports.get(rung)
        if tr is not None:
            return tr
        n = self.schedule.nranks
        if rung == "pallas":
            return PallasTransport(n, topo=self.topo)
        if rung == "shardmap":
            return ShardMapTransport(n, "_resil", topo=self.topo)
        return SimTransport(n, topo=self.topo)     # sim | reference

    def _rung_unavailable(self, rung: str) -> str | None:
        if rung == "shardmap" and "shardmap" not in self.transports \
                and jax.device_count() < self.schedule.nranks:
            return (f"needs {self.schedule.nranks} devices, have "
                    f"{jax.device_count()}")
        return None

    def _call(self, rung: str, schedule: CommSchedule, buf):
        tr = self._transport(rung)
        if rung == "pallas":
            out = tr.run_global(schedule, buf)
        elif rung == "shardmap":
            out = tr.run_global(schedule, buf)
        elif rung == "reference":
            out = tr.run_reference(schedule, buf)
        else:
            out = tr.run(schedule, buf)
        return jax.block_until_ready(out) if hasattr(out, "block_until_ready") \
            else out

    # -- verification -----------------------------------------------------
    def _result_region(self, schedule: CommSchedule, out) -> np.ndarray:
        a = np.asarray(out)
        rows = schedule.result_slots
        return np.stack([a[r, schedule.out_offset(r):
                           schedule.out_offset(r) + rows]
                         for r in range(schedule.nranks)])

    def _verify(self, report, schedule, out, *, pattern, in_sum, buf,
                in_finite, reference) -> bool:
        """All verdicts are bitwise (``tobytes`` compares) so NaN-vs-NaN
        and negative-zero cases are never misjudged; ``schedule`` is the
        ORIGINAL (canary-free) schedule whose geometry defines the
        result region and the canary row index."""
        opts = self.options
        out = np.asarray(out)
        ok = True
        if pattern is not None:
            got = np.ascontiguousarray(
                out[:, schedule.num_slots: schedule.num_slots + 1])
            canary_ok = got.tobytes() == np.asarray(pattern).tobytes()
            report.verdicts.append(("canary", canary_ok))
            ok &= canary_ok
        if in_sum is not None:
            input_ok = _checksum(buf) == in_sum
            report.verdicts.append(("input-checksum", input_ok))
            ok &= input_ok
        res = self._result_region(schedule, out)
        if in_finite and np.issubdtype(res.dtype, np.floating):
            finite_ok = bool(np.isfinite(
                res.astype(np.float32, copy=False)).all())
            report.verdicts.append(("finite", finite_ok))
            ok &= finite_ok
        if opts.verify == "full":
            ref_ok = (np.ascontiguousarray(res).tobytes()
                      == np.ascontiguousarray(reference).tobytes())
            report.verdicts.append(("reference", ref_ok))
            ok &= ref_ok
        return ok

    # -- the ladder -------------------------------------------------------
    def run(self, buf):
        """Execute with the full recovery ladder; returns
        ``(output, DegradationReport)`` or raises a typed
        ``UnrecoverableError``."""
        opts = self.options
        report = DegradationReport(schedule=self.schedule.name,
                                   verify=opts.verify)
        out = self._run_ladder(buf, report, self.schedule,
                               self.algorithm or self.schedule.name)
        if out is not None:
            return out, report
        # every rung failed -> algorithm refit (selector NotApplicable
        # ladder, the PR 8 elastic-swap machinery)
        if opts.refit and self.collective is not None \
                and self.topo is not None:
            from repro.core.algorithms import REGISTRY
            from repro.core.selector import _FIXED
            coll = self.collective
            ladder = [a for a in _FIXED.get(coll, ())
                      if a != self.algorithm]
            ladder += [a for a in REGISTRY.get(coll, {})
                       if a != self.algorithm and a not in ladder]
            for cand in ladder:
                try:
                    cand_sched = REGISTRY[coll][cand](self.topo)
                except NotApplicable as e:
                    report.attempts.append(Attempt(
                        rung="refit", algorithm=cand, attempt=0,
                        outcome="skipped", detail=str(e) or "NotApplicable"))
                    continue
                child = ResilientExec(
                    cand_sched, self.topo, options=opts,
                    collective=None, algorithm=cand,
                    transports=self.transports)
                child_report = DegradationReport(
                    schedule=cand_sched.name, verify=opts.verify)
                out = child._run_ladder(buf, child_report, cand_sched, cand)
                report.attempts.extend(child_report.attempts)
                report.verdicts.extend(child_report.verdicts)
                if out is not None:
                    report.refit_algorithm = cand
                    report.recovered_with = child_report.recovered_with
                    return out, report
        raise UnrecoverableError(
            "collective could not be recovered on any transport rung "
            "or refit algorithm", report)

    def _run_ladder(self, buf, report, schedule, algorithm):
        """Walk the transport rungs for ONE schedule; returns the
        verified output (canary stripped) or None when every rung is
        exhausted."""
        opts = self.options
        use_canary = opts.verify != "off"
        pattern = in_sum = None
        xsched, xbuf = schedule, buf
        if use_canary:
            if schedule is self.schedule:
                if self._canary is None:
                    self._canary = add_canary_slot(schedule)
                xsched = self._canary
            else:
                xsched = add_canary_slot(schedule)
            pattern = canary_pattern(schedule, np.asarray(buf).dtype,
                                     np.asarray(buf).shape[2:])
            xbuf = np.concatenate([np.asarray(buf), pattern], axis=1)
            in_sum = _checksum(xbuf)
        in_finite = bool(np.isfinite(
            np.asarray(buf).astype(np.float32, copy=False)).all()) \
            if np.issubdtype(np.asarray(buf).dtype, np.floating) else False
        reference = None
        if opts.verify == "full":
            ref_tr = SimTransport(schedule.nranks, topo=self.topo)
            reference = self._result_region(
                schedule, ref_tr.run_reference(schedule, np.asarray(buf)))
        return self._walk(report, schedule, xsched, xbuf, algorithm,
                          pattern=pattern, in_sum=in_sum,
                          in_finite=in_finite, reference=reference)

    def _walk(self, report, schedule, xsched, xbuf, algorithm, *,
              pattern, in_sum, in_finite, reference):
        opts = self.options
        for rung in opts.ladder:
            reason = self._rung_unavailable(rung)
            if reason is not None:
                report.attempts.append(Attempt(
                    rung=rung, algorithm=algorithm, attempt=0,
                    outcome="skipped", detail=reason))
                continue
            delay = opts.backoff_s
            for attempt in range(opts.max_retries + 1):
                t0 = time.perf_counter()
                try:
                    out = self._call(rung, xsched, xbuf)
                except TransportError as e:
                    report.attempts.append(Attempt(
                        rung=rung, algorithm=algorithm, attempt=attempt,
                        outcome="fault", detail=str(e),
                        seconds=time.perf_counter() - t0))
                    time.sleep(delay)
                    delay *= opts.backoff_mult
                    continue
                dt = time.perf_counter() - t0
                if opts.deadline_s is not None and dt > opts.deadline_s:
                    report.attempts.append(Attempt(
                        rung=rung, algorithm=algorithm, attempt=attempt,
                        outcome="timeout",
                        detail=f"{dt:.4f}s > deadline "
                               f"{opts.deadline_s:.4f}s", seconds=dt))
                    time.sleep(delay)
                    delay *= opts.backoff_mult
                    continue
                if self._verify(report, schedule, out, pattern=pattern,
                                in_sum=in_sum, buf=xbuf,
                                in_finite=in_finite, reference=reference):
                    report.attempts.append(Attempt(
                        rung=rung, algorithm=algorithm, attempt=attempt,
                        outcome="ok", seconds=dt))
                    report.recovered_with = rung
                    a = np.asarray(out)
                    return a[:, :schedule.num_slots] if pattern is not None \
                        else a
                report.attempts.append(Attempt(
                    rung=rung, algorithm=algorithm, attempt=attempt,
                    outcome="corrupt", detail="integrity check failed",
                    seconds=dt))
                time.sleep(delay)
                delay *= opts.backoff_mult
        return None


def run_resilient(schedule: CommSchedule, buf, *,
                  topo: Topology | None = None,
                  resilience=True, collective: str | None = None,
                  algorithm: str | None = None,
                  transports: dict | None = None):
    """One-shot convenience: build a ``ResilientExec`` and run it."""
    opts = resolve_resilience(resilience) or ResilienceOptions()
    ex = ResilientExec(schedule, topo, options=opts,
                       collective=collective, algorithm=algorithm,
                       transports=transports)
    return ex.run(buf)
