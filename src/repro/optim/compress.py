"""Gradient compression: int8 block quantization with error feedback.

Used by the ``compressed_hierarchical`` DP-allreduce mode: gradients are
quantized to int8 (per-block absmax scale) before crossing the DCN; the
quantization residual is fed back into the next step's gradient so the
bias cancels over time (standard EF-SGD argument).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_int8(x: jax.Array):
    """x [..] -> (q int8 [..], scale f32 [nblocks]) over flattened blocks."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def decompress_int8(q: jax.Array, scale: jax.Array, shape, dtype):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def ef_compress_tree(grads, residual):
    """Apply error feedback then compress each leaf; returns
    (compressed leaves, new residual)."""
    def one(g, r):
        x = g.astype(jnp.float32) + (r if r is not None else 0.0)
        q, s = compress_int8(x)
        back = decompress_int8(q, s, g.shape, jnp.float32)
        return (q, s), x - back

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual) if residual is not None \
        else [None] * len(flat_g)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_res = jax.tree.unflatten(tdef, [o[1] for o in out])
    return comp, new_res
