"""AdamW with fp32 master moments over bf16 params (pytree-native).

Kept dependency-free (no optax) so the whole update is one jnp
expression the partitioner shards exactly like the parameters — moments
inherit the FSDP sharding of their parameter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    cnt = state["count"] + 1
    c1 = 1.0 - b1 ** cnt.astype(jnp.float32)
    c2 = 1.0 - b2 ** cnt.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        step = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": cnt}
