from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm  # noqa: F401
from repro.optim.schedule import cosine_schedule  # noqa: F401
from repro.optim.compress import compress_int8, decompress_int8  # noqa: F401
