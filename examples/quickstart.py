"""Quickstart: the MPIX layer in 60 lines (paper Listings 1-4).

Runs on 8 forced host devices — same code runs on a TPU pod by swapping
the mesh.  Shows: (1) drop-in collective replacement with a selectable
algorithm, (2) a persistent locality-aware neighborhood collective.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import api as mpix
from repro.core.plan import CommGraph, build_plan, run_shardmap
from repro.core.topology import Topology
from repro import compat

mesh = compat.make_mesh((2, 4), ("pod", "data"))
x = np.arange(8 * 4, dtype=np.float32).reshape(8, 4)

# --- Listing 1 -> 2: replace the collective, pick the algorithm --------
for algo in ("xla", "ring_rs_ag", "hierarchical", "auto"):
    f = jax.jit(compat.shard_map(
        lambda v: mpix.mpix_allreduce(v, ("pod", "data"), algorithm=algo),
        mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(None),
        check_vma=False))
    with compat.set_mesh(mesh):
        out = np.asarray(f(x))
    assert np.allclose(out, x.reshape(8, 1, 4).sum(0))
    print(f"mpix_allreduce[{algo:>13s}] ok -> {out[0][:4]}")

# --- Listing 3 -> 4: persistent neighborhood alltoallv -----------------
rng = np.random.default_rng(0)
graph = CommGraph.random(8, n_local=4, degree=3, rng=rng, dup_frac=0.8)
topo = Topology(nranks=8, ranks_per_pod=4)
plan = build_plan(graph, topo, aggregate=True)      # init once ...
std = build_plan(graph, topo, aggregate=False)
print(f"neighbor plan: DCN bytes {std.traffic()['dcn']} -> "
      f"{plan.traffic()['dcn']} (locality-aware dedupe), "
      f"DCN msgs {std.traffic()['msgs_dcn']} -> "
      f"{plan.traffic()['msgs_dcn']}")

values = np.stack([rng.normal(size=(4, 2)).astype(np.float32)
                   for _ in range(8)])
g = jax.jit(compat.shard_map(                          # ... execute often
    lambda v: run_shardmap(plan, v, ("pod", "data")),
    mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
    check_vma=False))
with compat.set_mesh(mesh):
    recv = np.asarray(g(values.reshape(8 * 4, 2)))
print("neighbor exchange ok, recv shape", recv.shape)
print("quickstart OK")
