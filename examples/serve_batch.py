"""Serve a small model with batched requests: batched prefill via the
forward pass + greedy KV-cache decode, measuring per-token latency.

    PYTHONPATH=src python examples/serve_batch.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M
from repro.serve.step import ServeOptions, make_decode_step
from repro import compat

ARCH = "qwen3-14b"          # smoke-sized variant of the qwen3 family
BATCH, PROMPT, GEN = 8, 24, 24


def main():
    cfg = configs.get_smoke(ARCH)
    n = jax.device_count()
    mesh = compat.make_mesh((n, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        params = M.init_params(jax.random.key(0), cfg)
        reqs = jax.random.randint(jax.random.key(1), (BATCH, PROMPT), 2,
                                  cfg.vocab_size)
        cache = M.init_cache(cfg, BATCH, PROMPT + GEN)
        decode = jax.jit(make_decode_step(cfg, mesh, ServeOptions()))

        tok = reqs[:, :1]
        t0 = time.time()
        gen = []
        for i in range(PROMPT + GEN - 1):
            nxt, cache = decode(params, cache, tok)
            tok = reqs[:, i + 1: i + 2] if i + 1 < PROMPT else nxt
            if i + 1 >= PROMPT:
                gen.append(np.asarray(nxt)[:, 0])
        jax.block_until_ready(tok)
        dt = time.time() - t0
    gen = np.stack(gen, 1)
    steps = PROMPT + GEN - 1
    print(f"batch={BATCH} prompt={PROMPT} gen={GEN}: "
          f"{dt/steps*1e3:.1f} ms/step, "
          f"{BATCH*steps/dt:.0f} tok/s aggregate")
    assert gen.shape == (BATCH, GEN)
    print("serve_batch OK")


if __name__ == "__main__":
    main()
