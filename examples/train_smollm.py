"""End-to-end driver: train the smollm-family model for a few hundred
steps on host devices with the full production stack — FSDP/explicit-DP
through the MPIX layer, fault-tolerant loop, async checkpoints.

    PYTHONPATH=src python examples/train_smollm.py           # ~2 min
    PYTHONPATH=src python examples/train_smollm.py --full    # 360M cfg

Kill it mid-run and start it again: it resumes from the last committed
checkpoint and the loss curve continues exactly.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import sys

sys.argv = [sys.argv[0]]  # launch.train re-parses

from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 360M config (slow on CPU)")
    ap.add_argument("--steps", type=int, default=300)
    args, _ = ap.parse_known_args()
    argv = ["--arch", "smollm-360m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--lr", "3e-3",
            "--dp-mode", "explicit", "--dp-algorithm", "hierarchical",
            "--grad-buckets", "4",
            "--ckpt-dir", "/tmp/repro_smollm_run", "--ckpt-every", "100"]
    if not args.full:
        argv.append("--smoke")
    losses = T.main(argv)
    assert losses[-1] < losses[0], "loss must decrease"
    print("train_smollm OK")


if __name__ == "__main__":
    main()
