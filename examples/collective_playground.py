"""Algorithm playground: compare every registered algorithm for each
collective on a chosen topology — rounds, link traffic, modeled time —
then verify them bit-exactly against numpy on the SimTransport.

    PYTHONPATH=src python examples/collective_playground.py \
        --nranks 64 --ranks-per-pod 16 --bytes 1048576
"""
import argparse

import numpy as np

from repro.core.algorithms import REGISTRY
from repro.core.topology import Topology
from repro.core.transport import SimTransport


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nranks", type=int, default=64)
    ap.add_argument("--ranks-per-pod", type=int, default=16)
    ap.add_argument("--bytes", type=int, default=1 << 20)
    args = ap.parse_args()
    topo = Topology(nranks=args.nranks, ranks_per_pod=args.ranks_per_pod)
    rng = np.random.default_rng(0)

    print(f"topology: {args.nranks} ranks, {topo.npods} pods")
    print(f"{'collective':<15}{'algorithm':<28}{'rounds':>7}"
          f"{'DCN msgs':>9}{'t_model':>12}")
    for coll, algos in REGISTRY.items():
        for name, builder in algos.items():
            try:
                sched = builder(topo)
            except AssertionError:
                continue
            t = sched.modeled_time(topo,
                                   args.bytes // max(1, sched.num_blocks))
            print(f"{coll:<15}{name:<28}{sched.num_rounds:>7}"
                  f"{sched.message_count(topo, local=False):>9}"
                  f"{t*1e6:>10.1f}us")
            # bit-exact verification on the numpy transport
            n = topo.nranks
            if coll == "allgather":
                buf = np.zeros((n, sched.num_blocks, 2))
                contrib = rng.normal(size=(n, 2))
                for r in range(n):
                    buf[r, r] = contrib[r]
                out = SimTransport(n).run(sched, buf)
                assert np.allclose(out, np.broadcast_to(contrib,
                                                        (n, n, 2)))
    print("playground OK (allgather outputs verified vs numpy)")


if __name__ == "__main__":
    main()
