"""Per-architecture smoke tests: reduced same-family configs, one
forward + one grad (train) step + one decode step on CPU; asserts output
shapes and finiteness.  Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import shapes as shp
from repro.models import model as M

B, S = 2, 16


def _inputs(cfg, key):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.encoder is not None:
        kw["encoder_frames"] = jax.random.normal(
            ks[1], (B, cfg.encoder.n_frames, cfg.encoder.d_model),
            jnp.bfloat16)
    if cfg.vision_prefix:
        kw["vision_embeds"] = jax.random.normal(
            ks[2], (B, cfg.vision_prefix, cfg.d_model), jnp.bfloat16)
    return tokens, kw


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_and_grad(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.key(0)
    params = M.init_params(key, cfg)
    tokens, kw = _inputs(cfg, jax.random.key(1))
    logits = jax.jit(lambda p: M.forward(p, cfg, tokens, **kw))(params)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    labels = jnp.roll(tokens, -1, axis=1)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: M.lm_loss(p, cfg, tokens, labels, **kw)))(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_decode_step(arch):
    cfg = configs.get_smoke(arch)
    params = M.init_params(jax.random.key(0), cfg)
    cache = M.init_cache(cfg, batch=B, max_len=S)
    tokens, kw = _inputs(cfg, jax.random.key(1))
    cross = (M.encode(params, cfg, kw["encoder_frames"])
             if cfg.encoder is not None else None)

    step = jax.jit(lambda c, t: M.decode_step(params, cfg, c, t,
                                              cross_src=cross))
    logits, cache = step(cache, tokens[:, :1])
    assert logits.shape == (B, 1, cfg.vocab_size)
    logits, cache = step(cache, tokens[:, 1:2])
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["rwkv6-3b", "jamba-1.5-large-398b"])
def test_recurrent_decode_matches_forward(arch):
    """O(1)-state decode must reproduce the parallel forward logits —
    the property that makes the 500k cell runnable for SSM/hybrid."""
    cfg = configs.get_smoke(arch)
    params = M.init_params(jax.random.key(0), cfg)
    tokens, _ = _inputs(cfg, jax.random.key(1))
    full = M.forward(params, cfg, tokens).astype(jnp.float32)

    cache = M.init_cache(cfg, batch=B, max_len=S)
    step = jax.jit(lambda c, t: M.decode_step(params, cfg, c, t))
    outs = []
    for i in range(S):
        lg, cache = step(cache, tokens[:, i: i + 1])
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    diff = np.abs(dec - np.asarray(full))
    if cfg.moe is not None:
        # forward uses dense-dispatch MoE, decode uses capacity dispatch:
        # near-tied top-k routing can flip between them under bf16, so a
        # small fraction of logits legitimately diverges.  Assert the
        # bulk agrees and the decoded distribution is operationally the
        # same (top-1 agreement).
        assert np.quantile(diff, 0.9) < 0.11, np.quantile(diff, 0.9)
        top_full = np.asarray(full).argmax(-1)
        top_dec = dec.argmax(-1)
        agree = (top_full == top_dec).mean()
        assert agree >= 0.9, agree
    else:
        np.testing.assert_allclose(dec, np.asarray(full), atol=0.11,
                                   rtol=0.05)


def test_full_param_counts():
    """Full configs hit their published parameter classes (eval_shape
    only — no allocation)."""
    expect = {
        "gemma2-2b": (2.0e9, 3.5e9),
        "gemma-2b": (2.0e9, 3.3e9),
        "qwen3-14b": (13e9, 16e9),
        "smollm-360m": (0.3e9, 0.45e9),
        "deepseek-v3-671b": (630e9, 700e9),
        # assignment pins 48L (actual Moonlight-16B has 27L); with the
        # assigned depth the same family lands at ~28B (see DESIGN.md §5)
        "moonshot-v1-16b-a3b": (26e9, 30e9),
        "rwkv6-3b": (2.7e9, 3.6e9),
        "whisper-small": (0.2e9, 0.3e9),
        "qwen2-vl-7b": (6.5e9, 8.5e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}," \
                              f" {hi/1e9}]B"


def test_cells_applicability():
    cells = shp.cells()
    assert len(cells) == 40
    skips = [(a, s) for a, s, ok in cells if not ok]
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)
