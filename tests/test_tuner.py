"""Unit + property tests for the empirical selector (repro.core.tuner)
and the policy plumbing in selector/api."""
import copy

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed: seeded fallback
    from _hypothesis_stub import given, settings, st

from repro.core import api, selector, tuner
from repro.core.algorithms import REGISTRY
from repro.core.topology import Topology, flat_topology

TOPO = Topology(nranks=8, ranks_per_pod=4)
SIZES = (1 << 10, 1 << 14, 1 << 18, 1 << 22)


@pytest.fixture(autouse=True)
def _isolate_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path / "cache.json"))
    tuner.clear_cache()
    yield
    tuner.clear_cache()


@pytest.fixture(scope="module")
def model_table():
    return tuner.tune(TOPO, sizes=SIZES, force_model=True)


# ---------------------------------------------------------------------------
# buckets + fingerprints
# ---------------------------------------------------------------------------


def test_size_bucket_boundaries():
    assert tuner.size_bucket(1) == 0
    assert tuner.size_bucket(1024) == 10
    assert tuner.size_bucket(1025) == 11
    assert tuner.size_bucket(0) == 0      # degenerate payloads clamp


def test_size_bucket_exact_powers_of_two():
    """Bucket b covers (2**(b-1), 2**b]: an exact power of two sits at
    the top of its own bucket, one byte more rolls over."""
    for b in range(1, 31):
        assert tuner.size_bucket(2 ** b) == b
        assert tuner.size_bucket(2 ** b + 1) == b + 1
    for b in range(2, 31):
        assert tuner.size_bucket(2 ** b - 1) == b
    assert tuner.size_bucket(2 ** 0) == 0


def test_size_bucket_degenerate_and_negative():
    assert tuner.size_bucket(0) == 0
    assert tuner.size_bucket(1) == 0
    with pytest.raises(ValueError, match="must be >= 0 bytes, got -1"):
        tuner.size_bucket(-1)
    with pytest.raises(ValueError, match="-4096"):
        tuner.size_bucket(-4096)


@settings(max_examples=30, deadline=None)
@given(a=st.integers(1, 1 << 30), b=st.integers(1, 1 << 30))
def test_size_bucket_monotone(a, b):
    lo, hi = min(a, b), max(a, b)
    assert tuner.size_bucket(lo) <= tuner.size_bucket(hi)


def test_fingerprint_distinguishes_topologies():
    fps = {Topology(8, 4).fingerprint("cpu"),
           Topology(8, 8).fingerprint("cpu"),
           Topology(16, 4).fingerprint("cpu"),
           Topology(8, 4).fingerprint("TPU v5e")}
    assert len(fps) == 4
    assert Topology(8, 4).fingerprint("TPU v5e") == "TPU_v5e:n8:rpp4"


# ---------------------------------------------------------------------------
# table round-trip through the JSON cache
# ---------------------------------------------------------------------------


def test_table_roundtrip(tmp_path, model_table):
    path = tmp_path / "tuned.json"
    tuner.save_table(model_table, path=path)
    tuner.clear_cache()
    loaded = tuner.load_table(model_table.fingerprint, path=path)
    assert loaded is not None
    assert loaded.fingerprint == model_table.fingerprint
    assert loaded.source == "model"
    assert loaded.entries == model_table.entries
    assert loaded.violations == model_table.violations


def test_save_merges_fingerprints(tmp_path):
    path = tmp_path / "tuned.json"
    t1 = tuner.tune(TOPO, sizes=(1024,), force_model=True)
    t2 = tuner.tune(flat_topology(16), sizes=(1024,), force_model=True)
    tuner.save_table(t1, path=path)
    tuner.save_table(t2, path=path)
    tuner.clear_cache()
    assert tuner.load_table(t1.fingerprint, path=path) is not None
    assert tuner.load_table(t2.fingerprint, path=path) is not None


def test_load_missing_and_corrupt(tmp_path):
    assert tuner.load_table("cpu:n8:rpp4", path=tmp_path / "nope.json") \
        is None
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert tuner.load_table("cpu:n8:rpp4", path=bad) is None


# ---------------------------------------------------------------------------
# tuned selection semantics
# ---------------------------------------------------------------------------


def test_tuned_never_worse_than_fixed(model_table):
    """The tuned winner's probed time never exceeds the fixed default's
    for the same bucket (argmin over a candidate set containing it)."""
    for coll in tuner.COLLECTIVES:
        for nbytes in SIZES:
            fixed = selector.select(coll, TOPO, nbytes, policy="fixed")
            tuned = selector.select(coll, TOPO, nbytes, policy="tuned",
                                    tuned_table=model_table)
            t_tuned = model_table.time_of(coll, nbytes, tuned)
            t_fixed = model_table.time_of(coll, nbytes, fixed)
            assert t_fixed is not None, (coll, nbytes, fixed)
            assert t_tuned <= t_fixed, (coll, nbytes, tuned, fixed)


def test_tuned_winners_are_executable(model_table):
    for coll, per in model_table.entries.items():
        for rec in per.values():
            name = rec["best"]
            assert name == "xla" or name in REGISTRY[coll]
            if name != "xla":
                REGISTRY[coll][name](TOPO)   # builds without assertion


def test_lookup_nearest_bucket(model_table):
    per = model_table.entries["allgather"]
    lo_bucket = min(per, key=int)
    # far below every probed size -> clamps to the smallest bucket
    assert model_table.lookup("allgather", 1) == per[lo_bucket]["best"]
    hi_bucket = max(per, key=int)
    assert model_table.lookup("allgather", 1 << 40) \
        == per[hi_bucket]["best"]
    assert model_table.lookup("not_a_collective", 1024) is None


@settings(max_examples=30, deadline=None)
@given(nbytes=st.integers(1, 1 << 28),
       coll=st.sampled_from(list(tuner.COLLECTIVES)))
def test_tuned_select_total(model_table, coll, nbytes):
    """policy="tuned" always returns a runnable algorithm name."""
    name = tuner.tuned_select(coll, TOPO, nbytes, table=model_table)
    assert name is not None
    assert name == "xla" or name in REGISTRY[coll]


def test_stale_table_entry_falls_back_to_model(model_table):
    stale = copy.deepcopy(model_table)
    for per in stale.entries.values():
        for rec in per.values():
            rec["best"] = "algorithm_deleted_in_v2"
    got = selector.select("allgather", TOPO, 1024, policy="tuned",
                          tuned_table=stale)
    assert got == selector.select("allgather", TOPO, 1024, policy="model")


def test_tuned_without_table_matches_model():
    topo = Topology(nranks=12, ranks_per_pod=3)   # never tuned
    for coll in tuner.COLLECTIVES:
        assert selector.select(coll, topo, 4096, policy="tuned") \
            == selector.select(coll, topo, 4096, policy="model")


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        selector.select("allgather", TOPO, 1024, policy="fastest")
    with pytest.raises(ValueError):
        api.set_default_policy("fastest")


def test_api_default_policy_roundtrip():
    assert api.get_default_policy() == "model"
    try:
        api.set_default_policy("tuned")
        assert api.get_default_policy() == "tuned"
    finally:
        api.set_default_policy("model")


# ---------------------------------------------------------------------------
# performance-guideline verification
# ---------------------------------------------------------------------------


def _synthetic_table(times):
    """One-bucket table with given {coll: {alg: t}} at bucket 10."""
    entries = {coll: {"10": {"best": min(t, key=t.get), "nbytes": 1024,
                             "times": dict(t)}}
               for coll, t in times.items()}
    return tuner.TunedTable(fingerprint="test:n8:rpp4", source="model",
                            entries=entries)


def test_guideline_composition_violation_fires():
    bad = _synthetic_table({
        "allreduce": {"ring_rs_ag": 10.0},
        "reduce_scatter": {"ring": 1.0},
        "allgather": {"ring": 1.0},
    })
    out = tuner.verify_guidelines(bad)
    assert any("allreduce>rs+ag" in v for v in out), out


def test_guideline_monotonicity_violation_fires():
    t = _synthetic_table({"allgather": {"ring": 5.0}})
    t.entries["allgather"]["14"] = {"best": "ring", "nbytes": 16384,
                                    "times": {"ring": 1.0}}
    out = tuner.verify_guidelines(t)
    assert any("non-monotone" in v for v in out), out


def test_guideline_specialized_violation_fires():
    bad = _synthetic_table({
        "alltoall": {"pairwise": 1.0, "hierarchical": 5.0},
    })
    out = tuner.verify_guidelines(bad, TOPO)
    assert any("hierarchical slower" in v for v in out), out


def test_guidelines_pass_on_consistent_table():
    good = _synthetic_table({
        "allreduce": {"ring_rs_ag": 1.5},
        "reduce_scatter": {"ring": 1.0},
        "allgather": {"ring": 1.0},
        "alltoall": {"pairwise": 1.0, "hierarchical": 0.8},
    })
    assert tuner.verify_guidelines(good, TOPO) == []


def test_violation_cells_name_offending_entries():
    t = _synthetic_table({"allgather": {"ring": 5.0}})
    t.entries["allgather"]["14"] = {"best": "ring", "nbytes": 16384,
                                    "times": {"ring": 1.0}}
    assert tuner.violation_cells(t) == [("allgather", "10"),
                                        ("allgather", "14")]
    bad = _synthetic_table({
        "allreduce": {"ring_rs_ag": 10.0},
        "reduce_scatter": {"ring": 1.0},
        "allgather": {"ring": 1.0},
    })
    assert set(tuner.violation_cells(bad)) == {
        ("allreduce", "10"), ("reduce_scatter", "10"), ("allgather", "10")}
    assert tuner.violation_cells(_synthetic_table(
        {"allgather": {"ring": 1.0}})) == []


# ---------------------------------------------------------------------------
# auto-retune on guideline violations (ensure_table heal path)
# ---------------------------------------------------------------------------


def _corrupt_cell(path, fp, coll, bucket, factor=99.0):
    """Scale every timing in one persisted cell so monotonicity breaks."""
    import json
    blob = json.loads(path.read_text())
    rec = blob[fp]["entries"][coll][bucket]
    rec["times"] = {k: v * factor for k, v in rec["times"].items()}
    rec["best"] = min(rec["times"], key=rec["times"].get)
    path.write_text(json.dumps(blob))
    tuner.clear_cache()


def test_ensure_table_heals_only_violated_cells(tmp_path):
    """Regression (ISSUE 3): a guideline violation injected into a
    cached table is healed by ``ensure_table`` without re-measuring
    untouched cells, and the persisted generation is bumped."""
    path = tmp_path / "tuned.json"
    table = tuner.tune(TOPO, sizes=SIZES, force_model=True)
    assert table.generation == 0
    pristine = copy.deepcopy(table.entries)
    tuner.save_table(table, path=path)

    lo = min(table.entries["allgather"], key=int)
    _corrupt_cell(path, table.fingerprint, "allgather", lo)

    loaded = tuner.load_table(table.fingerprint, path=path)
    cells = tuner.violation_cells(loaded, TOPO)
    # the corrupted bucket + its monotonicity partner (plus whatever
    # persistent findings the model already exhibits, e.g. alltoall
    # hierarchical-vs-pairwise at the largest bucket)
    assert ("allgather", lo) in cells

    calls = []
    real = tuner._modeled
    tuner._modeled = lambda s, t, nb: calls.append(nb) or real(s, t, nb)
    try:
        healed = tuner.ensure_table(TOPO, path=path, sizes=SIZES,
                                    force_model=True)
    finally:
        tuner._modeled = real

    # scoped: only the violated (collective, bucket) cells re-measured —
    # one _modeled call per candidate per violated cell, nowhere near
    # the full-tune count (len(COLLECTIVES) * len(SIZES) * candidates)
    expected = sum(len(tuner._candidates(coll, TOPO))
                   for coll, _ in cells)
    assert len(calls) == expected, (len(calls), expected, cells)

    # the corrupted cell is restored to the model values; every other
    # cell (including other allgather buckets) is byte-identical
    assert healed.entries == pristine
    assert healed.generation == 1
    assert healed.violations == table.violations

    # the bumped generation is persisted
    tuner.clear_cache()
    assert tuner.load_table(table.fingerprint, path=path).generation == 1


def test_ensure_table_heal_is_idempotent(tmp_path):
    """A violation the substrate genuinely exhibits (the model's
    alltoall finding at the largest bucket) re-confirms identically on
    every heal without changing the table or inflating the
    generation."""
    path = tmp_path / "tuned.json"
    table = tuner.tune(TOPO, sizes=SIZES, force_model=True)
    tuner.save_table(table, path=path)
    for _ in range(3):
        healed = tuner.ensure_table(TOPO, path=path, sizes=SIZES,
                                    force_model=True)
        assert healed.generation == 0
        assert healed.entries == table.entries


def test_ensure_table_tunes_once_when_missing(tmp_path):
    path = tmp_path / "tuned.json"
    t1 = tuner.ensure_table(TOPO, path=path, sizes=(1024,),
                            force_model=True)
    assert t1.generation == 0 and path.exists()
    calls = []
    real = tuner._modeled
    tuner._modeled = lambda s, t, nb: calls.append(nb) or real(s, t, nb)
    try:
        tuner.clear_cache()
        t2 = tuner.ensure_table(TOPO, path=path, sizes=(1024,),
                                force_model=True)
    finally:
        tuner._modeled = real
    assert t2.entries == t1.entries
    # loading a healthy persisted table measures nothing
    assert calls == []


def test_retune_cells_heals_neighbor_and_partitioned(tmp_path):
    """The scoped retune covers every tuned path, not just the dense
    collectives: corrupted neighbor / partitioned cells re-measure."""
    path = tmp_path / "tuned.json"
    table = tuner.autotune(TOPO, path=path, force_model=True,
                           sizes=(1 << 14,))
    for coll in (tuner.NEIGHBOR, tuner.PARTITIONED):
        bucket = next(iter(table.entries[coll]))
        good = copy.deepcopy(table.entries[coll][bucket])
        table.entries[coll][bucket]["times"] = {
            k: v * 97.0 for k, v in good["times"].items()}
        changed = tuner.retune_cells(table, TOPO, [(coll, bucket)],
                                     force_model=True)
        assert changed == [(coll, bucket)]
        assert table.entries[coll][bucket] == good, coll
    assert table.generation == 2


def test_heal_measures_newly_registered_algorithms(tmp_path):
    """A table tuned before an algorithm was registered (e.g. pre-staged
    releases) is stale, not healthy: healing re-measures the cells that
    never saw the newcomer so tuned selection can pick it."""
    path = tmp_path / "tuned.json"
    table = tuner.tune(TOPO, sizes=(1024,), force_model=True)
    for per in table.entries.values():      # simulate a pre-staged table
        for rec in per.values():
            rec["times"].pop("staged")
            rec["best"] = min(rec["times"], key=rec["times"].get)
    assert tuner.stale_cells(table, TOPO) == [
        (coll, "10") for coll in tuner.COLLECTIVES]
    tuner.save_table(table, path=path)
    tuner.clear_cache()
    healed = tuner.ensure_table(TOPO, path=path, sizes=(1024,),
                                force_model=True)
    assert healed.generation == 1
    for coll in tuner.COLLECTIVES:
        assert "staged" in healed.entries[coll]["10"]["times"], coll
    tuner.clear_cache()
    assert tuner.load_table(table.fingerprint, path=path).generation == 1


def test_cell_differs_tolerates_measurement_noise():
    rec = {"best": "ring", "nbytes": 1024,
           "times": {"ring": 1.0, "bruck": 2.0}}
    within = {"best": "ring", "nbytes": 1024,
              "times": {"ring": 1.05, "bruck": 1.95}}
    assert not tuner._cell_differs(within, rec, tol=1.10)
    beyond = {"best": "ring", "nbytes": 1024,
              "times": {"ring": 1.5, "bruck": 2.0}}
    assert tuner._cell_differs(beyond, rec, tol=1.10)
    flipped = {"best": "bruck", "nbytes": 1024,
               "times": {"ring": 1.05, "bruck": 0.98}}
    assert tuner._cell_differs(flipped, rec, tol=1.10)
    grew = {"best": "ring", "nbytes": 1024,
            "times": {"ring": 1.0, "bruck": 2.0, "staged": 3.0}}
    assert tuner._cell_differs(grew, rec, tol=1.10)


def test_api_ensure_tuned_sets_policy_and_reuses_table(tmp_path):
    path = tmp_path / "tuned.json"
    try:
        table = api.ensure_tuned(TOPO, path=path, sizes=(1024,),
                                 force_model=True)
        assert api.get_default_policy() == "tuned"
        assert table.fingerprint == tuner.substrate_fingerprint(
            TOPO, force_model=True)
    finally:
        api.set_default_policy("model")
    t2 = api.ensure_tuned(TOPO, path=path, sizes=(1024,),
                          force_model=True, set_policy=False)
    assert api.get_default_policy() == "model"     # set_policy=False
    assert t2.entries == table.entries             # loaded, not re-tuned


def test_table_generation_roundtrips(tmp_path, model_table):
    path = tmp_path / "tuned.json"
    bumped = copy.deepcopy(model_table)
    bumped.generation = 7
    tuner.save_table(bumped, path=path)
    tuner.clear_cache()
    assert tuner.load_table(bumped.fingerprint, path=path).generation == 7
    # tables persisted before the generation field default to 0
    legacy = tuner.TunedTable.from_dict(
        {"fingerprint": "cpu:n8:rpp4", "source": "model", "entries": {}})
    assert legacy.generation == 0
