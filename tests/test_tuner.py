"""Unit + property tests for the empirical selector (repro.core.tuner)
and the policy plumbing in selector/api."""
import copy

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed: seeded fallback
    from _hypothesis_stub import given, settings, st

from repro.core import api, selector, tuner
from repro.core.algorithms import REGISTRY
from repro.core.topology import Topology, flat_topology

TOPO = Topology(nranks=8, ranks_per_pod=4)
SIZES = (1 << 10, 1 << 14, 1 << 18, 1 << 22)


@pytest.fixture(autouse=True)
def _isolate_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path / "cache.json"))
    tuner.clear_cache()
    yield
    tuner.clear_cache()


@pytest.fixture(scope="module")
def model_table():
    return tuner.tune(TOPO, sizes=SIZES, force_model=True)


# ---------------------------------------------------------------------------
# buckets + fingerprints
# ---------------------------------------------------------------------------


def test_size_bucket_boundaries():
    assert tuner.size_bucket(1) == 0
    assert tuner.size_bucket(1024) == 10
    assert tuner.size_bucket(1025) == 11
    assert tuner.size_bucket(0) == 0      # degenerate payloads clamp


@settings(max_examples=30, deadline=None)
@given(a=st.integers(1, 1 << 30), b=st.integers(1, 1 << 30))
def test_size_bucket_monotone(a, b):
    lo, hi = min(a, b), max(a, b)
    assert tuner.size_bucket(lo) <= tuner.size_bucket(hi)


def test_fingerprint_distinguishes_topologies():
    fps = {Topology(8, 4).fingerprint("cpu"),
           Topology(8, 8).fingerprint("cpu"),
           Topology(16, 4).fingerprint("cpu"),
           Topology(8, 4).fingerprint("TPU v5e")}
    assert len(fps) == 4
    assert Topology(8, 4).fingerprint("TPU v5e") == "TPU_v5e:n8:rpp4"


# ---------------------------------------------------------------------------
# table round-trip through the JSON cache
# ---------------------------------------------------------------------------


def test_table_roundtrip(tmp_path, model_table):
    path = tmp_path / "tuned.json"
    tuner.save_table(model_table, path=path)
    tuner.clear_cache()
    loaded = tuner.load_table(model_table.fingerprint, path=path)
    assert loaded is not None
    assert loaded.fingerprint == model_table.fingerprint
    assert loaded.source == "model"
    assert loaded.entries == model_table.entries
    assert loaded.violations == model_table.violations


def test_save_merges_fingerprints(tmp_path):
    path = tmp_path / "tuned.json"
    t1 = tuner.tune(TOPO, sizes=(1024,), force_model=True)
    t2 = tuner.tune(flat_topology(16), sizes=(1024,), force_model=True)
    tuner.save_table(t1, path=path)
    tuner.save_table(t2, path=path)
    tuner.clear_cache()
    assert tuner.load_table(t1.fingerprint, path=path) is not None
    assert tuner.load_table(t2.fingerprint, path=path) is not None


def test_load_missing_and_corrupt(tmp_path):
    assert tuner.load_table("cpu:n8:rpp4", path=tmp_path / "nope.json") \
        is None
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert tuner.load_table("cpu:n8:rpp4", path=bad) is None


# ---------------------------------------------------------------------------
# tuned selection semantics
# ---------------------------------------------------------------------------


def test_tuned_never_worse_than_fixed(model_table):
    """The tuned winner's probed time never exceeds the fixed default's
    for the same bucket (argmin over a candidate set containing it)."""
    for coll in tuner.COLLECTIVES:
        for nbytes in SIZES:
            fixed = selector.select(coll, TOPO, nbytes, policy="fixed")
            tuned = selector.select(coll, TOPO, nbytes, policy="tuned",
                                    tuned_table=model_table)
            t_tuned = model_table.time_of(coll, nbytes, tuned)
            t_fixed = model_table.time_of(coll, nbytes, fixed)
            assert t_fixed is not None, (coll, nbytes, fixed)
            assert t_tuned <= t_fixed, (coll, nbytes, tuned, fixed)


def test_tuned_winners_are_executable(model_table):
    for coll, per in model_table.entries.items():
        for rec in per.values():
            name = rec["best"]
            assert name == "xla" or name in REGISTRY[coll]
            if name != "xla":
                REGISTRY[coll][name](TOPO)   # builds without assertion


def test_lookup_nearest_bucket(model_table):
    per = model_table.entries["allgather"]
    lo_bucket = min(per, key=int)
    # far below every probed size -> clamps to the smallest bucket
    assert model_table.lookup("allgather", 1) == per[lo_bucket]["best"]
    hi_bucket = max(per, key=int)
    assert model_table.lookup("allgather", 1 << 40) \
        == per[hi_bucket]["best"]
    assert model_table.lookup("not_a_collective", 1024) is None


@settings(max_examples=30, deadline=None)
@given(nbytes=st.integers(1, 1 << 28),
       coll=st.sampled_from(list(tuner.COLLECTIVES)))
def test_tuned_select_total(model_table, coll, nbytes):
    """policy="tuned" always returns a runnable algorithm name."""
    name = tuner.tuned_select(coll, TOPO, nbytes, table=model_table)
    assert name is not None
    assert name == "xla" or name in REGISTRY[coll]


def test_stale_table_entry_falls_back_to_model(model_table):
    stale = copy.deepcopy(model_table)
    for per in stale.entries.values():
        for rec in per.values():
            rec["best"] = "algorithm_deleted_in_v2"
    got = selector.select("allgather", TOPO, 1024, policy="tuned",
                          tuned_table=stale)
    assert got == selector.select("allgather", TOPO, 1024, policy="model")


def test_tuned_without_table_matches_model():
    topo = Topology(nranks=12, ranks_per_pod=3)   # never tuned
    for coll in tuner.COLLECTIVES:
        assert selector.select(coll, topo, 4096, policy="tuned") \
            == selector.select(coll, topo, 4096, policy="model")


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        selector.select("allgather", TOPO, 1024, policy="fastest")
    with pytest.raises(ValueError):
        api.set_default_policy("fastest")


def test_api_default_policy_roundtrip():
    assert api.get_default_policy() == "model"
    try:
        api.set_default_policy("tuned")
        assert api.get_default_policy() == "tuned"
    finally:
        api.set_default_policy("model")


# ---------------------------------------------------------------------------
# performance-guideline verification
# ---------------------------------------------------------------------------


def _synthetic_table(times):
    """One-bucket table with given {coll: {alg: t}} at bucket 10."""
    entries = {coll: {"10": {"best": min(t, key=t.get), "nbytes": 1024,
                             "times": dict(t)}}
               for coll, t in times.items()}
    return tuner.TunedTable(fingerprint="test:n8:rpp4", source="model",
                            entries=entries)


def test_guideline_composition_violation_fires():
    bad = _synthetic_table({
        "allreduce": {"ring_rs_ag": 10.0},
        "reduce_scatter": {"ring": 1.0},
        "allgather": {"ring": 1.0},
    })
    out = tuner.verify_guidelines(bad)
    assert any("allreduce>rs+ag" in v for v in out), out


def test_guideline_monotonicity_violation_fires():
    t = _synthetic_table({"allgather": {"ring": 5.0}})
    t.entries["allgather"]["14"] = {"best": "ring", "nbytes": 16384,
                                    "times": {"ring": 1.0}}
    out = tuner.verify_guidelines(t)
    assert any("non-monotone" in v for v in out), out


def test_guideline_specialized_violation_fires():
    bad = _synthetic_table({
        "alltoall": {"pairwise": 1.0, "hierarchical": 5.0},
    })
    out = tuner.verify_guidelines(bad, TOPO)
    assert any("hierarchical slower" in v for v in out), out


def test_guidelines_pass_on_consistent_table():
    good = _synthetic_table({
        "allreduce": {"ring_rs_ag": 1.5},
        "reduce_scatter": {"ring": 1.0},
        "allgather": {"ring": 1.0},
        "alltoall": {"pairwise": 1.0, "hierarchical": 0.8},
    })
    assert tuner.verify_guidelines(good, TOPO) == []
