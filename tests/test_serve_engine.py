"""Continuous-batching engine: state-machine invariants, KV-transfer
bit-exactness vs the gather oracle, and chaos under load.

Shardmap/pallas transport bit-exactness for the transfer plans runs in
the 8-device subprocess script (tests/device_scripts/check_serve.py,
registered in test_shardmap.py); this module covers everything that is
exact on the host sim substrate."""
import numpy as np
import pytest

from repro.core import chaos, kvtransfer
from repro.core.resilient import UnrecoverableError
from repro.core.topology import Topology
from repro.core.transport import SimTransport
from repro.serve.engine import (BlockPool, ContinuousBatchingEngine,
                                DoubleFreeError, EngineConfig, EngineStall,
                                Request, TransferVerificationError)
from repro.serve.traffic import poisson_workload, run_workload

SMALL = dict(prefill_ranks=2, decode_ranks=2, ranks_per_pod=2,
             blocks_per_rank=16, block_tokens=4, block_feat=8)


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------


class TestBlockPool:
    def test_alloc_free_roundtrip(self):
        p = BlockPool(8)
        a = p.alloc(3)
        b = p.alloc(5)
        assert sorted(a + b) == list(range(8))
        assert p.available == 0 and p.in_use == 8
        p.free(a)
        p.free(b)
        assert p.available == 8 and p.in_use == 0

    def test_exhaustion_returns_none(self):
        p = BlockPool(4)
        assert p.alloc(5) is None           # too big outright
        a = p.alloc(3)
        assert a is not None and p.alloc(2) is None
        assert p.available == 1             # failed alloc takes nothing

    def test_double_free_raises(self):
        p = BlockPool(4)
        a = p.alloc(2)
        p.free(a)
        with pytest.raises(DoubleFreeError):
            p.free(a)

    def test_free_never_allocated_raises(self):
        p = BlockPool(4)
        p.alloc(1)
        with pytest.raises(DoubleFreeError):
            p.free([3])


# ---------------------------------------------------------------------------
# transfer plans: ragged IR vs the gather oracle
# ---------------------------------------------------------------------------


def _random_moves(rng, topo, blocks_per_rank, n_moves, *,
                  src_ranks, dst_ranks, shared_frac=0.3):
    """Random valid move batch; ``shared_frac`` makes some source
    blocks fan out to several destinations (the dedupe case)."""
    moves, dst_used = [], set()
    shared = [(int(rng.integers(len(src_ranks))),
               int(rng.integers(blocks_per_rank)))
              for _ in range(max(1, blocks_per_rank // 4))]
    while len(moves) < n_moves:
        if rng.random() < shared_frac:
            si, row = shared[int(rng.integers(len(shared)))]
            s = src_ranks[si]
        else:
            s = src_ranks[int(rng.integers(len(src_ranks)))]
            row = int(rng.integers(blocks_per_rank))
        d = dst_ranks[int(rng.integers(len(dst_ranks)))]
        dr = int(rng.integers(blocks_per_rank))
        if (d, dr) in dst_used:
            continue
        dst_used.add((d, dr))
        moves.append(kvtransfer.BlockMove(s, row, d, dr))
    return moves


class TestTransferPlan:
    @pytest.mark.parametrize("aggregate", [False, True, None])
    @pytest.mark.parametrize("transport", ["sim", "reference"])
    def test_bit_exact_vs_oracle(self, aggregate, transport):
        rng = np.random.default_rng(0)
        topo = Topology(8, 4)
        B = 12
        pool = rng.normal(size=(8, B, 3, 2)).astype(np.float32)
        for trial in range(3):
            moves = _random_moves(rng, topo, B, 10 + 5 * trial,
                                  src_ranks=range(4),
                                  dst_ranks=range(4, 8))
            tp = kvtransfer.build_transfer_plan(
                moves, topo, blocks_per_rank=B, aggregate=aggregate,
                block_bytes=24)
            res = kvtransfer.run_transfer(tp, pool, transport=transport)
            assert kvtransfer.verify_bitwise(tp, pool, res), \
                (aggregate, transport, trial)

    def test_landing_mode_independent(self):
        """Both plan modes land every block on the same dst rows with
        the same bytes (the recv-layout interchangeability claim)."""
        rng = np.random.default_rng(1)
        topo = Topology(8, 4)
        pool = rng.normal(size=(8, 8, 2, 2)).astype(np.float32)
        moves = _random_moves(rng, topo, 8, 12, src_ranks=range(4),
                              dst_ranks=range(4, 8))
        outs = []
        for agg in (False, True):
            tp = kvtransfer.build_transfer_plan(
                moves, topo, blocks_per_rank=8, aggregate=agg,
                block_bytes=16)
            res = kvtransfer.run_transfer(tp, pool)
            outs.append({d: (r.tobytes(), v.tobytes())
                         for d, (r, v) in res.updates.items()})
        assert outs[0] == outs[1]

    def test_shared_prefix_dedupe(self):
        """One source block fanned to every decode rank: the
        locality-aware plan ships it over DCN once per pod pair."""
        topo = Topology(8, 4)
        moves = [kvtransfer.BlockMove(0, r, d, r)
                 for d in range(4, 8) for r in range(4)]
        std = kvtransfer.build_transfer_plan(
            moves, topo, blocks_per_rank=8, aggregate=False,
            block_bytes=64)
        agg = kvtransfer.build_transfer_plan(
            moves, topo, blocks_per_rank=8, aggregate=True,
            block_bytes=64)
        assert agg.traffic()["dcn"] < std.traffic()["dcn"]
        assert agg.traffic()["msgs_dcn"] < std.traffic()["msgs_dcn"]

    def test_invalid_moves_rejected(self):
        topo = Topology(4, 2)
        mk = kvtransfer.BlockMove
        with pytest.raises(ValueError, match="empty"):
            kvtransfer.build_transfer_plan([], topo, blocks_per_rank=4)
        with pytest.raises(ValueError, match="one rank"):
            kvtransfer.build_transfer_plan(
                [mk(1, 0, 1, 1)], topo, blocks_per_rank=4)
        with pytest.raises(ValueError, match="outside pool"):
            kvtransfer.build_transfer_plan(
                [mk(0, 7, 2, 0)], topo, blocks_per_rank=4)
        with pytest.raises(ValueError, match="land on dst row"):
            kvtransfer.build_transfer_plan(
                [mk(0, 0, 2, 1), mk(1, 3, 2, 1)], topo,
                blocks_per_rank=4)

    def test_resilient_transfer_reports(self):
        rng = np.random.default_rng(2)
        topo = Topology(4, 2)
        pool = rng.normal(size=(4, 6, 2, 2)).astype(np.float32)
        moves = _random_moves(rng, topo, 6, 6, src_ranks=range(2),
                              dst_ranks=range(2, 4))
        tp = kvtransfer.build_transfer_plan(
            moves, topo, blocks_per_rank=6, block_bytes=16)
        res = kvtransfer.run_transfer(
            tp, pool, resilience={"verify": "full",
                                  "ladder": ("sim", "reference"),
                                  "backoff_s": 1e-5})
        assert res.report is not None and not res.report.degraded
        assert kvtransfer.verify_bitwise(tp, pool, res)


# ---------------------------------------------------------------------------
# engine state machine
# ---------------------------------------------------------------------------


class TestEngine:
    def test_trace_drains_and_pools_free(self):
        eng = ContinuousBatchingEngine(EngineConfig(**SMALL))
        trace = poisson_workload(0, arrival_rate=8.0, tenants=2,
                                 n_requests=24, mean_prompt=10,
                                 mean_gen=5, max_prompt=24)
        m = run_workload(eng, trace)
        assert m["completed"] == m["submitted"] == 24
        assert all(p.in_use == 0 for p in eng.pools.values())
        assert m["tokens"] == sum(r.gen_len for r in eng.done)
        assert m["kv_transfer"]["plans"] >= 1
        assert m["kv_transfer"]["bytes"] > 0

    def test_fifo_admission_no_starvation(self):
        """Admission follows arrival order exactly (head-of-line):
        an early long request is never starved by later short ones."""
        eng = ContinuousBatchingEngine(EngineConfig(**SMALL))
        reqs = [Request(rid=0, tenant=0, prompt_len=40, gen_len=4,
                        arrival=0.0)]
        reqs += [Request(rid=i, tenant=1, prompt_len=4, gen_len=2,
                         arrival=0.01 * i) for i in range(1, 16)]
        m = run_workload(eng, reqs, dt=1.0)
        assert m["completed"] == 16
        by_arrival = sorted(eng.done, key=lambda r: (r.arrival, r.rid))
        admitted = [r.admitted_step for r in by_arrival]
        assert admitted == sorted(admitted), admitted

    def test_eviction_on_decode_oom(self):
        """A decode pool that fits two requests serving three: the
        youngest decoding request is preempted back to WAITING and
        everything still completes."""
        cfg = EngineConfig(prefill_ranks=2, decode_ranks=2,
                           ranks_per_pod=2, blocks_per_rank=2,
                           block_tokens=4, block_feat=4)
        eng = ContinuousBatchingEngine(cfg)
        reqs = [Request(rid=i, tenant=0, prompt_len=8, gen_len=12,
                        arrival=0.0) for i in range(3)]
        m = run_workload(eng, reqs, dt=1.0)
        assert m["completed"] == 3
        assert m["preemptions"] >= 1
        assert all(p.in_use == 0 for p in eng.pools.values())

    def test_eviction_requeues_in_arrival_order(self):
        cfg = EngineConfig(prefill_ranks=2, decode_ranks=2,
                           ranks_per_pod=2, blocks_per_rank=2,
                           block_tokens=4, block_feat=4)
        eng = ContinuousBatchingEngine(cfg)
        for i in range(3):
            eng.submit(Request(rid=i, tenant=0, prompt_len=8,
                               gen_len=12, arrival=float(i)))
        while eng.preemptions == 0 and eng.pending:
            eng.step()
        assert eng.preemptions >= 1
        victims = [r for r in eng.waiting if r.preemptions > 0]
        assert victims, "preempted request must re-enter the queue"
        arrivals = [r.arrival for r in eng.waiting]
        assert arrivals == sorted(arrivals)

    def test_oversized_request_stalls_typed(self):
        """A request that can never fit the decode pool ends in a typed
        EngineStall, not an infinite loop."""
        cfg = EngineConfig(prefill_ranks=2, decode_ranks=2,
                           ranks_per_pod=2, blocks_per_rank=4,
                           block_tokens=4, block_feat=4)
        eng = ContinuousBatchingEngine(cfg)
        eng.submit(Request(rid=0, tenant=0, prompt_len=64, gen_len=4,
                           arrival=0.0))
        with pytest.raises(EngineStall):
            eng.run(max_steps=64)

    def test_transfer_corruption_is_typed(self, monkeypatch):
        """A transport that lies about the payload must surface as a
        typed TransferVerificationError, never a silent cache."""
        real = kvtransfer.run_transfer

        def corrupting(tp, pool, **kw):
            res = real(tp, pool, **kw)
            for d, (rows, vals) in res.updates.items():
                vals = vals.copy()
                vals.flat[0] += 1.0
                res.updates[d] = (rows, vals)
                break
            return res

        monkeypatch.setattr(kvtransfer, "run_transfer", corrupting)
        eng = ContinuousBatchingEngine(EngineConfig(**SMALL))
        eng.submit(Request(rid=0, tenant=0, prompt_len=4, gen_len=2,
                           arrival=0.0))
        with pytest.raises(TransferVerificationError):
            eng.run(max_steps=16)

    def test_multi_tenant_metrics(self):
        eng = ContinuousBatchingEngine(EngineConfig(**SMALL))
        trace = poisson_workload(3, arrival_rate=6.0, tenants=3,
                                 n_requests=18, max_prompt=24)
        assert len({r.tenant for r in trace}) >= 2
        m = run_workload(eng, trace)
        assert m["completed"] == 18
        assert m["tokens_per_step"] > 0
        assert m["ttft_steps"]["p99"] >= m["ttft_steps"]["p50"] >= 0
        assert m["kv_transfer"]["dcn_bytes"] > 0   # pools cross pods


# ---------------------------------------------------------------------------
# chaos under load
# ---------------------------------------------------------------------------


class TestChaosUnderLoad:
    def _engine(self, plan, *, ladder=("sim", "reference"),
                wrap_reference=False):
        n = EngineConfig(**SMALL).topology().nranks
        transports = {"sim": chaos.wrap(SimTransport(n), plan)}
        if wrap_reference:
            transports["reference"] = chaos.wrap(SimTransport(n), plan)
        cfg = EngineConfig(**SMALL, resilience={
            "verify": "full", "ladder": ladder, "backoff_s": 1e-5})
        return ContinuousBatchingEngine(cfg, transports=transports)

    @pytest.mark.parametrize("campaign", ["corrupt", "fail", "mixed"])
    def test_faulted_decode_recovers_bitwise(self, campaign):
        """FaultPlan armed while the trace decodes: transfers degrade
        through the ladder and still land bitwise (the engine's oracle
        check runs on the ladder's output)."""
        plan = chaos.FaultPlan(0, campaign, times=1, delay_s=1e-4)
        eng = self._engine(plan)
        trace = poisson_workload(0, arrival_rate=8.0, tenants=2,
                                 n_requests=12, max_prompt=24)
        m = run_workload(eng, trace)
        assert m["completed"] == 12
        assert len(eng.degradations) == m["kv_transfer"]["plans"]
        degraded = sum(1 for r in eng.degradations if r.degraded)
        assert degraded >= 1, (
            f"campaign {campaign} never fired across "
            f"{len(eng.degradations)} transfer plans")
        assert all(p.in_use == 0 for p in eng.pools.values())

    def test_persistent_fault_raises_typed(self):
        """Every rung persistently faulted: the engine surfaces the
        typed UnrecoverableError instead of looping or corrupting."""
        plan = chaos.FaultPlan(0, "fail", times=None)
        eng = self._engine(plan, wrap_reference=True)
        eng.submit(Request(rid=0, tenant=0, prompt_len=4, gen_len=2,
                           arrival=0.0))
        with pytest.raises(UnrecoverableError):
            eng.run(max_steps=16)
