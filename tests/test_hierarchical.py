"""Cross-level conformance suite for the hierarchy-staged builders.

Proves the staged (3+-level) algorithm builders correct and profitable:

  * every registered dense schedule — including the ``staged`` family —
    is bit-exact against its flat reference on a 3-level topology
    (2 pods x 4x2 torus) via SimTransport; the ShardMapTransport half
    runs on forced host devices in device_scripts/check_hierarchical.py
    (plus the 3-level case added to check_unified_ir.py);
  * property tests over random level stacks (1-4 levels) check the
    staged decomposition engine on arbitrary geometries;
  * on the canonical 2-level hierarchy the staged allreduce /
    reduce-scatter reproduce the ``hierarchical`` builders
    round-for-round (the engine generalizes, not forks, them);
  * staged allreduce/alltoall beat their flat counterparts in modeled
    time on the 3-level torus, and their DCN traffic meets the same
    minimality bounds as the 2-level locality-aware algorithms;
  * ``Topology.from_fingerprint`` round-trips random level stacks with
    non-default link models.
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed: seeded fallback
    from _hypothesis_stub import given, settings, st

from repro.core import selector, tuner
from repro.core.algorithms import REGISTRY, allreduce, reduce_scatter, staged
from repro.core.schedule import NotApplicable
from repro.core.topology import (DCN_LINK, ICI_LINK, LinkModel, TopoLevel,
                                 Topology, flat_topology, torus_topology)
from repro.core.transport import SimTransport

from test_shardmap import run_script

TOPO3 = torus_topology(2, 4, 2)     # 2 pods x (4x2 torus) = 16 ranks
FLAT = {"allgather": "ring", "allreduce": "ring_rs_ag",
        "reduce_scatter": "ring", "alltoall": "pairwise"}


def _int_data(n, rng, lo=-8, hi=8):
    """Integer-valued floats: sums of <= n of these are exact in f32 for
    any association order, so reduce outputs are bit-comparable across
    algorithms with different reduction trees."""
    return rng.integers(lo, hi, (n, n, 3)).astype(np.float32)


def _run(topo, coll, name, buf):
    sched = REGISTRY[coll][name](topo)
    if sched.num_slots > buf.shape[1]:  # separate recv region (pairwise)
        pad = np.zeros((buf.shape[0], sched.num_slots - buf.shape[1])
                       + buf.shape[2:], buf.dtype)
        buf = np.concatenate([buf, pad], axis=1)
    out = SimTransport(topo.nranks).run(sched, buf)
    return out[:, : sched.result_slots]


def _oracle_io(coll, topo, rng):
    """(input buffer, expected output) for one dense collective."""
    n = topo.nranks
    data = _int_data(n, rng)
    if coll == "allgather":
        contrib = data[:, 0]
        buf = np.zeros((n, n, 3), np.float32)
        for r in range(n):
            buf[r, r] = contrib[r]
        return buf, np.broadcast_to(contrib, (n, n, 3))
    if coll == "allreduce":
        return data, np.broadcast_to(data.sum(0), (n, n, 3))
    if coll == "reduce_scatter":
        return data, data.sum(0)       # compared at [r, r] only
    if coll == "alltoall":
        return data, np.swapaxes(data, 0, 1)
    raise AssertionError(coll)


# ---------------------------------------------------------------------------
# the staged decomposition engine
# ---------------------------------------------------------------------------


def test_level_groups_partition_ranks():
    for lvl in range(len(TOPO3.levels)):
        groups = staged.level_groups(TOPO3, lvl)
        flat = sorted(r for g in groups for r in g)
        assert flat == list(range(TOPO3.nranks))
        for g in groups:
            assert len(g) == TOPO3.levels[lvl].size
            # members differ only in the level-lvl coordinate, in order
            coords = [TOPO3.coords(r) for r in g]
            assert [c[lvl] for c in coords] == list(range(len(g)))
            for c in coords:
                assert c[:lvl] == coords[0][:lvl]
                assert c[lvl + 1:] == coords[0][lvl + 1:]


def test_owned_blocks_formula():
    # fixing every level (lvl=0) collapses to the rank's own block;
    # an empty tail (lvl=len(levels)) matches every block
    k = len(TOPO3.levels)               # (dcn-2, torus_y-4, torus_x-2)
    for r in range(TOPO3.nranks):
        assert staged._owned_blocks(TOPO3, r, 0) == [r]
        assert staged._owned_blocks(TOPO3, r, k) == list(range(TOPO3.nranks))
    # rank 0 = coords (0, 0, 0): innermost-stage set fixes only the x
    # coordinate; the next stage up additionally fixes y
    assert staged._owned_blocks(TOPO3, 0, 2) == [0, 2, 4, 6, 8, 10, 12, 14]
    assert staged._owned_blocks(TOPO3, 0, 1) == [0, 8]


@pytest.mark.parametrize("coll", sorted(FLAT))
def test_every_registered_schedule_matches_flat_reference_on_3level(coll):
    """Acceptance: on 2 pods x 4x2 every registered algorithm — staged
    included — is bit-exact vs the flat reference (and the oracle)."""
    rng = np.random.default_rng(0)
    buf, want = _oracle_io(coll, TOPO3, rng)
    ref = _run(TOPO3, coll, FLAT[coll], buf)
    for name, builder in REGISTRY[coll].items():
        try:
            builder(TOPO3)
        except NotApplicable:
            continue
        got = _run(TOPO3, coll, name, buf)
        if coll == "reduce_scatter":
            for r in range(TOPO3.nranks):
                assert np.array_equal(got[r, r], want[r]), (name, r)
                assert np.array_equal(got[r, r], ref[r, r]), (name, r)
        else:
            assert np.array_equal(got, want), name
            assert np.array_equal(got, ref), name


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_staged_builders_on_random_level_stacks(seed):
    """The axis-decomposition engine is geometry-agnostic: correct on
    random 1-4 level stacks (degenerate axes of size 1 included)."""
    rng = np.random.default_rng(seed)
    naxes = int(rng.integers(1, 4))
    sizes = [int(rng.integers(1, 4)) for _ in range(naxes)]
    topo = torus_topology(int(rng.integers(1, 4)), *sizes)
    n = topo.nranks
    if n == 1:
        return
    for coll in sorted(FLAT):
        buf, want = _oracle_io(coll, topo, rng)
        got = _run(topo, coll, "staged", buf)
        if coll == "reduce_scatter":
            for r in range(n):
                assert np.array_equal(got[r, r], want[r]), (coll, r, topo)
        else:
            assert np.array_equal(got, want), (coll, topo)


@pytest.mark.parametrize("pair", [
    (staged.allreduce_staged, allreduce.hierarchical),
    (staged.reduce_scatter_staged, reduce_scatter.hierarchical),
])
def test_staged_reproduces_hierarchical_on_two_levels(pair):
    """On the canonical DCN-over-ICI split the staged engine emits the
    2-level hierarchical schedules round-for-round."""
    build_staged, build_hier = pair
    topo = Topology(8, 4)
    a, b = build_staged(topo), build_hier(topo)
    assert len(a.rounds) == len(b.rounds)
    for ra, rb in zip(a.rounds, b.rounds):
        assert ra.perm == rb.perm
        assert np.array_equal(ra.gather_idx, rb.gather_idx)
        assert np.array_equal(ra.scatter_idx, rb.scatter_idx)
        assert ra.reduce == rb.reduce


def test_staged_degenerates_to_flat_on_one_level():
    topo = flat_topology(6)
    rng = np.random.default_rng(1)
    for coll in sorted(FLAT):
        buf, want = _oracle_io(coll, topo, rng)
        got = _run(topo, coll, "staged", buf)
        ref = _run(topo, coll, FLAT[coll], buf)
        if coll == "reduce_scatter":
            for r in range(6):
                assert np.array_equal(got[r, r], ref[r, r])
        else:
            assert np.array_equal(got, ref)


# ---------------------------------------------------------------------------
# profitability: modeled time + per-link-class traffic (acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nbytes", [1 << 10, 1 << 16, 1 << 22])
def test_staged_beats_flat_in_modeled_time_on_3level(nbytes):
    """Acceptance: staged allreduce/alltoall beat their flat
    counterparts in modeled time on 2 pods x 4x2 (all probed sizes)."""
    n = TOPO3.nranks
    for coll in ("allreduce", "alltoall"):
        block = max(1, nbytes // n)
        t_staged = REGISTRY[coll]["staged"](TOPO3).modeled_time(TOPO3, block)
        t_flat = REGISTRY[coll][FLAT[coll]](TOPO3).modeled_time(TOPO3, block)
        assert t_staged < t_flat, (coll, nbytes, t_staged, t_flat)
    for coll in ("allgather", "reduce_scatter"):
        block = max(1, nbytes // n)
        t_staged = REGISTRY[coll]["staged"](TOPO3).modeled_time(TOPO3, block)
        t_flat = REGISTRY[coll][FLAT[coll]](TOPO3).modeled_time(TOPO3, block)
        assert t_staged <= t_flat, (coll, nbytes, t_staged, t_flat)


def test_staged_dcn_traffic_minimal():
    """Staged schedules meet the 2-level locality-aware DCN bounds on a
    3-level torus: each block crosses the DCN once per remote pod, and
    alltoall DCN messages drop from R^2 to R per pod-pair."""
    n, R, Q = TOPO3.nranks, TOPO3.ranks_per_pod, TOPO3.npods
    ag = REGISTRY["allgather"]["staged"](TOPO3)
    assert ag.byte_count(1, TOPO3, local=False) == n * (Q - 1)
    rs = REGISTRY["reduce_scatter"]["staged"](TOPO3)
    assert rs.byte_count(1, TOPO3, local=False) == n * (Q - 1)
    a2a = REGISTRY["alltoall"]["staged"](TOPO3)
    pairwise = REGISTRY["alltoall"]["pairwise"](TOPO3)
    assert a2a.message_count(TOPO3, local=False) == R * Q * (Q - 1)
    assert pairwise.message_count(TOPO3, local=False) == R * R * Q * (Q - 1)
    # bytes crossing the DCN are identical (aggregation cuts messages)
    assert a2a.byte_count(1, TOPO3, local=False) \
        == pairwise.byte_count(1, TOPO3, local=False)


def test_staged_allreduce_dcn_rounds_scale_with_pods_only():
    sched = REGISTRY["allreduce"]["staged"](TOPO3)
    dcn_rounds = sum(
        1 for rnd in sched.rounds
        if any(not TOPO3.is_local(s, d) for s, d in rnd.perm))
    assert dcn_rounds == 2 * (TOPO3.npods - 1)


# ---------------------------------------------------------------------------
# selection + tuner pickup
# ---------------------------------------------------------------------------


def test_fixed_policy_selects_staged_on_3plus_levels():
    for coll in sorted(FLAT):
        assert selector.select(coll, TOPO3, 1 << 20,
                               policy="fixed") == "staged"
        # 2-level and flat topologies keep the historical defaults
        assert selector.select(coll, Topology(8, 4),
                               1 << 20, policy="fixed") != "staged"
        # single-pod multi-axis tori too: with no DCN level to avoid,
        # staged store-and-forward only adds ICI bytes
        assert selector.select(coll, torus_topology(1, 4, 4, 4),
                               1 << 20, policy="fixed") != "staged"


def test_model_policy_includes_staged_candidates():
    times = selector.modeled_times("allreduce", TOPO3, 1 << 20)
    assert "staged" in times
    name = selector.select("allreduce", TOPO3, 1 << 20, policy="model")
    assert times[name] == min(times.values())


def test_staged_guideline_violation_fires_and_names_cells():
    entries = {"alltoall": {"20": {
        "best": "pairwise", "nbytes": 1 << 20,
        "times": {"pairwise": 1.0, "staged": 5.0}}}}
    table = tuner.TunedTable(
        fingerprint=TOPO3.fingerprint(), source="model", entries=entries)
    out = tuner.verify_guidelines(table, TOPO3)
    assert any("staged slower" in v for v in out), out
    assert ("alltoall", "20") in tuner.violation_cells(table, TOPO3)
    # ...and does not fire on 2-level topologies (no staged advantage)
    assert tuner.verify_guidelines(table, Topology(8, 4)) == []


def test_tuner_covers_staged_on_3level(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path / "cache.json"))
    tuner.clear_cache()
    table = tuner.tune(TOPO3, sizes=(1 << 14,), force_model=True)
    for coll in tuner.COLLECTIVES:
        rec = next(iter(table.entries[coll].values()))
        assert "staged" in rec["times"], coll


# ---------------------------------------------------------------------------
# fingerprint round-trip over random level stacks (non-default links)
# ---------------------------------------------------------------------------


_ALPHAS = (1e-6, 2.5e-6, 1e-5, 3.3e-5)
_BETAS = (1 / 25e9, 1 / 50e9, 1 / 12.5e9, 7.7e-11)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_fingerprint_roundtrip_random_levels_and_links(seed):
    rng = np.random.default_rng(seed)
    levels = []
    for i in range(int(rng.integers(1, 5))):
        custom = bool(rng.integers(0, 2))
        link = (LinkModel(alpha=float(_ALPHAS[rng.integers(4)]),
                          beta=float(_BETAS[rng.integers(4)]))
                if custom else None)
        levels.append((f"ax{i}", int(rng.integers(1, 5)), link))
    ndcn = int(rng.integers(0, len(levels) + 1))
    lvls = [TopoLevel(name, size,
                      link or (DCN_LINK if i < ndcn else ICI_LINK),
                      dcn=i < ndcn)
            for i, (name, size, link) in enumerate(levels)]
    topo = Topology.from_levels(lvls)
    for kind in ("model", "cpu", "TPU v5e"):
        back = Topology.from_fingerprint(topo.fingerprint(kind))
        assert back == topo, (topo.fingerprint(kind), back, topo)
        assert back.fingerprint(kind) == topo.fingerprint(kind)


def test_fingerprint_custom_link_has_lm_section():
    t = Topology.from_levels([
        TopoLevel("dcn", 2, LinkModel(alpha=2e-5, beta=1e-10), dcn=True),
        TopoLevel("x", 4, ICI_LINK)])
    fp = t.fingerprint("cpu")
    assert ":lm[" in fp and "2e-05" in fp
    assert Topology.from_fingerprint(fp) == t
    # default-link stacks keep the compact historical form
    assert ":lm[" not in torus_topology(2, 4, 4).fingerprint()
    with pytest.raises(ValueError):
        Topology.from_fingerprint("cpu:n8:rpp4:lm[0=1.0/1.0/1]")
    with pytest.raises(ValueError, match="out of range"):
        Topology.from_fingerprint("cpu:n8:rpp4:lv[a-2.b-4]:lm[7=1.0/1.0/1]")


# ---------------------------------------------------------------------------
# ShardMapTransport half (forced host devices, subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_hierarchical_shardmap_conformance():
    """Sim == ShardMap for every registered schedule + neighbor plans on
    the 3-level 2-pods x 4x2 torus, and staged == flat reference on the
    device path (16 forced host devices)."""
    out = run_script("check_hierarchical.py")
    assert "ALL OK" in out
