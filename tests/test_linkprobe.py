"""Wire-measurement pass: probe schedules, fits, measured fingerprints.

The probe pass feeds persisted tuned tables and every downstream cost
model, so these tests pin the full contract:

  * probe schedules are legal IR (validated like any collective's);
  * ``fit_link_model`` recovers exact coefficients from model-priced
    samples and fails loud on degenerate data — and ``LinkModel`` itself
    rejects non-finite/negative coefficients no matter who builds it;
  * ``measured_topology`` keys the geometry by measurement: the
    fingerprint grows an ``lm[...]`` section that round-trips, including
    under sanitized device kinds ("TPU v5e");
  * ``drifted_levels`` is noise-tolerant (ratio rule) and refuses to
    compare unlike geometries.
"""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed: seeded fallback
    from _hypothesis_stub import given, settings, st

from repro.core import linkprobe
from repro.core.linkprobe import (
    DEFAULT_PROBE_SIZES, drifted_levels, fit_link_model,
    injection_schedule, measured_topology, model_timer, pingpong_schedule,
    probe_links)
from repro.core.topology import (DCN_LINK, ICI_LINK, LinkModel, TopoLevel,
                                 Topology, torus_topology)
from repro.core.transport import SimTransport
from repro.runtime.fault import LinkFault

TOPO = Topology.from_levels([
    TopoLevel("dcn", 2, DCN_LINK, dcn=True),
    TopoLevel("ici", 4, ICI_LINK),
])


# ---------------------------------------------------------------------------
# LinkModel validation (S4: reject junk at the source)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alpha,beta", [
    (float("nan"), 1e-10), (1e-6, float("nan")),
    (float("inf"), 1e-10), (1e-6, float("inf")),
    (-1e-6, 1e-10), (1e-6, -1e-10),
    ("1e-6", 1e-10), (1e-6, None), (True, 1e-10),
])
def test_link_model_rejects_bad_coefficients(alpha, beta):
    with pytest.raises(ValueError):
        LinkModel(alpha=alpha, beta=beta)


def test_link_model_coerces_to_float():
    lm = LinkModel(alpha=1, beta=0)
    assert isinstance(lm.alpha, float) and isinstance(lm.beta, float)
    assert lm.time(1024.0) == 1.0


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------


def test_fit_recovers_exact_model():
    link = LinkModel(alpha=7e-6, beta=3e-11)
    samples = [(float(s), link.time(float(s)))
               for s in (1 << 10, 1 << 16, 1 << 20)]
    fit = fit_link_model(samples)
    assert math.isclose(fit.alpha, link.alpha, rel_tol=1e-9)
    assert math.isclose(fit.beta, link.beta, rel_tol=1e-9)


@pytest.mark.parametrize("samples,msg", [
    ([(1024.0, 1e-5)], ">= 2 probe samples"),
    ([(1024.0, 1e-5), (1024.0, 2e-5)], "distinct values"),
    ([(1024.0, float("nan")), (2048.0, 1e-5)], "non-finite probe"),
    ([(float("inf"), 1e-5), (2048.0, 1e-5)], "non-finite probe"),
    # time shrinking with size -> negative beta
    ([(1024.0, 1e-3), (1 << 20, 1e-5)], "negative fit"),
    # steep slope through a small intercept -> negative alpha
    ([(100.0, 1.0), (200.0, 3.0)], "negative fit"),
])
def test_fit_rejects_degenerate_data(samples, msg):
    with pytest.raises(ValueError, match=msg):
        fit_link_model(samples)


# ---------------------------------------------------------------------------
# probe schedules are legal IR
# ---------------------------------------------------------------------------


def test_pingpong_schedule_shape_and_semantics():
    sched = pingpong_schedule(TOPO, 0)
    assert sched.num_slots == 1 and len(sched.rounds) == 2
    # the probe really moves data over the level's canonical link and
    # brings it home: running it is the identity on rank 0's slot
    buf = np.arange(8, dtype=np.float32).reshape(8, 1, 1)
    out = SimTransport(8).run(sched, buf)
    assert out[0, 0, 0] == buf[0, 0, 0]


def test_pingpong_rejects_unprobeable_levels():
    with pytest.raises(ValueError, match="out of range"):
        pingpong_schedule(TOPO, 5)
    one = Topology.from_levels([TopoLevel("solo", 1, ICI_LINK),
                                TopoLevel("ici", 4, ICI_LINK)])
    with pytest.raises(ValueError, match="nothing to probe"):
        pingpong_schedule(one, 0)


def test_injection_schedule_serializes_distinct_peers():
    sched = injection_schedule(TOPO, 1, fanout=4)
    assert len(sched.rounds) == 3        # clamped to level size - 1
    dsts = [d for r in sched.rounds for _, d in r.perm]
    assert len(set(dsts)) == len(dsts)
    # every peer differs from rank 0 only at the probed level
    for d in dsts:
        c = TOPO.coords(d)
        assert c[0] == 0 and c[1] != 0


# ---------------------------------------------------------------------------
# the probe pass + measured fingerprints
# ---------------------------------------------------------------------------


def test_model_probe_recovers_link_models_exactly():
    res = probe_links(TOPO, timer=model_timer(TOPO))
    assert res.source == "custom" and not res.skipped
    for i, lv in enumerate(TOPO.levels):
        assert math.isclose(res.models[i].alpha, lv.link.alpha,
                            rel_tol=1e-6)
        assert math.isclose(res.models[i].beta, lv.link.beta,
                            rel_tol=1e-6)


def test_measured_topology_keys_by_lm_section():
    meas = measured_topology(TOPO, timer=model_timer(TOPO))
    fp = meas.fingerprint()
    assert ":lm[" in fp
    assert Topology.from_fingerprint(fp) == meas
    # geometry untouched: same levels, same validation-relevant shape
    assert [(l.name, l.size, l.dcn) for l in meas.levels] == \
           [(l.name, l.size, l.dcn) for l in TOPO.levels]
    assert meas.fingerprint() != TOPO.fingerprint()


def test_size1_levels_are_skipped_not_fatal():
    t = Topology.from_levels([TopoLevel("solo", 1, DCN_LINK, dcn=True),
                              TopoLevel("ici", 4, ICI_LINK)])
    res = probe_links(t, timer=model_timer(t))
    assert 0 in res.skipped and 0 not in res.models
    assert measured_topology(t, res).levels[0].link == DCN_LINK


def test_rejected_fit_skips_level_unless_strict():
    def broken(level, nbytes):
        return float("nan") if level == 0 else \
            model_timer(TOPO)(level, nbytes)

    res = probe_links(TOPO, timer=broken)
    assert 0 in res.skipped and 1 in res.models
    with pytest.raises(ValueError, match="non-finite"):
        probe_links(TOPO, timer=broken, strict=True)


def test_probe_needs_two_distinct_sizes():
    with pytest.raises(ValueError, match="distinct probe sizes"):
        probe_links(TOPO, sizes=(1024, 1024), timer=model_timer(TOPO))


def test_fault_injection_is_observed_per_level():
    fault = LinkFault()
    fault.degrade(0, beta_scale=16.0)
    res = probe_links(TOPO, timer=model_timer(TOPO, fault=fault))
    assert math.isclose(res.models[0].beta, DCN_LINK.beta * 16.0,
                        rel_tol=1e-6)
    assert math.isclose(res.models[0].alpha, DCN_LINK.alpha, rel_tol=1e-6)
    assert math.isclose(res.models[1].beta, ICI_LINK.beta, rel_tol=1e-6)


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------


def test_drifted_levels_ratio_rule():
    base = measured_topology(TOPO, timer=model_timer(TOPO))
    assert drifted_levels(base, base) == []
    # within tolerance: not drift
    fault = LinkFault()
    fault.degrade(0, beta_scale=1.1)
    near = measured_topology(TOPO, timer=model_timer(TOPO, fault=fault))
    assert drifted_levels(base, near, tol=1.25) == []
    # past tolerance, in either direction, on either coefficient
    fault.degrade(0, beta_scale=16.0)
    far = measured_topology(TOPO, timer=model_timer(TOPO, fault=fault))
    assert drifted_levels(base, far, tol=1.25) == [0]
    assert drifted_levels(far, base, tol=1.25) == [0]
    fault.clear()
    fault.degrade(1, alpha_scale=3.0)
    lat = measured_topology(TOPO, timer=model_timer(TOPO, fault=fault))
    assert drifted_levels(base, lat, tol=1.25) == [1]


def test_drift_refuses_geometry_changes():
    with pytest.raises(ValueError, match="elastic remesh"):
        drifted_levels(TOPO, torus_topology(2, 2, 2))


# ---------------------------------------------------------------------------
# fingerprint round-trip with measured lm[] sections (property, S4)
# ---------------------------------------------------------------------------


_ALPHAS = (1e-6, 2.5e-6, 1e-5, 3.3e-5)
_BETAS = (1 / 25e9, 1 / 50e9, 1 / 12.5e9, 7.7e-11)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_measured_fingerprint_roundtrip_random_levels(seed):
    """Random level stacks, probed through a model timer whose links
    were themselves randomized: the measured topology's fingerprint —
    lm[] overrides included — survives from_fingerprint under every
    device-kind sanitization ("TPU v5e" has a space)."""
    rng = np.random.default_rng(seed)
    lvls = []
    for i in range(int(rng.integers(1, 5))):
        link = LinkModel(alpha=float(_ALPHAS[rng.integers(4)]),
                         beta=float(_BETAS[rng.integers(4)]))
        lvls.append(TopoLevel(f"ax{i}", int(rng.integers(1, 5)), link,
                              dcn=bool(rng.integers(0, 2))))
    # dcn flags must be a prefix for from_levels ordering invariants
    lvls = sorted(lvls, key=lambda l: not l.dcn)
    topo = Topology.from_levels(lvls)
    meas = measured_topology(topo, timer=model_timer(topo))
    for kind in ("model", "cpu", "TPU v5e"):
        fp = meas.fingerprint(kind)
        back = Topology.from_fingerprint(fp)
        assert back == meas, (fp, back, meas)
        assert back.fingerprint(kind) == fp
        assert " " not in fp          # "TPU v5e" sanitized
    # measured levels (size >= 2) carry their fitted coefficients
    for i, lv in enumerate(topo.levels):
        if lv.size >= 2:
            got = meas.levels[i].link
            assert math.isclose(got.alpha, lv.link.alpha, rel_tol=1e-6)
            assert math.isclose(got.beta, lv.link.beta, rel_tol=1e-6)


def test_default_probe_sizes_span_alpha_and_beta():
    lo, hi = min(DEFAULT_PROBE_SIZES), max(DEFAULT_PROBE_SIZES)
    assert ICI_LINK.alpha > ICI_LINK.beta * lo    # small: alpha-dominated
    assert DCN_LINK.beta * hi > DCN_LINK.alpha    # large: beta-dominated
