"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against
the ref.py pure-jnp oracle (kernels run in interpret mode on CPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.attention import ops as attn_ops
from repro.kernels.attention.ref import attention_ref
from repro.kernels.wkv6 import ops as wkv_ops
from repro.kernels.wkv6.ref import wkv6_ref
from repro.kernels.rmsnorm import ops as rms_ops
from repro.kernels.rmsnorm.ref import rmsnorm_ref

rng = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,K,D,bq,bk",
    [(1, 32, 1, 1, 16, 16, 16),       # minimal
     (2, 64, 4, 2, 32, 32, 32),       # GQA 2:1
     (1, 128, 8, 1, 64, 64, 32),      # MQA, rectangular blocks
     (2, 96, 6, 3, 32, 32, 48)])      # non-pow2 heads/blocks
def test_flash_attention_sweep(B, S, H, K, D, bq, bk, dtype):
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), dtype)
    got = attn_ops.flash_attention(q, k, v, True, None, None, None,
                                   bq, bk)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("window,softcap,causal",
                         [(16, None, True), (None, 30.0, True),
                          (8, 50.0, True), (None, None, False)])
def test_flash_attention_variants(window, softcap, causal):
    B, S, H, K, D = 2, 64, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    got = attn_ops.flash_attention(q, k, v, causal, window, softcap,
                                   None, 32, 32)
    want = attention_ref(q, k, v, causal=causal, window=window,
                         softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_flash_attention_grad_matches_ref():
    B, S, H, K, D = 1, 32, 2, 1, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    g1 = jax.grad(lambda a: attn_ops.flash_attention(
        a, k, v, True, None, None, None, 16, 16).sum())(q)
    g2 = jax.grad(lambda a: attention_ref(a, k, v, causal=True).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,N,bt",
                         [(1, 16, 1, 8, 8), (2, 64, 3, 16, 16),
                          (1, 128, 2, 32, 64), (2, 48, 4, 8, 16)])
def test_wkv6_sweep(B, T, H, N, bt, dtype):
    r = jnp.asarray(rng.normal(size=(B, T, H, N)), dtype)
    k = jnp.asarray(rng.normal(size=(B, T, H, N)), dtype)
    v = jnp.asarray(rng.normal(size=(B, T, H, N)), dtype)
    # realistic decay domain: w = exp(-exp(x)) in (0, 1)
    w = jnp.exp(-jnp.exp(jnp.asarray(
        rng.normal(size=(B, T, H, N)), jnp.float32))).astype(dtype)
    u = jnp.asarray(rng.normal(size=(H, N)), jnp.float32)
    got = wkv_ops.wkv6(r, k, v, w, u, bt)
    want, _ = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dtype))


def test_wkv6_grad_matches_ref():
    B, T, H, N = 1, 16, 2, 8
    args = [jnp.asarray(rng.normal(size=(B, T, H, N)), jnp.float32)
            for _ in range(3)]
    w = jnp.exp(-jnp.exp(jnp.asarray(
        rng.normal(size=(B, T, H, N)), jnp.float32)))
    u = jnp.asarray(rng.normal(size=(H, N)), jnp.float32)
    g1 = jax.grad(lambda r: wkv_ops.wkv6(r, args[1], args[2], w, u,
                                         8).sum())(args[0])
    g2 = jax.grad(lambda r: wkv6_ref(r, args[1], args[2], w,
                                     u)[0].sum())(args[0])
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 32), (2, 8, 128), (3, 5, 7, 64),
                                   (1, 960)])
@pytest.mark.parametrize("gemma", [False, True])
def test_rmsnorm_sweep(shape, dtype, gemma):
    x = jnp.asarray(rng.normal(size=shape), dtype)
    s = jnp.asarray(rng.normal(size=shape[-1:]), dtype)
    got = rms_ops.rmsnorm(x, s, 1e-6, gemma)
    want = rmsnorm_ref(x, s, eps=1e-6, gemma_style=gemma)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **_tol(dtype))


def test_rmsnorm_grad():
    x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    g1 = jax.grad(lambda a: rms_ops.rmsnorm(a, s).sum())(x)
    g2 = jax.grad(lambda a: rmsnorm_ref(a, s).sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# kernels integrate with the model layer
# ---------------------------------------------------------------------------


def test_model_forward_with_kernels():
    from repro import configs
    from repro.models import model as M
    for arch in ("qwen3-14b", "rwkv6-3b"):
        cfg = configs.get_smoke(arch)
        params = M.init_params(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                  cfg.vocab_size)
        base = M.forward(params, cfg, toks)
        fast = M.forward(params, cfg, toks, use_kernel=True)
        np.testing.assert_allclose(
            np.asarray(fast, np.float32), np.asarray(base, np.float32),
            atol=0.15, rtol=0.05)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

from repro.kernels.mamba_scan import ops as ssm_ops
from repro.kernels.mamba_scan.ref import selective_scan_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,Di,S,bt",
                         [(1, 16, 8, 4, 8), (2, 64, 32, 8, 16),
                          (1, 128, 64, 16, 64), (2, 48, 24, 8, 16)])
def test_selective_scan_sweep(B, T, Di, S, bt, dtype):
    xc = jnp.asarray(rng.normal(size=(B, T, Di)), dtype)
    dt = jnp.asarray(np.abs(rng.normal(size=(B, T, Di))) * 0.1, dtype)
    bm = jnp.asarray(rng.normal(size=(B, T, S)), dtype)
    cm = jnp.asarray(rng.normal(size=(B, T, S)), dtype)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(Di, S)), jnp.float32))
    D = jnp.asarray(rng.normal(size=(Di,)), jnp.float32)
    got = ssm_ops.selective_scan(xc, dt, bm, cm, A, D, bt)
    want, _ = selective_scan_ref(xc, dt, bm, cm, A, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dtype))


def test_selective_scan_grad():
    B, T, Di, S = 1, 16, 8, 4
    xc = jnp.asarray(rng.normal(size=(B, T, Di)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(B, T, Di))) * 0.1,
                     jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, T, S)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, T, S)), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(Di, S)), jnp.float32))
    D = jnp.asarray(rng.normal(size=(Di,)), jnp.float32)
    g1 = jax.grad(lambda a: ssm_ops.selective_scan(
        a, dt, bm, cm, A, D, 8).sum())(xc)
    g2 = jax.grad(lambda a: selective_scan_ref(
        a, dt, bm, cm, A, D)[0].sum())(xc)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-4, rtol=1e-4)
