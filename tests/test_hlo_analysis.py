"""Unit tests for the trip-count-corrected HLO walker — the §Roofline
cornerstone.  Oracles: unrolled-loop XLA cost_analysis and hand counts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (_parse_op_line, _shape_bytes,
                                       analyse_hlo, parse_module)


def _flops(fn, *args):
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    return analyse_hlo(hlo)["flops"]


def test_scan_trip_count_multiplication():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((17, 128, 128), jnp.float32)

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x,
                            ws)[0]

    got = _flops(scanned, a, ws)
    assert got == pytest.approx(17 * 2 * 128 ** 3, rel=0.02)


def test_matches_xla_on_straightline():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    f = jax.jit(lambda x, y: (x @ y).sum())
    compiled = f.lower(a, b).compile()
    got = analyse_hlo(compiled.as_text())["flops"]
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0]
    want = ca["flops"]
    assert got == pytest.approx(want, rel=0.05)


def test_nested_scan_products():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 5, 64, 64), jnp.float32)

    def nested(x, ws):
        def outer(c, wrow):
            def inner(c2, w):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, wrow)
            return c, None
        return jax.lax.scan(outer, x, ws)[0]

    got = _flops(nested, a, ws)
    assert got == pytest.approx(20 * 2 * 64 ** 3, rel=0.02)


def test_grad_flops_doubling():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    fwd = _flops(lambda x, y: (x @ y).sum(), a, a)
    bwd = _flops(jax.grad(lambda x, y: (x @ y).sum(), argnums=(0, 1)),
                 a, a)
    assert bwd == pytest.approx(2 * fwd, rel=0.05)


def test_tuple_type_parsing_with_index_comments():
    line = ("  %while.47 = (s32[], bf16[16,256,960]{2,1,0}, "
            "/*index=5*/f32[1,4096,1,32]{3,2,1,0}) while(%tuple.5), "
            "condition=%cond, body=%body")
    got = _parse_op_line(line)
    assert got is not None
    name, rtype, kind = got
    assert name == "while.47" and kind == "while"
    assert _shape_bytes(rtype) == (4 + 16 * 256 * 960 * 2
                                   + 4096 * 32 * 4)


def test_collective_wire_factors():
    # 8 host devices exist only in subprocess tests; build HLO by hand
    hlo = """
ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  ROOT %ar = f32[64]{0} all-reduce(%p0), replica_groups=[1,4]<=[4], to_apply=%add
}
"""
    r = analyse_hlo(hlo, entry="main")
    # all-reduce wire = 2*(G-1)/G * bytes = 2*(3/4)*256
    assert r["coll"]["all-reduce"] == pytest.approx(2 * 0.75 * 256)


def test_fusable_ops_excluded_from_bytes():
    a = jax.ShapeDtypeStruct((4096, 4096), jnp.float32)
    f_el = lambda x: jnp.tanh(x) + 1.0          # pure elementwise
    hlo = jax.jit(f_el).lower(a).compile().as_text()
    r = analyse_hlo(hlo, tpu_projection=True)
    r_cpu = analyse_hlo(hlo, tpu_projection=False)
    assert r["hbm_bytes"] <= r_cpu["hbm_bytes"]


def test_parse_module_shapes():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    hlo = jax.jit(lambda x: x @ x).lower(a).compile().as_text()
    comps = parse_module(hlo)
    assert comps
    dots = [op for c in comps.values() for op in c.ops
            if op.kind == "dot"]
    assert len(dots) == 1
