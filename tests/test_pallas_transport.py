"""Device-side Pallas transport conformance (core.pallas_lowering).

The contract mirrors test_executor's: the single-kernel lowering of
every registered schedule — the WHOLE compiled round sequence as ONE
``pallas_call`` — is bit-exact with the rank-by-rank oracle
``SimTransport.run_reference``, across topology classes and dtypes
(float32 everywhere; bfloat16 on the flat topology, compared through a
uint8 view so -0.0/NaN payloads cannot hide).  On top of that:

  * launch amortization — R compiled rounds cost exactly ONE launch per
    ``run`` (``PallasExec.launches``), and the jit cache keeps it at one
    trace per (shape, dtype, chunks) — the persistent-collective
    property;
  * grid chunking (``chunks > 1`` = double-buffered block pipeline) is
    bit-identical to the monolithic launch;
  * the ``transport=`` plumbing in ``core.api`` rejects unknown names
    with the valid choices in the message, and the tuner's transport
    policy cell prices shardmap-vs-pallas per size bucket;
  * the compute-fused terminal rounds — the rmsnorm allreduce epilogue
    and the attention dispatch-gather prologue — match their jnp
    oracles (and the plain kernels where they degenerate to them).

The multi-device half (PallasTransport inside shard_map vs
ShardMapTransport, the fused ``mpix_allreduce_rmsnorm``) runs on forced
host devices in tests/device_scripts/check_pallas_transport.py via
test_shardmap.py.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import api as mpix
from repro.core import executor, pallas_lowering, tuner
from repro.core.algorithms import REGISTRY
from repro.core.pallas_lowering import get_pallas_exec
from repro.core.schedule import NotApplicable
from repro.core.topology import Topology, flat_topology, torus_topology
from repro.core.transport import PallasTransport, SimTransport


@pytest.fixture(autouse=True)
def _fresh_caches():
    executor.clear_cache()
    pallas_lowering.clear_cache()
    yield
    executor.clear_cache()
    pallas_lowering.clear_cache()


TOPOS = {
    "flat": flat_topology(8),
    "2pod": Topology(8, 4),
    "3lvl": torus_topology(2, 2, 2),
}


def _registry_schedules(topo):
    out = []
    for coll, algos in REGISTRY.items():
        for name, builder in algos.items():
            try:
                out.append((f"{coll}.{name}", builder(topo)))
            except NotApplicable:
                continue
    return out


def _bits(x):
    return np.asarray(x).view(np.uint8)


# ---------------------------------------------------------------------------
# bit-exactness: one kernel == rank-by-rank oracle (registry sweep)
# ---------------------------------------------------------------------------


# bf16 only on the flat topology: the sweep pays a real interpret-mode
# lowering per (schedule, dtype) and the routing program is dtype-
# independent — flat8 bf16 already pins the -0.0/rounding behavior.
SWEEPS = [("flat", np.float32), ("2pod", np.float32),
          ("3lvl", np.float32), ("flat", jnp.bfloat16)]


@pytest.mark.parametrize(
    "topo_name,dtype", SWEEPS,
    ids=[f"{t}-{np.dtype(d).name}" for t, d in SWEEPS])
def test_single_kernel_bit_exact_with_reference(topo_name, dtype):
    topo = TOPOS[topo_name]
    n = topo.nranks
    rng = np.random.default_rng(0)
    tr = SimTransport(n)
    pt = PallasTransport(n, topo=topo)
    seen = set()
    for label, sched in _registry_schedules(topo):
        if sched.fingerprint() in seen:     # one lowering per content
            continue
        seen.add(sched.fingerprint())
        buf = rng.integers(-8, 8, (n, sched.num_slots, 2)).astype(dtype)
        want = tr.run_reference(sched, buf)
        pex = get_pallas_exec(sched, topo=topo)
        got = pex.run(buf)
        assert _bits(want).tobytes() == _bits(got).tobytes(), (
            topo_name, label, np.dtype(dtype).name)
        # the transport wrapper is the same lowering
        got_tr = pt.run_global(sched, buf)
        assert _bits(want).tobytes() == _bits(got_tr).tobytes(), label


def test_r_rounds_cost_one_launch_and_one_trace():
    """The amortization the whole module exists for: a 14-round
    schedule runs as ONE pallas_call per invocation, and repeated runs
    reuse the jitted lowering (trace count stays 1)."""
    topo = TOPOS["flat"]
    sched = REGISTRY["allreduce"]["ring_rs_ag"](topo)
    pex = get_pallas_exec(sched, topo=topo)
    assert pex.rounds > 1                       # R genuinely > 1
    rng = np.random.default_rng(1)
    buf = rng.normal(size=(8, sched.num_slots, 4)).astype(np.float32)
    for i in range(3):
        pex.run(buf)
    assert pex.launches == 3                    # 1 launch per run, not R
    assert pex.jit_traces == 1                  # persistent lowering
    # the module cache hands back the same lowered object
    assert get_pallas_exec(sched, topo=topo) is pex


def test_chunked_grid_pipeline_bit_identical():
    topo = TOPOS["2pod"]
    sched = REGISTRY["alltoall"]["hierarchical"](topo)
    pex = get_pallas_exec(sched, topo=topo)
    rng = np.random.default_rng(2)
    buf = rng.normal(size=(8, sched.num_slots, 8, 3)).astype(np.float32)
    base = pex.run(buf)
    for chunks in (2, 4, 8):
        got = pex.run(buf, chunks=chunks)
        assert _bits(base).tobytes() == _bits(got).tobytes(), chunks
    with pytest.raises(ValueError, match="chunks"):
        pex.run(buf, chunks=3)                  # 8 % 3 != 0


# ---------------------------------------------------------------------------
# api plumbing + tuner transport policy
# ---------------------------------------------------------------------------


def test_unknown_transport_rejected_with_choices():
    x = jnp.zeros((8,), jnp.float32)
    with pytest.raises(ValueError, match="shardmap"):
        mpix.mpix_allgather(x, "data", transport="nvlink")
    with pytest.raises(ValueError, match="pallas"):
        mpix.mpix_alltoall(jnp.zeros((8, 2)), "data", transport="bogus")
    with pytest.raises(ValueError, match="expected one of"):
        mpix.mpix_allreduce(x, "data", transport="sharmdap")  # typo


def test_tuner_prices_transport_per_size_bucket():
    topo = TOPOS["flat"]
    table = tuner.tune_transport(topo)
    assert table, "transport cell must not be empty"
    bests = set()
    for nbytes, rec in table.items():
        assert rec["best"] in ("shardmap", "pallas"), nbytes
        assert rec["times"]["pallas"] > 0
        assert rec["times"]["shardmap"] > 0
        bests.add(rec["best"])
    # the model must produce a real crossover, not a constant answer
    assert bests == {"shardmap", "pallas"}
    # policy ladder: fixed never leaves the default substrate
    assert tuner.select_transport(topo, 4096,
                                  policy="fixed") == "shardmap"
    small = tuner.select_transport(topo, 1024, policy="model")
    large = tuner.select_transport(topo, 1 << 24, policy="model")
    assert small == "pallas" and large == "shardmap"


def test_auto_transport_resolves_to_valid_choice():
    topo = TOPOS["flat"]
    for nbytes in (256, 1 << 22):
        kind = mpix._resolve_transport("auto", topo, nbytes,
                                       policy="model")
        assert kind in ("shardmap", "pallas")


# ---------------------------------------------------------------------------
# compute-fused terminal rounds
# ---------------------------------------------------------------------------


def test_rmsnorm_allreduce_epilogue_matches_reference():
    from repro.kernels.rmsnorm.ops import (rmsnorm, rmsnorm_allreduce,
                                           rmsnorm_allreduce_ref)
    rng = np.random.default_rng(3)
    parts = rng.normal(size=(4, 16, 128)).astype(np.float32)
    scale = rng.normal(size=(128,)).astype(np.float32)
    want = rmsnorm_allreduce_ref(parts, scale, eps=1e-6,
                                 gemma_style=False)
    got = rmsnorm_allreduce(parts, scale)
    # fused == unfused KERNEL (sum in f32, then the same normalize
    # body) bitwise; the jnp reference agrees to rounding
    unfused = rmsnorm(jnp.sum(jnp.asarray(parts), axis=0), scale)
    assert _bits(unfused).tobytes() == _bits(got).tobytes()
    assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # bf16 payload + gemma style
    pb = parts.astype(jnp.bfloat16)
    got16 = rmsnorm_allreduce(pb, scale, 1e-6, True)
    want16 = rmsnorm_allreduce_ref(pb, scale, eps=1e-6, gemma_style=True)
    # the ref rounds the sum to bf16 before normalizing; the kernel
    # keeps it in f32 — compare at bf16 resolution
    assert np.allclose(np.asarray(got16, np.float32),
                       np.asarray(want16, np.float32),
                       rtol=2e-2, atol=5e-2)
    # gradients flow through the fused kernel (custom VJP vs reference)
    f = lambda p, s: jnp.sum(jnp.square(rmsnorm_allreduce(p, s)))
    g = lambda p, s: jnp.sum(jnp.square(
        rmsnorm_allreduce_ref(p, s, eps=1e-6, gemma_style=False)))
    dp, ds = jax.grad(f, argnums=(0, 1))(jnp.asarray(parts),
                                         jnp.asarray(scale))
    rp, rs = jax.grad(g, argnums=(0, 1))(jnp.asarray(parts),
                                         jnp.asarray(scale))
    assert np.allclose(np.asarray(dp), np.asarray(rp), atol=1e-4)
    assert np.allclose(np.asarray(ds), np.asarray(rs), atol=1e-4)


def test_attention_gather_prologue_matches_reference():
    from repro.kernels.attention.ops import (flash_attention,
                                             gathered_attention_ref)
    rng = np.random.default_rng(4)
    B, S, H, D = 2, 128, 4, 64
    q = rng.normal(size=(B, S, H, D)).astype(np.float32)
    k = rng.normal(size=(B, S, 2, D)).astype(np.float32)
    v = rng.normal(size=(B, S, 2, D)).astype(np.float32)
    # identity rows degenerate to the plain kernel, bitwise
    ident = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    plain = flash_attention(q, k, v, causal=True)
    fused = flash_attention(q, k, v, causal=True, q_rows=ident)
    assert _bits(plain).tobytes() == _bits(fused).tobytes()
    # random permutation with dead (-1) rows == explicit gather + ref
    rows = np.stack([rng.permutation(S) for _ in range(B)]).astype(
        np.int32)
    rows[:, ::7] = -1                          # dropped dispatch slots
    got = flash_attention(q, k, v, causal=True, q_rows=jnp.asarray(rows))
    want = gathered_attention_ref(q, k, v, jnp.asarray(rows),
                                  causal=True)
    assert np.allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    assert np.all(np.asarray(got)[rows < 0] == 0)   # dead rows exact 0
    # grads: the gather joins the differentiated graph (scatter-add)
    f = lambda q_: jnp.sum(jnp.square(flash_attention(
        q_, k, v, causal=True, q_rows=jnp.asarray(rows))))
    g = lambda q_: jnp.sum(jnp.square(gathered_attention_ref(
        q_, k, v, jnp.asarray(rows), causal=True)))
    dq = jax.grad(f)(jnp.asarray(q))
    rq = jax.grad(g)(jnp.asarray(q))
    assert np.allclose(np.asarray(dq), np.asarray(rq), atol=2e-4)


def test_interpret_shim_env_override(monkeypatch):
    from repro.kernels.compat import pallas_interpret
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert pallas_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert pallas_interpret() is False
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    assert pallas_interpret() == (jax.default_backend() != "tpu")
