"""Property tests: every schedule algorithm == its numpy oracle, for every
rank count / pod split, via SimTransport (no devices needed).

These validate the paper's algorithm zoo itself (§2.1) plus the message/
byte accounting the locality claims rest on.
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed: seeded fallback
    from _hypothesis_stub import given, settings, st

from repro.core.topology import Topology, flat_topology
from repro.core.transport import SimTransport
from repro.core.algorithms import allgather, allreduce, alltoall, reduce_scatter


def _topos(max_ranks=24):
    """All (nranks, ranks_per_pod) pairs up to max_ranks."""
    out = []
    for n in range(2, max_ranks + 1):
        for rpp in range(1, n + 1):
            if n % rpp == 0:
                out.append((n, rpp))
    return out


topo_strategy = st.sampled_from(_topos())
pow2_topos = [t for t in _topos(32) if t[0] & (t[0] - 1) == 0]


def _rand(nranks, num_blocks, rng, block=3):
    return rng.integers(-100, 100, (nranks, num_blocks, block)).astype(np.float64)


# ---------------------------------------------------------------------------
# allgather: rank r starts with block r; everyone ends with all blocks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["ring", "bruck", "hierarchical",
                                  "hierarchical_ring"])
@settings(max_examples=40, deadline=None)
@given(shape=topo_strategy, seed=st.integers(0, 2**31))
def test_allgather(algo, shape, seed):
    n, rpp = shape
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    rng = np.random.default_rng(seed)
    contrib = rng.normal(size=(n, 3))
    buf = np.zeros((n, n, 3))
    for r in range(n):
        buf[r, r] = contrib[r]
    sched = allgather.ALGORITHMS[algo](topo)
    out = SimTransport(n).run(sched, buf)
    np.testing.assert_allclose(out, np.broadcast_to(contrib, (n, n, 3)))


@settings(max_examples=20, deadline=None)
@given(shape=st.sampled_from(pow2_topos), seed=st.integers(0, 2**31))
def test_allgather_recursive_doubling(shape, seed):
    n, rpp = shape
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    rng = np.random.default_rng(seed)
    contrib = rng.normal(size=(n, 3))
    buf = np.zeros((n, n, 3))
    for r in range(n):
        buf[r, r] = contrib[r]
    out = SimTransport(n).run(allgather.recursive_doubling(topo), buf)
    np.testing.assert_allclose(out, np.broadcast_to(contrib, (n, n, 3)))


# ---------------------------------------------------------------------------
# allreduce: all ranks end with the sum over ranks of every block
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["ring_rs_ag", "hierarchical"])
@settings(max_examples=40, deadline=None)
@given(shape=topo_strategy, seed=st.integers(0, 2**31))
def test_allreduce(algo, shape, seed):
    n, rpp = shape
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    rng = np.random.default_rng(seed)
    buf = _rand(n, n, rng)
    sched = allreduce.ALGORITHMS[algo](topo)
    out = SimTransport(n).run(sched, buf)
    want = buf.sum(axis=0)
    np.testing.assert_allclose(out, np.broadcast_to(want, (n, n, 3)))


@pytest.mark.parametrize("algo", ["recursive_halving_doubling",
                                  "hierarchical_rh"])
@settings(max_examples=20, deadline=None)
@given(shape=st.sampled_from(pow2_topos), seed=st.integers(0, 2**31))
def test_allreduce_pow2_variants(algo, shape, seed):
    n, rpp = shape
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    rng = np.random.default_rng(seed)
    buf = _rand(n, n, rng)
    out = SimTransport(n).run(allreduce.ALGORITHMS[algo](topo), buf)
    np.testing.assert_allclose(out, np.broadcast_to(buf.sum(0), (n, n, 3)))


@settings(max_examples=20, deadline=None)
@given(shape=st.sampled_from(pow2_topos), seed=st.integers(0, 2**31))
def test_allreduce_rhd(shape, seed):
    n, rpp = shape
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    rng = np.random.default_rng(seed)
    buf = _rand(n, n, rng)
    out = SimTransport(n).run(allreduce.recursive_halving_doubling(topo), buf)
    np.testing.assert_allclose(out, np.broadcast_to(buf.sum(0), (n, n, 3)))


# ---------------------------------------------------------------------------
# reduce_scatter: rank r ends owning reduced block r
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["ring", "hierarchical"])
@settings(max_examples=40, deadline=None)
@given(shape=topo_strategy, seed=st.integers(0, 2**31))
def test_reduce_scatter(algo, shape, seed):
    n, rpp = shape
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    rng = np.random.default_rng(seed)
    buf = _rand(n, n, rng)
    sched = reduce_scatter.ALGORITHMS[algo](topo)
    out = SimTransport(n).run(sched, buf)
    want = buf.sum(axis=0)
    for r in range(n):
        np.testing.assert_allclose(out[r, r], want[r])


@settings(max_examples=20, deadline=None)
@given(shape=st.sampled_from(pow2_topos), seed=st.integers(0, 2**31))
def test_reduce_scatter_halving(shape, seed):
    n, rpp = shape
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    rng = np.random.default_rng(seed)
    buf = _rand(n, n, rng)
    out = SimTransport(n).run(reduce_scatter.recursive_halving(topo), buf)
    want = buf.sum(axis=0)
    for r in range(n):
        np.testing.assert_allclose(out[r, r], want[r])


# ---------------------------------------------------------------------------
# alltoall: out[r, s] == in[s, r]
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["pairwise", "bruck", "hierarchical"])
@settings(max_examples=40, deadline=None)
@given(shape=topo_strategy, seed=st.integers(0, 2**31))
def test_alltoall(algo, shape, seed):
    n, rpp = shape
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    rng = np.random.default_rng(seed)
    data = _rand(n, n, rng)
    sched = alltoall.ALGORITHMS[algo](topo)
    buf = np.zeros((n, sched.num_blocks, 3))
    buf[:, :n] = data
    out = SimTransport(n).run(sched, buf)[:, : sched.result_blocks]
    want = np.swapaxes(data, 0, 1)
    np.testing.assert_allclose(out, want)


# ---------------------------------------------------------------------------
# locality accounting — the paper's §2.1 claims as assertions
# ---------------------------------------------------------------------------


def test_bruck_round_count():
    for n in (4, 7, 16, 24):
        sched = allgather.bruck(flat_topology(n))
        assert sched.num_rounds == int(np.ceil(np.log2(n)))


def test_hierarchical_allgather_dcn_bytes_minimal():
    """Every block crosses the DCN exactly once per remote pod."""
    topo = Topology(nranks=16, ranks_per_pod=4)
    sched = allgather.hierarchical(topo)
    dcn_blocks = sched.byte_count(elem_bytes=1, topo=topo, local=False)
    # minimal: each of the 16 blocks crosses to each of the 3 remote pods once
    assert dcn_blocks == 16 * (topo.npods - 1)
    flat = allgather.bruck(topo)
    assert flat.byte_count(1, topo, local=False) > dcn_blocks


def test_hierarchical_alltoall_dcn_message_count():
    """DCN messages per pod-pair drop from R^2 (pairwise) to R."""
    topo = Topology(nranks=16, ranks_per_pod=4)
    R, Q = topo.ranks_per_pod, topo.npods
    pw = alltoall.pairwise(topo).message_count(topo, local=False)
    hi = alltoall.hierarchical(topo).message_count(topo, local=False)
    assert pw == R * R * Q * (Q - 1)
    assert hi == R * Q * (Q - 1)


def test_hierarchical_allreduce_dcn_rounds():
    topo = Topology(nranks=16, ranks_per_pod=8)
    sched = allreduce.hierarchical(topo)
    dcn_rounds = sum(
        1 for rnd in sched.rounds
        if any(not topo.is_local(s, d) for s, d in rnd.perm))
    assert dcn_rounds == 2 * (topo.npods - 1)


def test_alltoallv_bytes_conservation():
    topo = Topology(nranks=8, ranks_per_pod=4)
    rng = np.random.default_rng(0)
    counts = rng.integers(0, 50, (8, 8))
    np.fill_diagonal(counts, 0)
    pw = alltoall.alltoallv_bytes("pairwise", counts, topo)
    hi = alltoall.alltoallv_bytes("hierarchical", counts, topo)
    # same DCN payload either way (aggregation changes messages, not bytes)
    dcn_payload = sum(counts[s, d] for s in range(8) for d in range(8)
                      if not topo.is_local(s, d))
    assert pw["dcn"] == dcn_payload
    assert hi["dcn"] == dcn_payload
    assert hi["msgs_dcn"] < pw["msgs_dcn"]


def test_selector_model_prefers_hierarchical_multi_pod():
    from repro.core import selector
    topo = Topology(nranks=32, ranks_per_pod=16)
    # large payload, multi-pod: a hierarchical variant wins on the DCN
    # beta term (which sub-algorithm wins depends on the alpha model)
    name = selector.select("allreduce", topo, nbytes=64 << 20)
    assert name.startswith("hierarchical")
    # tiny payload, one pod: log-step wins on alpha
    name = selector.select("allgather", flat_topology(16), nbytes=1024)
    assert name in ("bruck", "recursive_doubling")
