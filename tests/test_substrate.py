"""Substrate tests: optimizer, compression, data pipeline determinism,
atomic/async checkpointing, fault-tolerant loop, straggler rebalance,
elastic remesh."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed: seeded fallback
    from _hypothesis_stub import given, settings, st

import jax
import jax.numpy as jnp

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_int8, decompress_int8, cosine_schedule)
from repro.optim.compress import ef_compress_tree
from repro.data import DataPipeline, PipelineConfig
from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.runtime import (FaultTolerantLoop, PreemptionSignal,
                           StragglerMonitor, remesh_plan)


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------


def test_adamw_reduces_quadratic():
    p = {"w": jnp.array([3.0, -2.0], jnp.float32)}
    st_ = adamw_init(p)
    lr = 0.1
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st_ = adamw_update(p, g, st_, lr=lr, weight_decay=0.0)
    assert float(jnp.abs(p["w"]).max()) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                         for x in jax.tree.leaves(clipped)))
    assert float(norm) == pytest.approx(np.sqrt(700.0), rel=1e-5)
    assert float(total) == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_shape():
    assert float(cosine_schedule(jnp.int32(0), peak_lr=1e-3,
                                 warmup_steps=10, total_steps=100)) == 0.0
    peak = float(cosine_schedule(jnp.int32(10), peak_lr=1e-3,
                                 warmup_steps=10, total_steps=100))
    assert peak == pytest.approx(1e-3, rel=1e-5)
    end = float(cosine_schedule(jnp.int32(100), peak_lr=1e-3,
                                warmup_steps=10, total_steps=100))
    assert end == pytest.approx(1e-4, rel=1e-3)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31), n=st.integers(1, 2000))
def test_int8_roundtrip_error_bound(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n,)).astype(np.float32) * rng.uniform(0.1, 10)
    q, s = compress_int8(jnp.asarray(x))
    back = np.asarray(decompress_int8(q, s, (n,), jnp.float32))
    # absmax-block int8: error <= scale/2 per element
    scale = np.repeat(np.asarray(s), 256)[:n]
    assert (np.abs(back - x) <= scale / 2 + 1e-6).all()


def test_error_feedback_unbiased_over_steps():
    """EF residual keeps the *accumulated* quantization error bounded, so
    the mean applied gradient converges to the true mean."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
    res = None
    applied = np.zeros(512, np.float32)
    T = 64
    for _ in range(T):
        comp_tree, res = ef_compress_tree({"g": g_true}, res)
        q, s = comp_tree["g"]
        applied += np.asarray(decompress_int8(q, s, (512,), jnp.float32))
    err = np.abs(applied / T - np.asarray(g_true)).max()
    assert err < 0.05 * float(jnp.abs(g_true).max())


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_pipeline_deterministic_and_shard_consistent():
    cfg = PipelineConfig(vocab_size=1000, seq_len=64, global_batch=8)
    full = DataPipeline(cfg, 1, 0)
    b0 = full.batch(7)
    again = DataPipeline(cfg, 1, 0).batch(7)
    np.testing.assert_array_equal(b0["tokens"], again["tokens"])
    # sharded views tile the global batch exactly
    parts = [DataPipeline(cfg, 4, k).batch(7)["tokens"] for k in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b0["tokens"])
    # different steps differ
    assert not np.array_equal(full.batch(8)["tokens"], b0["tokens"])


def test_pipeline_labels_shifted_and_masked():
    cfg = PipelineConfig(vocab_size=1000, seq_len=128, global_batch=2,
                         mean_doc_len=16)
    b = DataPipeline(cfg).batch(0)
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    live = labels[:, :-1] >= 0
    np.testing.assert_array_equal(labels[:, :-1][live],
                                  toks[:, 1:][live])
    assert (labels[:, -1] == -100).all()
    # boundaries exist and are masked
    assert (labels == -100).sum() > 2


def test_pipeline_reshard_preserves_stream():
    cfg = PipelineConfig(vocab_size=500, seq_len=32, global_batch=12)
    p = DataPipeline(cfg, 2, 1)
    q = p.reshard(3, 2)
    full = DataPipeline(cfg, 1, 0).batch(3)["tokens"]
    np.testing.assert_array_equal(
        np.asarray(q.batch(3)["tokens"]), np.asarray(full)[8:])


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(8, 3)),
                                        jnp.float32)},
            "opt": {"mu": jnp.zeros((8, 3)), "count": jnp.int32(5)}}


def test_checkpoint_roundtrip_sharded(tmp_path):
    s = _state()
    save_checkpoint(tmp_path, 42, s, num_shards=3, meta={"next_step": 43})
    got, meta = restore_checkpoint(tmp_path, s)
    assert meta["next_step"] == 43
    np.testing.assert_array_equal(got["params"]["w"], s["params"]["w"])
    assert latest_step(tmp_path) == 42


def test_checkpoint_atomicity(tmp_path):
    s = _state()
    save_checkpoint(tmp_path, 1, s)
    # simulate a crash: partial dir without marker
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 1
    got, _ = restore_checkpoint(tmp_path, s)
    np.testing.assert_array_equal(got["opt"]["count"], 5)


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, num_shards=2)
    s = _state(1)
    ck.save(10, s)
    ck.wait()
    got, _ = restore_checkpoint(tmp_path, s)
    np.testing.assert_array_equal(got["params"]["w"], s["params"]["w"])


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------


def test_fault_tolerant_loop_resume(tmp_path):
    calls = []

    def step_fn(state, step):
        calls.append(step)
        return {"x": state["x"] + step}

    loop = FaultTolerantLoop(tmp_path, ckpt_every=4)
    s0 = {"x": jnp.float32(0)}
    state, stopped = loop.run(s0, step_fn, start_step=0, num_steps=10)
    assert stopped == 10
    # crash-restart: a fresh loop resumes from the last committed step
    loop2 = FaultTolerantLoop(tmp_path, ckpt_every=4)
    state2, start = loop2.resume_or_init(s0)
    assert start == 10
    assert float(state2["x"]) == float(state["x"]) == sum(range(10))


def test_preemption_checkpoints_and_stops(tmp_path):
    pre = PreemptionSignal()

    def step_fn(state, step):
        if step == 2:
            pre.trigger()
        return {"x": state["x"] + 1}

    loop = FaultTolerantLoop(tmp_path, ckpt_every=100, preemption=pre)
    state, stopped = loop.run({"x": jnp.float32(0)}, step_fn,
                              start_step=0, num_steps=50)
    assert stopped == 3            # stopped right after the signal
    st_, start = loop.resume_or_init({"x": jnp.float32(0)})
    assert start == 3 and float(st_["x"]) == 3


def test_straggler_rebalance():
    mon = StragglerMonitor(num_hosts=4, threshold=1.5)
    for t in range(8):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 3.0)   # host 2 is slow
    assert mon.stragglers() == [2]
    asg = mon.rebalance()
    assert asg[2] == []
    assert sorted(sum(asg.values(), [])) == [0, 1, 2, 3]  # no shard lost


def test_remesh_plan():
    p = remesh_plan(global_batch=256, old_devices=512, new_devices=256,
                    data_axis_size=16)
    assert p.per_device_batch == 16
    with pytest.raises(ValueError):
        remesh_plan(global_batch=256, old_devices=512, new_devices=384,
                    data_axis_size=24)
