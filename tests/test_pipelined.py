"""Makespan-aware pipelined execution (PR 6).

Covers the pass-3 contract end to end on SimTransport:
  * the makespan model never prices a packing above the armed serial
    time plus registered compute (pointwise, every probe size),
  * the tail-split move commits only when it helps and the committed
    pipelined schedule stays bit-exact vs ``run_reference``,
  * ``split_round``/``can_split`` legality, ``run_chunked`` chunking,
    the partitioned entry-point validation, and the tuner's overlap
    (chunk-count) section.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import executor, tuner
from repro.core.algorithms import REGISTRY
from repro.core.algorithms import partitioned as pc
from repro.core.plan import CommGraph, build_plan
from repro.core.schedule import (CommSchedule, ComputeEvent, NotApplicable,
                                 can_split, split_round)
from repro.core.topology import Topology, flat_topology, torus_topology
from repro.core.transport import SimTransport


@pytest.fixture(autouse=True)
def _fresh_executor_cache():
    executor.clear_cache()
    yield
    executor.clear_cache()


TOPOS = {
    "flat": flat_topology(8),
    "2pod": Topology(8, 4),
    "3lvl": torus_topology(2, 2, 2),
}
PROBES = (1.0, 4096.0, float(1 << 20))


def _corpus(topo):
    out = []
    for coll, algos in REGISTRY.items():
        for name, builder in algos.items():
            try:
                out.append((f"{coll}.{name}", builder(topo)))
            except NotApplicable:
                continue
    return out


def _with_event(sched, topo, *, parts=4):
    """Attach one splittable consumer-compute event after the last
    round, sized to the schedule's own serial cost (the regime where
    overlap pays)."""
    ev_s = sched.modeled_time(topo, 4096.0)
    ev = ComputeEvent("consumer", ev_s, after_round=-1,
                      splittable=True, parts=parts)
    return dataclasses.replace(sched, compute_events=(ev,))


# ---------------------------------------------------------------------------
# ComputeEvent / split_round units
# ---------------------------------------------------------------------------


def test_compute_event_validation():
    with pytest.raises(ValueError):
        ComputeEvent("x", -1.0)
    with pytest.raises(ValueError):
        ComputeEvent("x", 1.0, after_round=-2)
    with pytest.raises(ValueError):
        ComputeEvent("x", 1.0, parts=-1)
    sched = REGISTRY["allgather"]["ring"](flat_topology(8))
    # out-of-range anchors trip schedule validation (assert-based, like
    # the other schedule invariants; always on in the test suite)
    with pytest.raises(AssertionError):
        dataclasses.replace(
            sched, compute_events=(
                ComputeEvent("x", 1.0,
                             after_round=len(sched.rounds)),))


def test_split_round_legality_and_semantics():
    topo = flat_topology(8)
    sched = REGISTRY["allgather"]["bruck"](topo)
    tail = sched.rounds[-1]
    assert tail.k >= 2 and can_split(tail, 2)
    chunks = split_round(tail, 2)
    assert len(chunks) == 2 and sum(c.k for c in chunks) == tail.k
    # chunks run sequentially == the unsplit round, bit-exact
    split = dataclasses.replace(
        sched, rounds=sched.rounds[:-1] + chunks)
    tr = SimTransport(8)
    rng = np.random.default_rng(0)
    buf = rng.integers(-8, 8, (8, sched.num_slots, 3)).astype(np.float32)
    assert np.array_equal(tr.run_reference(sched, buf),
                          tr.run_reference(split, buf))
    # illegal splits refuse
    assert not can_split(tail, 3) or tail.k % 3 == 0
    red = REGISTRY["allreduce"]["recursive_halving_doubling"](topo)
    first_red = next(r for r in red.rounds if r.reduce)
    assert not can_split(first_red, 2)
    with pytest.raises(AssertionError):
        split_round(first_red, 2)


def test_event_fingerprint_sensitivity():
    sched = REGISTRY["allgather"]["ring"](flat_topology(8))
    ev = ComputeEvent("mlp", 1e-3, after_round=-1, splittable=True,
                      parts=4)
    a = dataclasses.replace(sched, compute_events=(ev,))
    b = dataclasses.replace(
        sched, compute_events=(dataclasses.replace(ev, seconds=2e-3),))
    assert sched.fingerprint() != a.fingerprint()
    assert a.fingerprint() != b.fingerprint()


# ---------------------------------------------------------------------------
# makespan model
# ---------------------------------------------------------------------------


def test_makespan_requires_armed_executor():
    sched = REGISTRY["allgather"]["ring"](flat_topology(8))
    free = executor.get_executor(sched)
    with pytest.raises(RuntimeError):
        free.makespan(4096.0)
    with pytest.raises(RuntimeError):
        free.chunked_makespan(4096.0, 2, 1e-3)


def test_makespan_chain_and_split_wins_corpus():
    """Acceptance: over the full registry x {flat, 2-pod, 3-level}
    corpus with a splittable consumer event, the packed makespan is
    <= armed serial + compute at EVERY probe size, committed tail
    splits produce a strict win at some probe, and every committed
    pipelined schedule is bit-exact vs run_reference."""
    rng = np.random.default_rng(3)
    wins = 0
    for topo in TOPOS.values():
        tr = SimTransport(topo.nranks)
        for label, base in _corpus(topo):
            sched = _with_event(base, topo)
            ex = executor.get_executor(sched, topo=topo)
            ev_s = sum(e.seconds for e in sched.compute_events)
            strict = False
            for s in PROBES:
                mk = ex.makespan(s)
                serial = (ex.compiled_schedule.modeled_time(topo, s)
                          + ev_s)
                assert mk <= serial * (1 + 1e-9), (label, s, mk, serial)
                strict = strict or mk < serial * (1 - 1e-9)
            if ex.pipeline_tail_parts >= 2:
                assert strict, label
                wins += 1
                buf = rng.integers(-8, 8, (topo.nranks,
                                           sched.num_slots, 2)
                                   ).astype(np.float32)
                assert np.array_equal(
                    tr.run_reference(base, buf),
                    tr.run_reference(ex.pipelined_schedule, buf)), label
    assert wins >= 10, wins


def test_makespan_no_events_never_above_serial():
    topo = Topology(8, 4)
    for label, sched in _corpus(topo):
        ex = executor.get_executor(sched, topo=topo)
        for s in PROBES:
            assert (ex.makespan(s)
                    <= ex.compiled_schedule.modeled_time(topo, s)
                    * (1 + 1e-9)), (label, s)


def test_makespan_on_neighbor_plan():
    topo = Topology(8, 4)
    graph = CommGraph.random(8, n_local=6, degree=4,
                             rng=np.random.default_rng(7), dup_frac=0.8)
    plan = build_plan(graph, topo, aggregate=True)
    assert 0.0 < plan.makespan() <= plan.modeled_time() * (1 + 1e-9)


def test_chunked_makespan_model():
    """Closed-form row-chunk pipeline: parts=1 is serial + compute;
    with compute comparable to the wire time, some parts >= 2 wins at
    beta-dominated sizes (the overlap headroom the tuner prices)."""
    topo = Topology(8, 4)
    sched = REGISTRY["alltoall"]["hierarchical"](topo)
    ex = executor.get_executor(sched, topo=topo)
    big = float(1 << 20)
    serial = ex.compiled_schedule.modeled_time(topo, big)
    compute = serial                       # balanced pipeline regime
    assert ex.chunked_makespan(big, 1, compute) == pytest.approx(
        serial + compute)
    best = min(ex.chunked_makespan(big, p, compute)
               for p in (2, 4, 8))
    assert best < (serial + compute) * (1 - 1e-3)
    # alpha-dominated sizes: chunking only adds latency, p1 stays best
    small = 8.0
    s_serial = ex.compiled_schedule.modeled_time(topo, small)
    assert all(ex.chunked_makespan(small, p, 0.0)
               >= s_serial * (1 - 1e-12) for p in (1, 2, 4, 8))


def test_executor_stats_pipeline_fields():
    topo = flat_topology(8)
    sched = _with_event(REGISTRY["allgather"]["bruck"](topo), topo)
    ex = executor.get_executor(sched, topo=topo)
    st = ex.stats()
    assert st["pipeline_groups"] >= 1
    assert st["pipeline_packed_rounds"] >= len(ex.compiled_schedule.rounds)
    assert st["pipeline_tail_parts"] == ex.pipeline_tail_parts


# ---------------------------------------------------------------------------
# run_chunked (SimTransport)
# ---------------------------------------------------------------------------


def test_run_chunked_bit_identical_and_fold():
    topo = Topology(8, 4)
    rng = np.random.default_rng(1)
    for label, sched in _corpus(topo)[:8]:
        tr = SimTransport(8)
        buf = rng.integers(-8, 8, (8, sched.num_slots, 8, 3)
                           ).astype(np.float32)
        whole = tr.run(sched, buf)
        for chunks in (1, 2, 4):
            assert np.array_equal(
                tr.run_chunked(sched, buf, chunks=chunks), whole), (
                label, chunks)
        # early-bird fold: running sum over chunk outputs == whole sum
        got = tr.run_chunked(
            sched, buf, chunks=4,
            consume=lambda c, out, i: c + out.sum(axis=2),
            init=np.zeros((8, sched.num_slots, 3), np.float32))
        np.testing.assert_allclose(got, whole.sum(axis=2), atol=1e-4)


def test_run_chunked_validation():
    sched = REGISTRY["allgather"]["ring"](flat_topology(8))
    tr = SimTransport(8)
    buf = np.zeros((8, sched.num_slots, 6), np.float32)
    with pytest.raises(ValueError):
        tr.run_chunked(sched, buf, chunks=0)
    with pytest.raises(ValueError):
        tr.run_chunked(sched, buf, chunks=4)     # 6 % 4 != 0


# ---------------------------------------------------------------------------
# partitioned entry-point validation (mpix_* satellite)
# ---------------------------------------------------------------------------


def test_partitioned_validation():
    perm8 = [(i, (i + 1) % 8) for i in range(8)]
    with pytest.raises(ValueError):
        pc.partitioned_schedule(8, perm8, 0)
    with pytest.raises(ValueError):
        pc.partitioned_schedule(8, perm8, -2)
    x = jnp.zeros((12, 4), jnp.float32)
    perm = [(i, (i + 1) % 4) for i in range(4)]
    with pytest.raises(ValueError):
        pc.partitioned_ppermute(x, "data", perm, 0)
    with pytest.raises(ValueError):
        pc.partitioned_ppermute(x, "data", perm, 5)   # 12 % 5 != 0


def test_alltoall_overlap_validation():
    from repro.core import api as mpix
    topo = flat_topology(4)
    x = jnp.zeros((4 * 6, 3), jnp.float32)
    with pytest.raises(ValueError):
        mpix.mpix_alltoall_overlap(
            jnp.zeros((9, 3)), ("data",), lambda c, o, i: o, None,
            chunks=1, topo=topo)
    with pytest.raises(ValueError):
        mpix.mpix_alltoall_overlap(x, ("data",), lambda c, o, i: o,
                                   None, chunks=-1, topo=topo)
    with pytest.raises(ValueError):
        mpix.mpix_alltoall_overlap(x, ("data",), lambda c, o, i: o,
                                   None, chunks=4, topo=topo)  # 6 % 4


def test_dp_allreduce_overlap_validation():
    from repro.train import sync
    with pytest.raises(ValueError):
        sync.dp_allreduce_overlap({"a": jnp.zeros((4,))}, ("data",),
                                  chunks=0)


# ---------------------------------------------------------------------------
# tuner overlap (chunk-count) section
# ---------------------------------------------------------------------------


def test_select_overlap_chunks_policies():
    topo = Topology(8, 4)
    # fixed policy: always the monolithic fallback
    assert tuner.select_overlap_chunks(topo, 1 << 20, 1.0,
                                       policy="fixed") == 1
    # model policy, beta-dominated size + real compute: chunking wins
    big = tuner.select_overlap_chunks(topo, 64 << 20, 1.0,
                                      policy="model")
    assert big >= 2
    # tiny message, no compute: never worse than serial -> p1
    assert tuner.select_overlap_chunks(topo, 64, 0.0,
                                       policy="model") == 1


def test_tune_overlap_table_shape():
    topo = Topology(8, 4)
    table = tuner.tune_overlap(topo, sizes=(1 << 14, 1 << 22))
    assert set(table) == {"14", "22"}       # log2 bucket keys
    for rec in table.values():
        assert set(rec["times"]) == {f"p{p}"
                                     for p in tuner._OVERLAP_PARTS}
        assert rec["best"] in rec["times"]
        assert rec["times"][rec["best"]] <= rec["times"]["p1"] * (
            1 + 1e-9)
