"""Randomized schedule-conformance fuzzing (core.executor's contract).

A generator of random *legal* ``CommSchedule``s — random geometry,
gather/perm/scatter tables, optional reduce and ragged-payload rounds,
optional bijective local pre/post permutations — paired with random
1–4-level topologies, drives three metamorphic properties:

  * **bit-exactness** — the compiled executor (unoptimized, topology-
    free fused, and topology-armed) is bit-identical to the historical
    rank-by-rank oracle ``SimTransport.run_reference`` on every fuzzed
    schedule;
  * **cost safety** — fusion/reordering never raises the alpha-beta
    ``modeled_time``: armed <= topology-free <= original, at small
    (alpha-dominated), medium, and large (beta-dominated) slot sizes;
  * **identity** — ``CommSchedule.fingerprint()`` round-trips: a
    schedule rebuilt from copies of the same tables shares the
    fingerprint, a renamed schedule shares it, any table mutation
    changes it (the executor-cache key is exactly content identity).

The suite runs under the real Hypothesis runner when the ``dev`` extra
is installed and falls back to the seeded stub otherwise, so it is
tier-1 in every environment.  Setting ``REPRO_FUZZ_DETERMINISTIC=1``
(the CI fuzz leg) pins Hypothesis to its derandomized profile so CI
failures reproduce locally from the recorded falsifying example.
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    if os.environ.get("REPRO_FUZZ_DETERMINISTIC"):
        settings.register_profile("repro-fuzz", derandomize=True,
                                  deadline=None)
        settings.load_profile("repro-fuzz")
except ImportError:      # dev extra not installed: seeded fallback
    from _hypothesis_stub import given, settings, st

import dataclasses
import math

from repro.core import executor, pallas_lowering
from repro.core.schedule import CommRound, CommSchedule, ComputeEvent
from repro.core.topology import Topology, flat_topology, torus_topology
from repro.core.transport import SimTransport


@pytest.fixture(autouse=True)
def _fresh_executor_cache():
    executor.clear_cache()
    pallas_lowering.clear_cache()
    yield
    executor.clear_cache()
    pallas_lowering.clear_cache()


# ---------------------------------------------------------------------------
# generators (plain numpy RNG so the hypothesis stub drives them too)
# ---------------------------------------------------------------------------


def rand_topology(rng) -> Topology:
    """Random 1–4-level hierarchy, nranks capped so the rank-by-rank
    oracle stays fast (degenerate size-1 axes included on purpose)."""
    n_axes = int(rng.integers(0, 4))
    sizes = [int(rng.integers(1, 4)) for _ in range(n_axes)]
    npods = int(rng.integers(1, 4))
    while sizes and npods * math.prod(sizes) > 24:
        sizes.pop()
    if not sizes:
        n = max(2, npods * int(rng.integers(1, 9)))
        return (flat_topology(n) if npods == 1
                else Topology(n, n // npods))
    return torus_topology(npods, *sizes)


def rand_round(rng, n: int, slots: int, *, allow_reduce=True) -> CommRound:
    """One random legal round: a random partial matching ((r, r)
    self-pairs included), random gather rows with -1 zero-send padding,
    distinct live scatter targets with -1 dropped-on-arrival holes, an
    optional reduce flag and an optional ragged ``payload``."""
    m = int(rng.integers(1, n + 1))
    srcs = rng.permutation(n)[:m]
    dsts = rng.permutation(n)[:m]
    k = int(rng.integers(1, min(4, slots) + 1))
    gi = np.full((n, k), -1, np.int64)
    si = np.full((n, k), -1, np.int64)
    reduce = bool(allow_reduce and rng.random() < 0.25)
    payload = (np.zeros(n, np.int64)
               if (not reduce and rng.random() < 0.4) else None)
    perm = []
    for s, d in zip(srcs, dsts):
        w = int(rng.integers(1, k + 1))
        g = rng.integers(0, slots, k).astype(np.int64)
        g[w:] = -1
        g[rng.random(k) < 0.15] = -1          # zero-send holes
        t = np.full(k, -1, np.int64)
        t[:w] = rng.permutation(slots)[:w]    # distinct live targets
        t[:w][rng.random(w) < 0.2] = -1       # dropped-on-arrival holes
        gi[s], si[d] = g, t
        perm.append((int(s), int(d)))
        if payload is not None:
            payload[s] = int(rng.integers(0, int((g >= 0).sum()) + 1))
    return CommRound(perm=tuple(perm), gather_idx=gi, scatter_idx=si,
                     reduce=reduce, payload=payload)


def rand_schedule(rng, n: int) -> CommSchedule:
    slots = int(rng.integers(2, 9))
    nrounds = int(rng.integers(1, 6))
    rounds = tuple(rand_round(rng, n, slots) for _ in range(nrounds))
    local_pre = (np.stack([rng.permutation(slots) for _ in range(n)])
                 if rng.random() < 0.3 else None)
    local_post = (np.stack([rng.permutation(slots) for _ in range(n)])
                  if rng.random() < 0.3 else None)
    return CommSchedule(nranks=n, num_slots=slots, rounds=rounds,
                        name="fuzz", local_pre=local_pre,
                        local_post=local_post)


def rand_events(rng, nrounds: int) -> tuple:
    """0–3 random compute events: anchors span the whole schedule
    (``-1`` = after the last round), seconds span alpha-to-beta
    magnitudes, and ~half are splittable so the tail-split move fires
    when legality lines up."""
    if rng.random() < 0.5:
        return ()
    out = []
    for i in range(int(rng.integers(1, 4))):
        anchor = -1 if rng.random() < 0.5 else int(
            rng.integers(0, nrounds))
        out.append(ComputeEvent(
            f"ev{i}", float(10.0 ** rng.uniform(-7, -2)),
            after_round=anchor,
            splittable=bool(rng.random() < 0.5),
            parts=int(rng.choice([0, 2, 4]))))
    return tuple(out)


# ---------------------------------------------------------------------------
# the metamorphic core
# ---------------------------------------------------------------------------


_PROBE_SLOT_BYTES = (1, 4096, 1 << 20)   # alpha-, mixed-, beta-dominated


def check_conformance(sched: CommSchedule, topo: Topology, rng) -> None:
    n = sched.nranks
    tr = SimTransport(n)
    buf = rng.integers(-8, 8, (n, sched.num_slots, 2)).astype(np.float32)
    want = tr.run_reference(sched, buf)
    armed = executor.compile_schedule(sched, optimize=True, topo=topo)
    free = executor.compile_schedule(sched, optimize=True)
    plain = executor.compile_schedule(sched, optimize=False)
    # bit-exactness of every compile mode vs the rank-by-rank oracle
    assert np.array_equal(want, armed.run_sim(buf)), sched.name
    assert np.array_equal(want, free.run_sim(buf))
    assert np.array_equal(want, plain.run_sim(buf))
    # cost safety at every probe size: armed <= topology-free <= original
    ev_s = sum(e.seconds for e in sched.compute_events)
    for s in _PROBE_SLOT_BYTES:
        t_orig = sched.modeled_time(topo, s)
        t_free = free.compiled_schedule.modeled_time(topo, s)
        t_armed = armed.compiled_schedule.modeled_time(topo, s)
        tol = 1 + 1e-9
        assert t_free <= t_orig * tol, (s, t_free, t_orig)
        assert t_armed <= t_free * tol, (s, t_armed, t_free)
        assert t_armed <= t_orig * tol, (s, t_armed, t_orig)
        # pipelined pass 3: any packing (split or not) never prices
        # above the armed serial chain plus the registered compute
        assert armed.makespan(s) <= (t_armed + ev_s) * tol, (
            s, armed.makespan(s), t_armed, ev_s)
    # a committed tail split must stay an execution no-op (bit-exact)
    if armed.pipelined_schedule is not None:
        assert armed.pipeline_tail_parts >= 2
        assert np.array_equal(
            want, tr.run_reference(armed.pipelined_schedule, buf))


def check_fingerprint_roundtrip(sched: CommSchedule) -> None:
    rebuilt = CommSchedule(
        nranks=sched.nranks, num_slots=sched.num_slots,
        rounds=tuple(CommRound(perm=r.perm,
                               gather_idx=r.gather_idx.copy(),
                               scatter_idx=r.scatter_idx.copy(),
                               reduce=r.reduce,
                               payload=None if r.payload is None
                               else r.payload.copy())
                     for r in sched.rounds),
        name="rebuilt-under-another-name",
        slot_bytes=sched.slot_bytes,
        local_pre=None if sched.local_pre is None
        else np.asarray(sched.local_pre).copy(),
        local_post=None if sched.local_post is None
        else np.asarray(sched.local_post).copy(),
        out_slots=sched.out_slots, out_offsets=sched.out_offsets)
    assert rebuilt.fingerprint() == sched.fingerprint()
    # any table mutation must change the identity
    rnd = sched.rounds[0]
    g = rnd.gather_idx.copy()
    g[0, 0] = (g[0, 0] + 2) % sched.num_slots   # stays a legal index
    mutated = dataclasses.replace(
        sched,
        rounds=(dataclasses.replace(rnd, gather_idx=g),) + sched.rounds[1:])
    assert mutated.fingerprint() != sched.fingerprint()


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fuzzed_schedules_conform(seed):
    """Random schedule x random 1–4-level topology: compiled execution
    is bit-exact and fusion/reordering never raises modeled time."""
    rng = np.random.default_rng(seed)
    topo = rand_topology(rng)
    sched = rand_schedule(rng, topo.nranks)
    check_conformance(sched, topo, rng)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fuzzed_event_schedules_makespan_safe(seed):
    """Random schedule + random compute events: the makespan chain
    (packed <= armed serial + compute, pointwise) and tail-split
    bit-exactness hold under fuzzing, and attaching events never
    perturbs execution (they are model-only)."""
    rng = np.random.default_rng(seed)
    topo = rand_topology(rng)
    base = rand_schedule(rng, topo.nranks)
    sched = dataclasses.replace(
        base, compute_events=rand_events(rng, len(base.rounds)))
    check_conformance(sched, topo, rng)
    if sched.compute_events:
        # events change identity (cache key) but not results
        assert sched.fingerprint() != base.fingerprint()
        buf = rng.integers(-8, 8, (topo.nranks, sched.num_slots, 2)
                           ).astype(np.float32)
        a = executor.compile_schedule(sched, optimize=True, topo=topo)
        b = executor.compile_schedule(base, optimize=True, topo=topo)
        assert np.array_equal(a.run_sim(buf), b.run_sim(buf))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fuzzed_fingerprints_roundtrip(seed):
    rng = np.random.default_rng(seed)
    topo = rand_topology(rng)
    check_fingerprint_roundtrip(rand_schedule(rng, topo.nranks))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), probe=st.sampled_from([0, 1, 2]))
def test_fuzzed_reduce_only_schedules_pass_through(seed, probe):
    """Reduce rounds are barriers for every compile mode: a schedule of
    only reduce rounds keeps its round count under the armed pass and
    stays bit-exact (accumulation order is bit-exactness-critical)."""
    rng = np.random.default_rng(seed)
    topo = rand_topology(rng)
    n = topo.nranks
    slots = int(rng.integers(2, 7))
    rounds = []
    for _ in range(int(rng.integers(1, 4))):
        rnd = rand_round(rng, n, slots, allow_reduce=False)
        rounds.append(dataclasses.replace(rnd, reduce=True, payload=None))
    sched = CommSchedule(nranks=n, num_slots=slots, rounds=tuple(rounds),
                         name="fuzz.reduce")
    ex = executor.compile_schedule(sched, optimize=True, topo=topo)
    # a round survives compilation iff some edge delivers something;
    # reduce rounds are never merged or reordered away
    live = sum(1 for r in rounds
               if any((r.scatter_idx[d] >= 0).any() for _, d in r.perm))
    assert ex.rounds_after == live
    buf = rng.integers(-4, 4,
                       (n, slots, 2)).astype(np.float32) * (probe + 1)
    assert np.array_equal(SimTransport(n).run_reference(sched, buf),
                          ex.run_sim(buf))


def test_fuzz_corpus_sweep_200_schedules():
    """Deterministic acceptance sweep: >= 200 fuzzed (schedule,
    topology) pairs are bit-exact vs the oracle and cost-safe — the
    fixed-seed floor under the sampled property tests above."""
    checked = 0
    for seed in range(210):
        rng = np.random.default_rng(seed)
        topo = rand_topology(rng)
        sched = rand_schedule(rng, topo.nranks)
        check_conformance(sched, topo, rng)
        checked += 1
    assert checked >= 200


def _small_fuzz_case(seed):
    """Bounded (schedule, topology) pair for the Pallas sweep: the
    single-kernel lowering unrolls every route statically, so each new
    schedule pays a real interpret-mode trace — keep nranks/rounds small
    and let the seeds supply the variety."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 7))
    topo = flat_topology(n)
    slots = int(rng.integers(2, 6))
    rounds = tuple(rand_round(rng, n, slots)
                   for _ in range(int(rng.integers(1, 4))))
    local_pre = (np.stack([rng.permutation(slots) for _ in range(n)])
                 if rng.random() < 0.3 else None)
    local_post = (np.stack([rng.permutation(slots) for _ in range(n)])
                  if rng.random() < 0.3 else None)
    sched = CommSchedule(nranks=n, num_slots=slots, rounds=rounds,
                         name="fuzz.pallas", local_pre=local_pre,
                         local_post=local_post)
    return sched, topo, rng


def check_pallas_conformance(sched, topo, rng) -> None:
    """pallas == shardmap-compiled == rank-by-rank oracle, bitwise.

    The device-side single-kernel lowering (core.pallas_lowering) must
    agree with both the oracle and the compiled simulator on the same
    fuzzed schedule — one kernel launch for the whole round sequence,
    chunked or not."""
    from repro.core.pallas_lowering import get_pallas_exec

    n = sched.nranks
    buf = rng.integers(-8, 8, (n, sched.num_slots, 2)).astype(np.float32)
    want = SimTransport(n).run_reference(sched, buf)
    sim = executor.compile_schedule(sched, optimize=True,
                                    topo=topo).run_sim(buf)
    pex = get_pallas_exec(sched, topo=topo)
    got = np.asarray(pex.run(buf))
    assert np.array_equal(want, sim)
    assert want.tobytes() == got.tobytes()
    got2 = np.asarray(pex.run(buf, chunks=2))      # grid pipeline
    assert want.tobytes() == got2.tobytes()
    assert pex.launches == 2 and pex.jit_traces <= 2


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fuzzed_schedules_conform_on_pallas(seed):
    """Random bounded schedule: the single-kernel Pallas lowering is
    bit-exact vs the oracle and the compiled simulator."""
    check_pallas_conformance(*_small_fuzz_case(seed))


def test_pallas_fuzz_corpus_sweep():
    """Deterministic floor under the sampled Pallas property test: a
    fixed-seed corpus of bounded fuzz cases, every one bit-exact."""
    for seed in range(25):
        check_pallas_conformance(*_small_fuzz_case(seed))


# ---------------------------------------------------------------------------
# chaos campaigns over fuzzed schedules (core.chaos + core.resilient)
# ---------------------------------------------------------------------------


def _result_region(sched, out):
    out = np.asarray(out)
    rows = sched.result_slots
    return np.stack([out[r, sched.out_offset(r):
                         sched.out_offset(r) + rows]
                     for r in range(sched.nranks)])


def check_chaos_recovery(seed) -> None:
    """The metamorphic chaos oracle on a random schedule: under a
    seeded fault campaign the recovered result region is bitwise
    identical to the fault-free oracle, or a typed
    ``UnrecoverableError`` is raised — never a silent mismatch."""
    from repro.core import chaos
    from repro.core.resilient import (ResilienceOptions, ResilientExec,
                                      UnrecoverableError)

    rng = np.random.default_rng(seed)
    topo = rand_topology(rng)
    sched = rand_schedule(rng, topo.nranks)
    n = sched.nranks
    buf = rng.integers(-8, 8, (n, sched.num_slots, 2)).astype(np.float32)
    want = _result_region(sched, SimTransport(n).run_reference(sched, buf))

    campaign = ("corrupt", "fail", "hang", "mixed")[int(rng.integers(4))]
    persistent = rng.random() < 0.25
    plan = chaos.FaultPlan(
        int(rng.integers(2 ** 31)), campaign,
        times=None if persistent else int(rng.integers(1, 3)),
        max_faults=int(rng.integers(1, 3)), delay_s=0.002)
    transports = {"sim": chaos.wrap(SimTransport(n), plan)}
    if persistent and rng.random() < 0.5:
        # fault the fallback rung too: the typed-error path must fire
        # (or corruption must land outside the verified region)
        transports["reference"] = chaos.wrap(SimTransport(n), plan)
    ex = ResilientExec(
        sched, topo,
        options=ResilienceOptions(verify="full", max_retries=1,
                                  ladder=("sim", "reference"),
                                  backoff_s=1e-5),
        transports=transports)
    try:
        out, report = ex.run(buf)
    except UnrecoverableError as e:
        assert e.report.recovered_with is None     # typed, with the walk
        return
    assert _result_region(sched, out).tobytes() == want.tobytes(), (
        seed, campaign, persistent, report.summary())


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fuzzed_fault_campaigns_recover_or_raise(seed):
    """Random schedule x random seeded campaign: recovery is bitwise
    or the error is typed — the data-plane analogue of the
    bit-exactness conformance sweep above."""
    check_chaos_recovery(seed)


def test_chaos_fuzz_corpus_sweep():
    """Deterministic floor under the sampled chaos property test: a
    fixed-seed corpus of fault campaigns, every outcome bitwise-or-
    typed."""
    for seed in range(40):
        check_chaos_recovery(seed)


def test_armed_pass_strictly_beats_topology_free_on_staged_multipod():
    """The acceptance bound has teeth: on the width-staggered multi-pod
    staged allgather the armed pass merges rounds the equal-width rule
    must keep apart — strictly fewer rounds AND strictly lower modeled
    time on 2- and 4-pod topologies."""
    from repro.core.algorithms.staged import staggered_pod_allgather

    wins = 0
    for topo in (Topology(8, 4), Topology(16, 4)):
        sched = staggered_pod_allgather(topo)
        free = executor.compile_schedule(sched, optimize=True)
        armed = executor.compile_schedule(sched, optimize=True, topo=topo)
        rng = np.random.default_rng(0)
        buf = rng.integers(-8, 8,
                           (topo.nranks, sched.num_slots, 2)
                           ).astype(np.float32)
        want = SimTransport(topo.nranks).run_reference(sched, buf)
        assert np.array_equal(want, armed.run_sim(buf))
        for s in _PROBE_SLOT_BYTES:
            t_free = free.compiled_schedule.modeled_time(topo, s)
            t_armed = armed.compiled_schedule.modeled_time(topo, s)
            assert t_armed <= t_free * (1 + 1e-9)
        if (armed.rounds_after < free.rounds_after
                and armed.compiled_schedule.modeled_time(topo, 4096)
                < free.compiled_schedule.modeled_time(topo, 4096)):
            wins += 1
    assert wins == 2, "armed pass must strictly win on both topologies"
