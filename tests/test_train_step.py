"""Train-step integration on 1 CPU device: loss decreases over a few
steps for a smoke config, both MoE paths agree, remat preserves grads."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.data import DataPipeline, PipelineConfig
from repro.models import model as M
from repro.models import moe as moe_mod
from repro.train.step import TrainOptions, init_train_state, make_train_step

import pytest
from repro import compat


def _mesh1():
    return compat.make_mesh((1, 1), ("data", "model"))


def test_loss_decreases_smollm():
    cfg = configs.get_smoke("smollm-360m")
    opts = TrainOptions(dp_mode="fsdp", remat=False, peak_lr=3e-3,
                        warmup_steps=2, total_steps=40)
    state = init_train_state(jax.random.key(0), cfg, opts)
    pipe = DataPipeline(PipelineConfig(vocab_size=cfg.vocab_size,
                                       seq_len=32, global_batch=4))
    step = jax.jit(make_train_step(cfg, _mesh1(), opts))
    losses = []
    for i in range(12):
        state, m = step(state, pipe.batch(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.2, losses


def test_remat_matches_no_remat():
    cfg = configs.get_smoke("qwen3-14b")
    params = M.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                              cfg.vocab_size)
    labels = jnp.roll(toks, -1, 1)
    g1 = jax.grad(lambda p: M.lm_loss(p, cfg, toks, labels))(params)
    g2 = jax.grad(lambda p: M.lm_loss(p, cfg, toks, labels,
                                      remat=True))(params)
    # bf16 recompute reorders reductions; compare in aggregate (rel-L2)
    a = np.concatenate([np.asarray(x, np.float32).ravel()
                        for x in jax.tree.leaves(g1)])
    b = np.concatenate([np.asarray(x, np.float32).ravel()
                        for x in jax.tree.leaves(g2)])
    rel = np.linalg.norm(a - b) / (np.linalg.norm(a) + 1e-12)
    assert rel < 0.02, rel


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "jamba-1.5-large-398b"])
def test_moe_dense_vs_dropless(arch):
    """Dense-dispatch and capacity dispatch agree when capacity is
    generous (no drops)."""
    cfg = configs.get_smoke(arch)
    mcfg = cfg.moe
    key = jax.random.key(0)
    p = moe_mod.init(key, mcfg, cfg.d_model)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model),
                          jnp.float32) * 0.3
    dense = moe_mod.forward(p, mcfg, x, cfg.mlp_act)
    dropless = moe_mod.forward_dropless(p, mcfg, x, cfg.mlp_act,
                                        capacity_factor=float(
                                            mcfg.n_experts))
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(dropless, np.float32),
                               atol=2e-2, rtol=1e-2)


def test_moe_train_step_runs():
    cfg = configs.get_smoke("moonshot-v1-16b-a3b")
    opts = TrainOptions(dp_mode="fsdp", moe_mode="dropless", remat=True,
                        total_steps=10)
    state = init_train_state(jax.random.key(0), cfg, opts)
    pipe = DataPipeline(PipelineConfig(vocab_size=cfg.vocab_size,
                                       seq_len=16, global_batch=2))
    step = jax.jit(make_train_step(cfg, _mesh1(), opts))
    state, m = step(state, pipe.batch(0))
    assert np.isfinite(float(m["loss"]))
    assert int(state["step"]) == 1
