"""Fleet-scale tuning, end to end: probe -> drift heal -> elastic swap.

The acceptance scenario for the online-tuning loop, run deterministically
on the model substrate:

  1. a ``TuningDaemon`` baselines a mesh through the probe pass (tables
     keyed by measured ``lm[]`` geometry);
  2. a DCN link degrades mid-run (``LinkFault``, beta x16) -> the next
     tick detects drift on exactly that level, re-measures ONLY the
     affected table cells (asserted: strictly fewer than the table — no
     full re-tune), bumps the generation, and evicts exactly the stale
     geometry's compiled plans/executors;
  3. a pod drops -> the ``FaultTolerantLoop`` checkpoints, the elastic
     handler re-derives every registered schedule for the shrunk
     topology and swaps executors in place — no restart, and the
     re-derived schedules are bit-exact with a fresh build on the
     surviving topology.
"""
import numpy as np
import pytest

from repro.core import api, executor, tuner
from repro.core.algorithms import REGISTRY
from repro.core.linkprobe import model_timer
from repro.core.topology import (DCN_LINK, ICI_LINK, TopoLevel, Topology,
                                 flat_topology)
from repro.runtime.elastic import (ElasticScheduleSet, RankLossSignal,
                                   rank_remap, shrink_topology)
from repro.runtime.fault import FaultTolerantLoop, LinkFault
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.tuning_daemon import TuningDaemon


@pytest.fixture(autouse=True)
def _fresh_caches():
    executor.clear_cache()
    tuner.clear_cache()
    api._SCHEDULES.clear()
    yield
    executor.clear_cache()
    tuner.clear_cache()
    api._SCHEDULES.clear()


def _base():
    return Topology.from_levels([
        TopoLevel("dcn", 2, DCN_LINK, dcn=True),
        TopoLevel("ici", 4, ICI_LINK),
    ])


def _daemon(tmp_path, fault=None, **kw):
    base = _base()
    return TuningDaemon(
        base, path=tmp_path / "tuned.json", force_model=True,
        timer=model_timer(base, fault=fault), repeats=1, **kw)


# ---------------------------------------------------------------------------
# the daemon
# ---------------------------------------------------------------------------


def test_daemon_tables_key_on_measured_geometry(tmp_path):
    d = _daemon(tmp_path)
    assert ":lm[" in d.topo.fingerprint()
    assert d.table.fingerprint == tuner.substrate_fingerprint(
        d.topo, force_model=True)


def test_no_drift_tick_is_a_noop(tmp_path):
    d = _daemon(tmp_path)
    gen0 = d.table.generation
    report = d.tick(0)
    assert report is not None and not report.healed
    assert report.retuned_cells == () and report.affected_cells == ()
    assert report.invalidated == {"plans": 0, "executors": 0}
    assert report.generation == gen0
    assert report.old_fingerprint == report.new_fingerprint


def test_tick_respects_probe_cadence(tmp_path):
    d = _daemon(tmp_path, probe_every=3)
    assert d.tick(1) is None and d.tick(2) is None
    assert d.tick(3) is not None
    with pytest.raises(ValueError, match="probe_every"):
        TuningDaemon(_base(), probe_every=0)


def test_dcn_drift_heals_scoped_not_full(tmp_path):
    fault = LinkFault()
    d = _daemon(tmp_path, fault=fault)
    old_fp = d.topo.fingerprint()
    # warm a cached api plan under the healthy geometry so the tick's
    # eviction scope is observable on both caches
    api._schedule("allgather", "hierarchical", d.topo)

    fault.degrade(0, beta_scale=16.0)
    report = d.probe_and_heal(step=7)

    assert report.healed and report.drifted_levels == (0,)
    assert report.old_fingerprint == old_fp
    assert report.new_fingerprint == d.topo.fingerprint() != old_fp
    # scoped: a bandwidth collapse moves beta-dominated cells, never the
    # whole table — alpha-dominated small buckets stay untouched
    assert 0 < len(report.affected_cells) < report.total_cells
    assert 0 < len(report.retuned_cells) <= len(report.affected_cells)
    assert report.generation == 1
    # the stale geometry's compiled state is gone, old plan included
    assert report.invalidated["plans"] >= 1
    assert report.invalidated["executors"] >= 1
    assert not any(k[3] == old_fp for k in executor._CACHE)
    # the table now keys on the degraded measured geometry
    assert d.table.fingerprint == tuner.substrate_fingerprint(
        d.topo, force_model=True)
    # the degraded fabric re-confirmed is not drift: next tick no-ops
    report2 = d.probe_and_heal(step=8)
    assert not report2.healed and report2.generation == 1


def test_healed_topology_reprices_armed_executors(tmp_path):
    fault = LinkFault()
    d = _daemon(tmp_path, fault=fault)
    before = REGISTRY["allgather"]["hierarchical"](d.topo)
    t_before = before.modeled_time(d.topo, float(1 << 20))
    fault.degrade(0, beta_scale=16.0)
    d.probe_and_heal(step=1)
    after = REGISTRY["allgather"]["hierarchical"](d.topo)
    t_after = after.modeled_time(d.topo, float(1 << 20))
    # collectives armed against the healed topology see the collapsed
    # DCN bandwidth in their cost model
    assert t_after > 4.0 * t_before
    ex = executor.get_executor(after, topo=d.topo)
    assert ex is executor.get_executor(after, topo=d.topo)  # warm


def test_daemon_shares_heartbeat_with_straggler_monitor(tmp_path):
    mon = StragglerMonitor(num_hosts=4, threshold=1.5, window=4)
    for _ in range(4):
        for h in range(3):
            mon.record(h, 1.0)
        mon.record(3, 10.0)
    d = _daemon(tmp_path, monitor=mon)
    report = d.probe_and_heal(step=0)
    assert report.stragglers == (3,)
    assert mon.assignment[3] == []      # rebalanced on the same tick


def test_daemon_background_thread_probes(tmp_path):
    d = _daemon(tmp_path)
    d.start(interval_s=0.01)
    import time
    deadline = time.time() + 5.0
    while not d.reports and time.time() < deadline:
        time.sleep(0.01)
    d.stop()
    assert d.reports and not d.reports[0].healed
    d.stop()                            # idempotent


# ---------------------------------------------------------------------------
# shrink_topology / rank_remap
# ---------------------------------------------------------------------------


def test_shrink_whole_pod_preserves_hierarchy():
    topo = Topology.from_levels([
        TopoLevel("dcn", 3, DCN_LINK, dcn=True),
        TopoLevel("ici", 4, ICI_LINK)])
    new = shrink_topology(topo, range(4, 8))    # middle pod dies
    assert [(l.name, l.size) for l in new.levels] == \
           [("dcn", 2), ("ici", 4)]
    assert new.levels[0].dcn and new.levels[0].link == DCN_LINK
    assert rank_remap(topo, range(4, 8)) == {
        0: 0, 1: 1, 2: 2, 3: 3, 8: 4, 9: 5, 10: 6, 11: 7}


def test_shrink_to_single_pod_drops_the_level():
    new = shrink_topology(_base(), [0, 1, 2, 3])
    assert [(l.name, l.size) for l in new.levels] == [("ici", 4)]
    assert not new.levels[0].dcn


def test_shrink_inner_axis_slice():
    topo = _base()
    # ici coordinate 2 dies in BOTH pods -> ici shrinks 4 -> 3
    lost = [r for r in range(8) if topo.coords(r)[1] == 2]
    new = shrink_topology(topo, lost)
    assert [(l.name, l.size) for l in new.levels] == \
           [("dcn", 2), ("ici", 3)]


def test_shrink_irregular_loss_flattens():
    new = shrink_topology(_base(), [1, 6])      # no whole slice
    assert [(l.name, l.size) for l in new.levels] == [("ici", 6)]
    assert new.levels[0].link == ICI_LINK and not new.levels[0].dcn
    assert rank_remap(_base(), [1, 6])[7] == 5


@pytest.mark.parametrize("lost,msg", [
    ([], "empty"), ([9], "out of range"), ([-1], "out of range"),
    (list(range(8)), "all ranks"),
])
def test_shrink_rejects_bad_losses(lost, msg):
    with pytest.raises(ValueError, match=msg):
        shrink_topology(_base(), lost)


def test_rank_loss_signal_latches_and_clears():
    sig = RankLossSignal()
    assert sig.take() is None and not sig.pending
    sig.trigger(3)
    sig.trigger([1, 3, 2])
    assert sig.pending
    assert sig.take() == [1, 2, 3]
    assert sig.take() is None


# ---------------------------------------------------------------------------
# elastic re-derivation
# ---------------------------------------------------------------------------

_ENTRIES = {"grad_sync": ("allreduce", "ring_rs_ag"),
            "ep_dispatch": ("alltoall", "pairwise")}


def test_elastic_swap_is_bit_exact_with_fresh_build():
    topo = _base()
    es = ElasticScheduleSet(topo, _ENTRIES)
    old_fp = topo.fingerprint()
    report = es.shrink([0, 1, 2, 3])            # pod 0 dies

    assert report.lost_ranks == (0, 1, 2, 3)
    assert report.old_fingerprint == old_fp
    assert es.topo.nranks == 4
    assert report.rederived == ("ep_dispatch", "grad_sync")
    assert report.refit == () and report.generation == 1
    assert report.invalidated >= 2              # both warmed executors
    assert report.remap == {4: 0, 5: 1, 6: 2, 7: 3}
    for name, (coll, algo) in _ENTRIES.items():
        fresh = REGISTRY[coll][algo](es.topo)
        assert es.schedule_for(name).fingerprint() == fresh.fingerprint()
        assert es.executor_for(name) is executor.get_executor(
            fresh, topo=es.topo)                # swapped-in cache is warm
    assert not any(k[3] == old_fp for k in executor._CACHE)


def test_elastic_swap_refits_inapplicable_algorithms():
    es = ElasticScheduleSet(flat_topology(8),
                            {"ag": ("allgather", "recursive_doubling")})
    report = es.shrink([2, 5])                  # 6 ranks: not a power of 2
    assert report.refit == ("ag",)
    coll, algo = es.entries["ag"]
    assert coll == "allgather" and algo != "recursive_doubling"
    assert es.schedule_for("ag").fingerprint() == \
        REGISTRY[coll][algo](es.topo).fingerprint()


def test_rank_loss_swaps_schedules_without_restart(tmp_path):
    """The full no-restart path: mid-run rank loss -> checkpoint with
    the lost-rank manifest -> schedules re-derived for the shrunk
    topology -> the SAME loop keeps stepping to completion."""
    topo = _base()
    es = ElasticScheduleSet(topo, _ENTRIES)
    sig = RankLossSignal()
    swaps = []

    def on_rank_loss(state, step, lost):
        swaps.append((step, tuple(lost), es.shrink(lost)))
        return None                             # state/step_fn unchanged

    loop = FaultTolerantLoop(tmp_path, ckpt_every=100, rank_loss=sig,
                             on_rank_loss=on_rank_loss)
    state, done = loop.run(
        {"x": np.float32(0)}, lambda st, s: {"x": st["x"] + 1.0},
        start_step=0, num_steps=6,
        on_step=lambda step, st: sig.trigger([4, 5, 6, 7])
        if step == 3 else None)

    assert done == 6 and float(state["x"]) == 6.0   # never restarted
    assert len(swaps) == 1
    step, lost, report = swaps[0]
    assert step == 3 and lost == (4, 5, 6, 7)
    assert es.topo.nranks == 4 and report.generation == 1
    # the pre-swap state was persisted with the loss manifest
    from repro.checkpoint import restore_checkpoint
    tree, meta = restore_checkpoint(tmp_path, {"x": np.float32(0)}, step=3)
    assert meta["lost_ranks"] == [4, 5, 6, 7]
    assert float(tree["x"]) == 3.0
    # re-derived schedules match a fresh build on the survivors
    for name, (coll, algo) in _ENTRIES.items():
        assert es.schedule_for(name).fingerprint() == \
            REGISTRY[coll][algo](es.topo).fingerprint()


# ---------------------------------------------------------------------------
# the whole fleet loop: drift heal, then rank loss, one run
# ---------------------------------------------------------------------------


def test_fleet_end_to_end_drift_then_shrink(tmp_path):
    fault = LinkFault()
    d = _daemon(tmp_path, fault=fault)
    es = ElasticScheduleSet(d.topo, _ENTRIES)
    sig = RankLossSignal()
    events = []

    def on_step(step, state):
        if step == 2:
            fault.degrade(0, beta_scale=16.0)   # DCN collapses...
        report = d.tick(step)
        if report is not None and report.healed:
            events.append(("healed", step, report))
        if step == 4:
            sig.trigger([0, 1, 2, 3])           # ...then pod 0 dies

    def on_rank_loss(state, step, lost):
        events.append(("shrunk", step, es.shrink(lost)))
        return None

    loop = FaultTolerantLoop(tmp_path, ckpt_every=100, rank_loss=sig,
                             on_rank_loss=on_rank_loss)
    state, done = loop.run(
        {"x": np.float32(0)}, lambda st, s: {"x": st["x"] + 1.0},
        start_step=0, num_steps=6, on_step=on_step)

    assert done == 6 and float(state["x"]) == 6.0
    assert [e[0] for e in events] == ["healed", "shrunk"]
    _, heal_step, heal = events[0]
    assert heal_step == 2 and heal.drifted_levels == (0,)
    assert 0 < len(heal.affected_cells) < heal.total_cells
    _, shrink_step, swap = events[1]
    assert shrink_step == 4 and es.topo.nranks == 4
    for name, (coll, algo) in _ENTRIES.items():
        assert es.schedule_for(name).fingerprint() == \
            REGISTRY[coll][algo](es.topo).fingerprint()
