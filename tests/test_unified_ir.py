"""Unified-IR tests: one ``CommSchedule`` vocabulary for dense,
neighborhood, and partitioned paths; multi-level ``Topology``; tuner
coverage for the non-dense paths.

The SimTransport-vs-ShardMapTransport bit-exactness half (every
registered schedule x {flat, 2-pod, 2x4 torus, 3-level} x {float32,
bfloat16}) runs on forced host devices in
device_scripts/check_unified_ir.py via test_shardmap.py; here we cover
everything that needs no devices.  The staged (3+-level) builders'
dedicated conformance suite is tests/test_hierarchical.py.
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed: seeded fallback
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.core import selector, tuner
from repro.core.algorithms import REGISTRY, partitioned
from repro.core.plan import CommGraph, build_plan, run_sim
from repro.core.schedule import (CommRound, CommSchedule, make_round,
                                 validate_schedules_enabled)
from repro.core.topology import (DCN_LINK, ICI_LINK, TopoLevel, Topology,
                                 flat_topology, torus_topology)
from repro.core.transport import SimTransport


@pytest.fixture(autouse=True)
def _isolate_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path / "cache.json"))
    tuner.clear_cache()
    yield
    tuner.clear_cache()


# ---------------------------------------------------------------------------
# multi-level topology
# ---------------------------------------------------------------------------


def test_two_level_call_sites_unchanged():
    t = Topology(8, 4)
    assert t.fingerprint("TPU v5e") == "TPU_v5e:n8:rpp4"
    assert flat_topology(8).fingerprint("cpu") == "cpu:n8:rpp8"
    assert t.npods == 2 and t.pod(5) == 1 and t.local(5) == 1
    assert t.is_local(0, 3) and not t.is_local(0, 4)
    assert t.link(0, 3) is ICI_LINK and t.link(0, 4) is DCN_LINK
    assert Topology.from_fingerprint(t.fingerprint("cpu")) == t


def test_three_level_fingerprint_roundtrip():
    t = torus_topology(2, 4, 4)      # (dcn, torus_y, torus_x)
    fp = t.fingerprint()
    assert fp == "model:n32:rpp16:lv[dcn-2.torus_y-4.torus_x-4]"
    back = Topology.from_fingerprint(fp)
    assert back == t
    assert back.fingerprint() == fp
    assert [lv.name for lv in back.levels] == ["dcn", "torus_y", "torus_x"]
    assert back.levels[0].dcn and not back.levels[1].dcn


def test_digit_suffixed_axis_names_roundtrip():
    """Axis names ending in digits (e.g. a mesh axis "stage2") must not
    make the fingerprint ambiguous."""
    t = Topology.from_levels([("x1", 8), ("y", 2)])
    back = Topology.from_fingerprint(t.fingerprint("cpu"))
    assert back == t
    assert [lv.name for lv in back.levels] == ["x1", "y"]
    with pytest.raises(ValueError):   # "-" is the name/size separator
        TopoLevel("bad-name", 2, ICI_LINK)


def test_level_aware_link_classification():
    t = torus_topology(2, 4, 4)
    # same pod, same row -> innermost axis; same pod -> ICI; else DCN
    assert t.link_level(0, 1) == 2
    assert t.link_level(0, 4) == 1
    assert t.link_level(0, 16) == 0
    assert t.link(0, 16) is DCN_LINK and t.link(0, 5) is ICI_LINK
    # coords round-trip
    for r in range(t.nranks):
        assert t.rank_of(t.coords(r)) == r
    # pod helpers agree with the DCN prefix
    assert t.pod(17) == 1 and t.local(17) == 1 and t.rank(1, 1) == 17


def test_from_levels_validation():
    with pytest.raises(ValueError):   # DCN inside the pod
        Topology.from_levels([TopoLevel("ici", 4, ICI_LINK),
                              TopoLevel("dcn", 2, DCN_LINK, dcn=True)])
    with pytest.raises(ValueError):   # sizes don't multiply to nranks
        Topology(nranks=8, ranks_per_pod=4,
                 levels=(TopoLevel("ici", 3, ICI_LINK),))
    with pytest.raises(ValueError):
        Topology.from_fingerprint("not-a-fingerprint")


@settings(max_examples=25, deadline=None)
@given(npods=st.integers(1, 4), ty=st.integers(1, 4), tx=st.integers(1, 4))
def test_torus_fingerprint_roundtrip_property(npods, ty, tx):
    t = torus_topology(npods, ty, tx)
    assert t.nranks == npods * ty * tx
    assert t.ranks_per_pod == ty * tx
    back = Topology.from_fingerprint(t.fingerprint("cpu"))
    assert back == t


def test_round_time_per_edge_and_self_edges():
    t = Topology(8, 4)
    edges = [(0, 1), (4, 5)]
    assert t.round_time(edges, 1000) == t.round_time(edges, [1000, 1000])
    assert t.round_time([(2, 2)], 1 << 20) == 0.0   # on-chip copy
    # DCN edge dominates an equal-size ICI edge
    assert t.round_time([(0, 4)], 4096) > t.round_time([(0, 1)], 4096)


# ---------------------------------------------------------------------------
# dense algorithms on multi-level topologies (same IR, sim oracle)
# ---------------------------------------------------------------------------


TORUS = torus_topology(2, 2, 2)      # 3-level, 8 ranks


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_dense_algorithms_on_torus_topology(dtype):
    n = TORUS.nranks
    rng = np.random.default_rng(0)
    contrib = rng.integers(-8, 8, (n, 3)).astype(dtype)
    buf = np.zeros((n, n, 3), dtype)
    for r in range(n):
        buf[r, r] = contrib[r]
    for name, builder in REGISTRY["allgather"].items():
        out = SimTransport(n).run(builder(TORUS), buf)
        assert np.array_equal(
            out, np.broadcast_to(contrib, (n, n, 3))), name
    data = rng.integers(-8, 8, (n, n, 3)).astype(dtype)
    for name, builder in REGISTRY["allreduce"].items():
        out = SimTransport(n).run(builder(TORUS), data)
        assert np.array_equal(
            out, np.broadcast_to(data.astype(np.float64).sum(0)
                                 .astype(dtype), (n, n, 3))), name


def test_partitioned_schedule_matches_monolithic_shift():
    n = 8
    rng = np.random.default_rng(1)
    data = rng.normal(size=(n, 4, 3)).astype(np.float32)
    for name, builder in REGISTRY["partitioned"].items():
        sched = builder(flat_topology(n))
        chunks = sched.result_slots
        if 4 % chunks:
            continue
        buf = np.zeros((n, 2 * chunks, 4 // chunks, 3), np.float32)
        buf[:, :chunks] = data.reshape(n, chunks, 4 // chunks, 3)
        out = SimTransport(n).run(sched, buf)
        got = out[:, chunks:].reshape(n, 4, 3)
        want = np.roll(data, 1, axis=0)       # shift-by-one permutation
        assert np.array_equal(got, want), name


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31), aggregate=st.booleans())
def test_neighbor_plan_on_torus_topology(seed, aggregate):
    """Neighbor exchanges execute through the shared SimTransport on a
    3-level topology and match the direct per-edge gather oracle."""
    rng = np.random.default_rng(seed)
    n = TORUS.nranks
    graph = CommGraph.random(n, n_local=6, degree=3, rng=rng)
    plan = build_plan(graph, TORUS, aggregate=aggregate)
    values = [rng.normal(size=(6, 2)) for _ in range(n)]
    got = run_sim(plan, values)
    for r in range(n):
        segs = [values[s][idx] for s, idx in graph.recv_layout(r)]
        want = (np.concatenate(segs) if segs else np.zeros((0, 2)))
        np.testing.assert_allclose(got[r], want)


# ---------------------------------------------------------------------------
# schedule validation gating (REPRO_VALIDATE_SCHEDULES)
# ---------------------------------------------------------------------------


def _bad_round():
    # rank 1 is not a destination but carries a live scatter row
    return CommRound(perm=((0, 2),),
                     gather_idx=np.zeros((3, 1), np.int32),
                     scatter_idx=np.array([[-1], [0], [0]], np.int32))


def test_pow2_builders_raise_not_applicable():
    """Inapplicable builders raise the dedicated NotApplicable (so the
    CI smoke / bit-exactness sweeps can skip *only* those), while real
    invariant violations stay plain AssertionErrors and fail loud."""
    from repro.core.schedule import NotApplicable
    topo = Topology(12, 3)
    with pytest.raises(NotApplicable):
        REGISTRY["allgather"]["recursive_doubling"](topo)
    with pytest.raises(NotApplicable):
        REGISTRY["reduce_scatter"]["recursive_halving"](topo)
    assert issubclass(NotApplicable, AssertionError)


def test_validation_on_by_default_in_tests(monkeypatch):
    assert validate_schedules_enabled()
    with pytest.raises(AssertionError):
        _bad_round()


def test_validation_gated_off(monkeypatch):
    monkeypatch.setenv("REPRO_VALIDATE_SCHEDULES", "0")
    assert not validate_schedules_enabled()
    _bad_round()    # builds without the O(n^2) checks


# ---------------------------------------------------------------------------
# unified accounting
# ---------------------------------------------------------------------------


def test_self_edges_never_count_as_messages():
    rnd = make_round(2, [(0, 0), (1, 1)], {0: [0], 1: [0]},
                     {0: [1], 1: [1]})
    sched = CommSchedule(nranks=2, num_slots=2, rounds=(rnd,))
    topo = flat_topology(2)
    assert sched.message_count() == 0
    assert sched.byte_count(4) == 0
    assert sched.traffic(topo) == {"ici": 0, "dcn": 0,
                                   "msgs_ici": 0, "msgs_dcn": 0}
    assert sched.modeled_time(topo, 1024) == 0.0


def test_neighbor_traffic_identical_through_unified_accounting():
    """NeighborPlan.traffic == its schedule's generic traffic — the
    neighbor accounting no longer has a private implementation."""
    rng = np.random.default_rng(3)
    topo = Topology(12, 4)
    graph = CommGraph.random(12, n_local=5, degree=6, rng=rng)
    for aggregate in (False, True):
        plan = build_plan(graph, topo, aggregate=aggregate)
        assert plan.traffic(4) == plan.schedule.traffic(topo, 4)


# ---------------------------------------------------------------------------
# tuner coverage for the neighbor + partitioned paths
# ---------------------------------------------------------------------------


def test_autotune_persists_neighbor_and_partitioned_winners(tmp_path):
    topo = Topology(8, 4)
    path = tmp_path / "tuned.json"
    table = tuner.autotune(topo, path=path, force_model=True)
    assert tuner.NEIGHBOR in table.entries
    assert tuner.PARTITIONED in table.entries
    for rec in table.entries[tuner.NEIGHBOR].values():
        assert rec["best"] in tuner.NEIGHBOR_MODES
        assert set(rec["times"]) == set(tuner.NEIGHBOR_MODES)
    for rec in table.entries[tuner.PARTITIONED].values():
        assert rec["best"] in REGISTRY["partitioned"]
    # persisted: a fresh load resolves the neighbor winner
    tuner.clear_cache()
    name = tuner.tuned_select(tuner.NEIGHBOR, topo, 1 << 16, path=path)
    assert name in tuner.NEIGHBOR_MODES


def test_select_neighbor_policy_ladder(tmp_path):
    rng = np.random.default_rng(0)
    topo = Topology(8, 4)
    graph = CommGraph.random(8, n_local=8, degree=4, rng=rng,
                             dup_frac=0.8)
    # fixed: aggregate on multi-pod, standard on single-pod
    assert selector.select_neighbor(graph, topo, policy="fixed") \
        == "locality_aware"
    assert selector.select_neighbor(graph, flat_topology(8),
                                    policy="fixed") == "standard"
    # model: argmin over both compiled plans
    mode = selector.select_neighbor(graph, topo, policy="model")
    assert mode in selector.NEIGHBOR_MODES
    # tuned with a persisted table resolves from it
    path = tmp_path / "tuned.json"
    table = tuner.autotune(topo, path=path, force_model=True)
    want = table.lookup(tuner.NEIGHBOR,
                        graph.total_values() * 4)
    got = selector.select_neighbor(graph, topo, policy="tuned",
                                   tuned_table=table)
    assert got == want
    # tuned without any table falls back to the model choice
    tuner.clear_cache()
    assert selector.select_neighbor(graph, topo, policy="tuned") \
        == selector.select_neighbor(graph, topo, policy="model")


def test_build_plan_auto_mode_resolves_policy():
    rng = np.random.default_rng(7)
    topo = Topology(8, 4)
    graph = CommGraph.random(8, n_local=8, degree=4, rng=rng,
                             dup_frac=0.8)
    plan = build_plan(graph, topo, aggregate=None, policy="fixed")
    assert plan.name == "neighbor.locality_aware"
    plan = build_plan(graph, flat_topology(8), aggregate=None,
                      policy="fixed")
    assert plan.name == "neighbor.standard"
    plan = build_plan(graph, topo, aggregate=None, policy="model")
    assert plan.name in ("neighbor.standard", "neighbor.locality_aware")


def test_neighbor_guideline_violation_fires():
    entries = {tuner.NEIGHBOR: {"14": {
        "best": "standard", "nbytes": 16384,
        "times": {"standard": 1.0, "locality_aware": 5.0}}}}
    table = tuner.TunedTable(fingerprint="test:n8:rpp4", source="model",
                             entries=entries)
    out = tuner.verify_guidelines(table, Topology(8, 4))
    assert any("locality_aware slower" in v for v in out), out
    # and passes when the guideline holds
    entries[tuner.NEIGHBOR]["14"]["times"]["locality_aware"] = 0.5
    assert tuner.verify_guidelines(table, Topology(8, 4)) == []


def test_autotune_on_three_level_topology(tmp_path):
    table = tuner.autotune(TORUS, path=tmp_path / "t.json",
                           force_model=True)
    assert ":lv[dcn-2.torus_y-2.torus_x-2]" in table.fingerprint
    assert tuner.NEIGHBOR in table.entries


# ---------------------------------------------------------------------------
# api input validation (asserts -> ValueErrors)
# ---------------------------------------------------------------------------


def test_api_shape_errors_are_value_errors():
    from repro.core import api
    topo = flat_topology(8)
    x = jnp.zeros((7, 2), jnp.float32)    # 7 rows, 8 ranks
    with pytest.raises(ValueError, match="divisible by nranks=8"):
        api.mpix_alltoall(x, "r", algorithm="pairwise", topo=topo)
    with pytest.raises(ValueError, match="divisible by nranks=8"):
        api.mpix_reduce_scatter(x, "r", algorithm="ring", topo=topo)
