"""Neighborhood-collective plan tests (paper §2.2).

Oracle: direct numpy gather per edge.  Both plan modes must reproduce it
exactly; the aggregated mode must additionally satisfy the paper's
locality claims (unique values cross the DCN once; DCN messages collapse
to one per pod-pair stripe).
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed: seeded fallback
    from _hypothesis_stub import given, settings, st

from repro.core.plan import CommGraph, build_plan, run_sim
from repro.core.topology import Topology


def oracle(graph: CommGraph, values):
    out = []
    for r in range(graph.nranks):
        segs = [values[s][idx] for s, idx in graph.recv_layout(r)]
        out.append(np.concatenate(segs) if segs
                   else np.zeros((0,) + values[0].shape[1:]))
    return out


def _run_case(n, rpp, seed, aggregate, degree=None, n_local=8):
    rng = np.random.default_rng(seed)
    graph = CommGraph.random(n, n_local=n_local,
                             degree=degree or min(n - 1, 4), rng=rng)
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    plan = build_plan(graph, topo, aggregate=aggregate)
    values = [rng.normal(size=(n_local, 2)) for _ in range(n)]
    got = run_sim(plan, values)
    want = oracle(graph, values)
    for r in range(n):
        np.testing.assert_allclose(got[r], want[r])
    return graph, topo, plan


@pytest.mark.parametrize("aggregate", [False, True])
@settings(max_examples=30, deadline=None)
@given(shape=st.sampled_from([(n, rpp) for n in range(2, 17)
                              for rpp in range(1, n + 1) if n % rpp == 0]),
       seed=st.integers(0, 2**31))
def test_plan_matches_oracle(aggregate, shape, seed):
    _run_case(shape[0], shape[1], seed, aggregate)


def test_dcn_bytes_deduped():
    """Paper claim 2: aggregated DCN bytes == sum over (src, remote pod)
    of |unique indices|; strictly less than naive when duplicates exist."""
    rng = np.random.default_rng(7)
    n, rpp = 12, 4
    graph = CommGraph.random(n, n_local=6, degree=8, rng=rng, dup_frac=0.9)
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    std = build_plan(graph, topo, aggregate=False).traffic()
    agg = build_plan(graph, topo, aggregate=True).traffic()
    # naive: every remote edge's full index list crosses the DCN
    naive = sum(len(idx) for (s, d), idx in graph.edges.items()
                if not topo.is_local(s, d))
    uniq = {}
    for (s, d), idx in graph.edges.items():
        q = topo.pod(d)
        if q == topo.pod(s):
            continue
        uniq[(s, q)] = np.union1d(uniq.get((s, q), np.array([], int)), idx)
    deduped = sum(len(v) for v in uniq.values())
    assert std["dcn"] == naive
    assert agg["dcn"] == deduped
    assert deduped < naive  # dup_frac=0.9 guarantees real duplicates


def test_dcn_message_aggregation():
    """DCN messages collapse to <= 1 per ordered pod pair."""
    rng = np.random.default_rng(3)
    n, rpp = 16, 4
    graph = CommGraph.random(n, n_local=5, degree=10, rng=rng)
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    std = build_plan(graph, topo, aggregate=False).traffic()
    agg = build_plan(graph, topo, aggregate=True).traffic()
    Q = topo.npods
    assert agg["msgs_dcn"] <= Q * (Q - 1)
    assert agg["msgs_dcn"] < std["msgs_dcn"]


def test_no_duplicates_no_dedup_win():
    """Equality when every index list is already unique and disjoint."""
    n, rpp = 8, 4
    edges = {}
    for s in range(n):
        d = (s + rpp) % n  # always remote
        edges[(s, d)] = np.arange(4)
    graph = CommGraph(nranks=n, local_sizes=(4,) * n, edges=edges)
    topo = Topology(nranks=n, ranks_per_pod=rpp)
    std = build_plan(graph, topo, aggregate=False).traffic()
    agg = build_plan(graph, topo, aggregate=True).traffic()
    assert agg["dcn"] == std["dcn"]


def test_single_pod_falls_back_to_standard():
    rng = np.random.default_rng(0)
    graph = CommGraph.random(6, n_local=4, degree=3, rng=rng)
    topo = Topology(nranks=6, ranks_per_pod=6)
    plan = build_plan(graph, topo, aggregate=True)
    assert plan.name == "neighbor.standard"
