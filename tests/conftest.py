"""Shared test configuration.

Schedule-invariant validation is O(nranks^2) python per round and is
off by default (large-mesh plan builds must not pay it); the test suite
always runs with it on so every schedule any test builds is checked.
"""
import os

os.environ.setdefault("REPRO_VALIDATE_SCHEDULES", "1")
