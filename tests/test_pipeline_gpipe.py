"""core/pipeline.py unit + property tests (single device).

The multi-device gpipe forward/AD equivalence runs from
``tests/device_scripts/check_partitioned.py``; here we cover the
degenerate 1-stage pipeline against a sequential oracle, the
stage->layer partition properties, and the GPipe wavefront expressed
in the shared ``CommSchedule``/``ComputeEvent`` vocabulary — the
generic makespan pass must reproduce the classic pipeline cost with no
GPipe-specific pricing.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except Exception:                                  # pragma: no cover
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).parent))
    from _hypothesis_stub import given, settings, st

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import executor, pipeline as pl
from repro.core.topology import flat_topology


@pytest.fixture(autouse=True)
def _fresh_executor_cache():
    executor.clear_cache()
    yield
    executor.clear_cache()


def test_gpipe_single_stage_matches_sequential():
    """S=1 degenerates to a per-microbatch map: same numbers as calling
    the stage directly (pipelined == unpipelined oracle)."""
    mesh = compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(0)
    W = rng.normal(size=(5, 5)).astype(np.float32) * 0.3
    b = rng.normal(size=(5,)).astype(np.float32)
    xs = rng.normal(size=(6, 4, 5)).astype(np.float32)

    def stage_fn(p, h):
        return jnp.tanh(h @ p[0] + p[1])

    from jax.sharding import PartitionSpec as P
    f = jax.jit(compat.shard_map(
        lambda v: pl.gpipe(stage_fn, (W, b), v, "data"),
        mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False))
    with compat.set_mesh(mesh):
        got = np.asarray(f(xs))
    want = np.tanh(xs @ W + b)
    np.testing.assert_allclose(got, want, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(n_layers=st.integers(1, 64), n_stages=st.integers(1, 16))
def test_stage_params_spec_properties(n_layers, n_stages):
    if n_stages > n_layers:
        n_stages = n_layers
    spans = pl.stage_params_spec(n_layers, n_stages)
    assert len(spans) == n_stages
    # contiguous partition of [0, n_layers)
    flat = [i for r in spans for i in r]
    assert flat == list(range(n_layers))
    sizes = [len(r) for r in spans]
    assert max(sizes) - min(sizes) <= 1
    # the remainder lands on the LAST stages (they also hold the head)
    assert sizes == sorted(sizes)


def test_gpipe_wavefront_schedule_shape():
    M, S = 6, 4
    sched = pl.gpipe_wavefront_schedule(M, S, 1e-3)
    T = M + S - 1
    assert len(sched.rounds) == T
    assert len(sched.compute_events) == T
    assert all(ev.seconds == 1e-3 and ev.after_round == t
               for t, ev in enumerate(sched.compute_events))
    with pytest.raises(ValueError):
        pl.gpipe_wavefront_schedule(0, 4, 1e-3)
    with pytest.raises(ValueError):
        pl.gpipe_wavefront_schedule(4, 0, 1e-3)


def test_gpipe_wavefront_makespan_is_pipelined():
    """The generic pass prices the wavefront like a software pipeline:
    tick t's compute overlaps shift t+1 (consecutive shifts are RAW on
    the in-flight slot, so rounds stay serialized; events slide one
    group right).  Strictly better than the serial sum, and >= the
    trivial lower bound max(total shift, total compute)."""
    M, S = 8, 4
    topo = flat_topology(S)
    tick_s = 1e-3
    sched = pl.gpipe_wavefront_schedule(M, S, tick_s)
    ex = executor.get_executor(sched, topo=topo)
    T = M + S - 1
    slot = float(1 << 16)
    shift = ex.compiled_schedule.modeled_time(topo, slot) / len(
        ex.compiled_schedule.rounds)
    mk = ex.makespan(slot)
    serial = T * (shift + tick_s)
    assert mk <= serial * (1 + 1e-9)
    assert mk < serial * (1 - 1e-3)            # real overlap
    assert mk >= max(T * shift, T * tick_s) * (1 - 1e-9)
    # classic pipeline cost: first shift exposed, then max(shift, tick)
    # per remaining tick, then the last tick's compute exposed
    want = shift + (T - 1) * max(shift, tick_s) + tick_s
    assert mk == pytest.approx(want, rel=1e-6)
