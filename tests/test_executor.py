"""Persistent-executor compilation tests (core.executor).

The contract: the compiled path — baked tables, vectorized simulator
rounds, local_pre folding, round compaction/fusion, scratch-zero
elision — is bit-exact with the historical rank-by-rank reference
executor for every registered schedule, every topology class, float32
and bfloat16; fusion is *legal* exactly per ``schedule.can_fuse``; and
the process-level executor cache hands back one compiled object per
(schedule content, flags).

The shard_map half of the sweep (fused ppermute lowering vs the same
reference) and the jit trace-count proof run on forced host devices in
tests/device_scripts/check_executor.py via test_shardmap.py.
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed: seeded fallback
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.core import executor
from repro.core.algorithms import REGISTRY
from repro.core.plan import CommGraph, build_plan
from repro.core.schedule import (CommRound, CommSchedule, NotApplicable,
                                 can_fuse, make_round)
from repro.core.topology import Topology, flat_topology, torus_topology
from repro.core.transport import SimTransport


@pytest.fixture(autouse=True)
def _fresh_executor_cache():
    executor.clear_cache()
    yield
    executor.clear_cache()


TOPOS = {
    "flat": flat_topology(8),
    "2pod": Topology(8, 4),
    "3lvl": torus_topology(2, 2, 2),
}
DTYPES = {"float32": np.float32, "bfloat16": jnp.bfloat16}


def _all_schedules(topo):
    out = []
    for coll, algos in REGISTRY.items():
        for name, builder in algos.items():
            try:
                out.append((f"{coll}.{name}", builder(topo)))
            except NotApplicable:
                continue
    rng = np.random.default_rng(7)
    graph = CommGraph.random(topo.nranks, n_local=6, degree=4, rng=rng,
                             dup_frac=0.8)
    for aggregate in (False, True):
        plan = build_plan(graph, topo, aggregate=aggregate)
        out.append((plan.name, plan.schedule))
    return out


# ---------------------------------------------------------------------------
# bit-exactness: fused+compiled == unfused reference (full sim sweep)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo_name", sorted(TOPOS))
@pytest.mark.parametrize("dt_name", sorted(DTYPES))
def test_compiled_bit_exact_with_reference(topo_name, dt_name):
    topo, dtype = TOPOS[topo_name], DTYPES[dt_name]
    n = topo.nranks
    rng = np.random.default_rng(0)
    tr = SimTransport(n)
    for label, sched in _all_schedules(topo):
        buf = rng.integers(-8, 8,
                           (n, sched.num_slots, 3)).astype(dtype)
        want = tr.run_reference(sched, buf)
        got_fused = tr.run(sched, buf)            # compiled + optimized
        got_plain = executor.compile_schedule(
            sched, optimize=False).run_sim(buf)   # compiled, no peephole
        assert np.array_equal(want, got_fused), (topo_name, label, dt_name)
        assert np.array_equal(want, got_plain), (topo_name, label, dt_name)


def test_reference_buffer_not_mutated():
    topo = TOPOS["2pod"]
    sched = REGISTRY["allreduce"]["ring_rs_ag"](topo)
    buf = np.random.default_rng(1).normal(
        size=(8, sched.num_slots, 2)).astype(np.float32)
    keep = buf.copy()
    SimTransport(8).run(sched, buf)
    assert np.array_equal(buf, keep)


# ---------------------------------------------------------------------------
# fusion legality (schedule.can_fuse) — satellite property tests
# ---------------------------------------------------------------------------


def _rand_round(rng, nranks, num_slots, *, reduce=False, forbid=None):
    """A random valid round: random partial matching + random tables."""
    ranks = list(range(nranks))
    m = int(rng.integers(1, nranks // 2 + 1))
    srcs = list(rng.permutation(ranks)[:m])
    dsts = list(rng.permutation(ranks)[:m])
    edges, send, recv = [], {}, {}
    for s, d in zip(srcs, dsts):
        k = int(rng.integers(1, 3))
        send[s] = list(rng.integers(0, num_slots, k))
        recv[d] = list(rng.permutation(num_slots)[:k])  # distinct targets
        edges.append((int(s), int(d)))
    return make_round(nranks, edges, send, recv, reduce=reduce)


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**31), reduce_a=st.booleans(),
       reduce_b=st.booleans())
def test_can_fuse_rejects_by_rule(seed, reduce_a, reduce_b):
    """can_fuse must be exactly: no reduce, disjoint srcs, disjoint
    dsts, and no scatter(i) -> gather(i+1) aliasing."""
    rng = np.random.default_rng(seed)
    n, slots = 8, 5
    a = _rand_round(rng, n, slots, reduce=reduce_a)
    b = _rand_round(rng, n, slots, reduce=reduce_b)
    share_src = bool(a.src_set & b.src_set)
    share_dst = bool(a.dst_set & b.dst_set)
    alias = any(a.writes(r) & b.reads(r)
                for r in a.dst_set & b.src_set)
    expect = not (reduce_a or reduce_b or share_src or share_dst or alias)
    assert can_fuse(a, b) == expect


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_legal_fusion_is_semantics_preserving(seed):
    """Whenever can_fuse says yes, executing the two rounds as one
    merged round is bit-identical to executing them in sequence."""
    rng = np.random.default_rng(seed)
    n, slots = 8, 5
    a = _rand_round(rng, n, slots)
    b = _rand_round(rng, n, slots)
    if not can_fuse(a, b):
        return
    k = max(a.k, b.k)

    def pad(x):
        out = np.full((n, k), -1, np.int64)
        out[:, : x.shape[1]] = x
        return out

    ga, sa, gb, sb = (pad(a.gather_idx), pad(a.scatter_idx),
                      pad(b.gather_idx), pad(b.scatter_idx))
    in_b = np.zeros(n, bool)
    for s, d in b.perm:
        in_b[s] = True
        in_b[d] = True
    # disjoint src/dst sets => per-rank row merge is well-defined for
    # gather (srcs) and scatter (dsts) separately
    gather = ga.copy()
    scatter = sa.copy()
    for s, _ in b.perm:
        gather[s] = gb[s]
    for _, d in b.perm:
        scatter[d] = sb[d]
    merged = CommRound(perm=a.perm + b.perm, gather_idx=gather,
                       scatter_idx=scatter, reduce=False)
    tr = SimTransport(n)
    buf = rng.normal(size=(n, slots, 2)).astype(np.float32)
    seq = tr.run_reference(
        CommSchedule(nranks=n, num_slots=slots, rounds=(a, b)), buf)
    one = tr.run_reference(
        CommSchedule(nranks=n, num_slots=slots, rounds=(merged,)), buf)
    assert np.array_equal(seq, one)


def test_rejected_fusions_concrete_cases():
    n, slots = 4, 4
    base = make_round(n, [(0, 1)], {0: [0]}, {1: [2]})
    # shared src
    assert not can_fuse(base, make_round(n, [(0, 2)], {0: [1]}, {2: [3]}))
    # shared dst
    assert not can_fuse(base, make_round(n, [(2, 1)], {2: [1]}, {1: [3]}))
    # reduce involved
    assert not can_fuse(base, make_round(n, [(2, 3)], {2: [1]}, {3: [3]},
                                         reduce=True))
    # scatter of round i aliases gather of round i+1 (rank 1 writes row 2
    # then reads it): must execute in two rounds
    assert not can_fuse(base, make_round(n, [(1, 3)], {1: [2]}, {3: [3]}))
    # fully legal: disjoint srcs/dsts, no aliasing
    legal = make_round(n, [(2, 3)], {2: [1]}, {3: [3]})
    assert can_fuse(base, legal)


# ---------------------------------------------------------------------------
# fusion cuts rounds on staged multi-pod schedules
# ---------------------------------------------------------------------------


from repro.core.algorithms.staged import serialized_pod_allgather


def test_fusion_overlaps_disjoint_pod_stages():
    """The serialized two-pod staged allgather fuses back to the
    parallel_fuse'd round count (2*(R-1) -> R-1) bit-exactly, and the
    fused schedule matches the registered hierarchical builder's stage-A
    depth."""
    topo = Topology(8, 4)
    sched = serialized_pod_allgather(topo)
    ex = executor.get_executor(sched)
    assert ex.rounds_before == 6          # 2 pods x (4-1) ring rounds
    assert ex.rounds_after == 3           # pod stages fully overlapped
    rng = np.random.default_rng(3)
    buf = rng.normal(size=(8, 8, 2)).astype(np.float32)
    tr = SimTransport(8)
    assert np.array_equal(tr.run_reference(sched, buf),
                          tr.run(sched, buf))
    # and on a 4-pod topology: 4 serialized stages -> one fused stage
    topo4 = Topology(12, 3)
    ex4 = executor.get_executor(serialized_pod_allgather(topo4))
    assert ex4.rounds_before == 8 and ex4.rounds_after == 2


def test_fusion_never_worsens_modeled_time():
    """Cost-safety of the all-or-nothing drain rule: across the whole
    corpus (incl. real multi-pod staged neighbor plans), compilation
    never raises the alpha-beta modeled time — partial migrations that
    would redistribute edges without deleting a ppermute are rolled
    back.  The already-round-minimal colored neighbor plans therefore
    pass through unchanged."""
    for topo in (Topology(12, 3), Topology(8, 4)):
        for label, sched in _all_schedules(topo):
            ex = executor.get_executor(sched)
            before = sched.modeled_time(topo, 4096)
            after = ex.compiled_schedule.modeled_time(topo, 4096)
            assert after <= before * 1.0001, (label, before, after)
    # and a plan whose coloring is already tight keeps its round count
    rng = np.random.default_rng(0)
    graph = CommGraph.random(12, n_local=6, degree=4, rng=rng,
                             dup_frac=0.8)
    plan = build_plan(graph, Topology(12, 3), aggregate=True)
    assert plan.num_compiled_rounds == plan.num_rounds


def test_armed_corpus_never_worse_than_topology_free():
    """Acceptance invariant of the cost-model-armed pass: over the full
    registry x {flat, 2-pod, 3-level} corpus the armed compilation's
    modeled time is <= the topology-free pass AND <= the unoptimized
    schedule at alpha-dominated, mixed, and beta-dominated slot sizes —
    with bit-exact execution."""
    rng = np.random.default_rng(2)
    for topo in TOPOS.values():
        tr = SimTransport(topo.nranks)
        for label, sched in _all_schedules(topo):
            armed = executor.get_executor(sched, topo=topo)
            free = executor.get_executor(sched)
            buf = rng.integers(-8, 8, (topo.nranks, sched.num_slots, 2)
                               ).astype(np.float32)
            assert np.array_equal(tr.run_reference(sched, buf),
                                  armed.run_sim(buf)), label
            for s in (1, 4096, 1 << 20):
                t_orig = sched.modeled_time(topo, s)
                t_free = free.compiled_schedule.modeled_time(topo, s)
                t_armed = armed.compiled_schedule.modeled_time(topo, s)
                assert t_armed <= t_free * 1.0001, (label, s)
                assert t_armed <= t_orig * 1.0001, (label, s)


def test_armed_fuses_staggered_multipod_stages():
    """The width-staggered serialized staged allgather: the topology-
    free equal-width rule can only partially re-fuse it; the armed pass
    overlaps the wide Bruck rounds with the ring rounds (unequal-width
    whole-round merges) — strictly fewer rounds, strictly lower modeled
    time, bit-exact."""
    from repro.core.algorithms.staged import staggered_pod_allgather

    topo = Topology(8, 4)
    sched = staggered_pod_allgather(topo)
    free = executor.get_executor(sched)
    armed = executor.get_executor(sched, topo=topo)
    assert sched.num_rounds == 5          # 3 ring + 2 bruck rounds
    assert free.rounds_after == 4         # only the w=1 bruck round fuses
    assert armed.rounds_after == 3        # w=2 bruck round overlaps too
    assert armed.armed_merged_rounds >= 1
    assert (armed.compiled_schedule.modeled_time(topo, 4096)
            < free.compiled_schedule.modeled_time(topo, 4096))
    rng = np.random.default_rng(4)
    buf = rng.integers(-8, 8, (8, 8, 2)).astype(np.float32)
    tr = SimTransport(8)
    assert np.array_equal(tr.run_reference(sched, buf), armed.run_sim(buf))


def test_duplicate_reduce_targets_accumulate_like_reference(monkeypatch):
    """With validation off, a reduce round may carry duplicate live
    scatter targets; the vectorized path must fall back to unbuffered
    accumulation and still match the reference loop."""
    monkeypatch.setenv("REPRO_VALIDATE_SCHEDULES", "0")
    n = 3
    gi = np.array([[0, 1], [-1, -1], [-1, -1]], np.int64)
    si = np.array([[-1, -1], [1, 1], [-1, -1]], np.int64)  # dup target 1
    rnd = CommRound(perm=((0, 1),), gather_idx=gi, scatter_idx=si,
                    reduce=True)
    sched = CommSchedule(nranks=n, num_slots=2, rounds=(rnd,))
    rng = np.random.default_rng(11)
    buf = rng.normal(size=(n, 2, 2)).astype(np.float32)
    tr = SimTransport(n)
    assert np.array_equal(tr.run_reference(sched, buf),
                          tr.run(sched, buf))


def test_reduce_rounds_are_never_fused():
    """Reduce rounds act as barriers: disjoint-pod REDUCE stages must
    stay separate (accumulation order is bit-exactness-critical)."""
    n = 8
    rounds = []
    for members in ([0, 1], [4, 5]):
        edges = [(members[0], members[1])]
        rounds.append(make_round(n, edges, {members[0]: [0]},
                                 {members[1]: [0]}, reduce=True))
    sched = CommSchedule(nranks=n, num_slots=2, rounds=tuple(rounds))
    ex = executor.get_executor(sched)
    assert ex.rounds_after == ex.rounds_before == 2


# ---------------------------------------------------------------------------
# local_pre folding
# ---------------------------------------------------------------------------


def test_bruck_local_pre_is_folded():
    sched = REGISTRY["alltoall"]["bruck"](flat_topology(8))
    assert sched.local_pre is not None
    ex = executor.get_executor(sched)
    assert ex.pre_folded and ex.local_pre is None
    assert ex.local_post is not None
    # unoptimized executor keeps the pre-gather
    plain = executor.compile_schedule(sched, optimize=False)
    assert not plain.pre_folded and plain.local_pre is not None


def test_non_bijective_local_pre_not_folded():
    n = 4
    rnd = make_round(n, [(0, 1)], {0: [0]}, {1: [2]})
    pre = np.zeros((n, 3), np.int64)        # all rows read slot 0
    sched = CommSchedule(nranks=n, num_slots=3, rounds=(rnd,),
                         local_pre=pre)
    ex = executor.get_executor(sched)
    assert not ex.pre_folded and ex.local_pre is not None
    rng = np.random.default_rng(5)
    buf = rng.normal(size=(n, 3, 2)).astype(np.float32)
    tr = SimTransport(n)
    assert np.array_equal(tr.run_reference(sched, buf),
                          tr.run(sched, buf))


# ---------------------------------------------------------------------------
# executor cache — satellite tests
# ---------------------------------------------------------------------------


def test_cache_one_executor_per_schedule_content():
    topo = flat_topology(8)
    s1 = REGISTRY["allgather"]["ring"](topo)
    s2 = REGISTRY["allgather"]["ring"](topo)      # independent build
    assert s1 is not s2
    assert s1.fingerprint() == s2.fingerprint()
    ex1 = executor.get_executor(s1)
    assert executor.get_executor(s1) is ex1       # same object
    assert executor.get_executor(s2) is ex1       # content-keyed
    stats = executor.cache_stats()
    assert stats["size"] == 1
    assert stats["misses"] == 1 and stats["hits"] == 2
    # a different schedule compiles separately
    other = REGISTRY["allgather"]["bruck"](topo)
    assert other.fingerprint() != s1.fingerprint()
    assert executor.get_executor(other) is not ex1
    assert executor.cache_stats()["size"] == 2


def test_cache_invalidated_by_validation_flag(monkeypatch):
    sched = REGISTRY["allgather"]["ring"](flat_topology(8))
    ex_on = executor.get_executor(sched)
    monkeypatch.setenv("REPRO_VALIDATE_SCHEDULES", "0")
    ex_off = executor.get_executor(sched)
    assert ex_on is not ex_off
    monkeypatch.setenv("REPRO_VALIDATE_SCHEDULES", "1")
    assert executor.get_executor(sched) is ex_on


@settings(max_examples=20, deadline=None)
@given(pair=st.sampled_from([("flat", "2pod"), ("flat", "3lvl"),
                             ("2pod", "3lvl")]),
       algo=st.sampled_from(["ring", "bruck"]))
def test_cache_keyed_by_topology_distinct_entries_same_numerics(pair, algo):
    """Two distinct topologies compiling the SAME schedule content must
    occupy distinct cache entries (per-geometry armed compilations
    never collide) — and topology-armed vs topology-free likewise —
    while every entry stays bit-identical to the oracle."""
    executor.clear_cache()
    a_name, b_name = pair
    topo_a, topo_b = TOPOS[a_name], TOPOS[b_name]
    sched = REGISTRY["allgather"][algo](flat_topology(8))
    ex_none = executor.get_executor(sched)
    ex_a = executor.get_executor(sched, topo=topo_a)
    ex_b = executor.get_executor(sched, topo=topo_b)
    assert ex_none is not ex_a and ex_none is not ex_b
    assert ex_a is not ex_b
    assert executor.cache_stats()["size"] == 3
    # repeat lookups hit the same per-geometry entries
    assert executor.get_executor(sched, topo=topo_a) is ex_a
    assert executor.get_executor(sched, topo=topo_b) is ex_b
    assert executor.get_executor(sched) is ex_none
    assert executor.cache_stats()["size"] == 3
    # identical numerics across all three compilations
    rng = np.random.default_rng(9)
    buf = rng.integers(-8, 8, (8, sched.num_slots, 2)).astype(np.float32)
    want = SimTransport(8).run_reference(sched, buf)
    for ex in (ex_none, ex_a, ex_b):
        assert np.array_equal(want, ex.run_sim(buf))


def test_cache_same_geometry_different_instances_share_entry():
    """The cache keys on the topology's geometry fingerprint, not
    object identity: two equal Topology instances share one executor;
    a same-shape topology with different link models does not."""
    from repro.core.topology import LinkModel, TopoLevel

    sched = REGISTRY["allgather"]["ring"](flat_topology(8))
    t1, t2 = Topology(8, 4), Topology(8, 4)
    assert executor.get_executor(sched, topo=t1) is \
        executor.get_executor(sched, topo=t2)
    slow_dcn = Topology(
        8, 4, levels=(TopoLevel("dcn", 2, LinkModel(1e-4, 1e-7), True),
                      TopoLevel("ici", 4)))
    assert executor.get_executor(sched, topo=slow_dcn) is not \
        executor.get_executor(sched, topo=t1)


def test_cache_invalidated_by_optimize_flag(monkeypatch):
    sched = REGISTRY["allgather"]["ring"](flat_topology(8))
    ex_opt = executor.get_executor(sched)
    monkeypatch.setenv("REPRO_EXEC_OPTIMIZE", "0")
    ex_plain = executor.get_executor(sched)
    assert ex_plain is not ex_opt and not ex_plain.optimize


def test_sim_run_counter_and_stats():
    sched = REGISTRY["allreduce"]["ring_rs_ag"](flat_topology(8))
    tr = SimTransport(8)
    buf = np.ones((8, sched.num_slots, 1), np.float32)
    tr.run(sched, buf)
    tr.run(sched, buf)
    ex = executor.get_executor(sched)
    assert ex.sim_runs == 2
    st_ = ex.stats()
    assert st_["rounds_before"] == sched.num_rounds
    assert st_["trace_count"] == 0


# ---------------------------------------------------------------------------
# schedule fingerprint
# ---------------------------------------------------------------------------


def test_fingerprint_ignores_name_tracks_content():
    topo = flat_topology(8)
    a = REGISTRY["allgather"]["ring"](topo)
    import dataclasses
    renamed = dataclasses.replace(a, name="something.else")
    assert renamed.fingerprint() == a.fingerprint()
    # content drift (one table entry) changes the fingerprint
    rnd = a.rounds[0]
    g = rnd.gather_idx.copy()
    g[0, 0] = (g[0, 0] + 1) % a.num_slots
    mutated = dataclasses.replace(
        a, rounds=(dataclasses.replace(rnd, gather_idx=g),) + a.rounds[1:])
    assert mutated.fingerprint() != a.fingerprint()


# ---------------------------------------------------------------------------
# byte_count precedence — satellite regression
# ---------------------------------------------------------------------------


def test_byte_count_honors_slot_bytes_with_payload():
    """A round carrying both ``payload`` and schedule-level
    ``slot_bytes`` must bill the per-slot true byte widths, not
    ``slots * elem_bytes``."""
    n = 2
    gi = np.array([[0, 1], [-1, -1]], np.int64)
    si = np.array([[-1, -1], [0, 1]], np.int64)
    rnd = CommRound(perm=((0, 1),), gather_idx=gi, scatter_idx=si,
                    payload=np.array([2, 0], np.int64))
    slot_bytes = np.array([100, 7], np.int64)
    sched = CommSchedule(nranks=n, num_slots=2, rounds=(rnd,),
                         slot_bytes=slot_bytes)
    # slot widths win over the elem_bytes estimate: 100 + 7
    assert sched.byte_count(4) == 107
    # payload truncates padded gather entries: only the first true slot
    rnd_pad = CommRound(perm=((0, 1),), gather_idx=gi, scatter_idx=si,
                        payload=np.array([1, 0], np.int64))
    sched_pad = CommSchedule(nranks=n, num_slots=2, rounds=(rnd_pad,),
                             slot_bytes=slot_bytes)
    assert sched_pad.byte_count(4) == 100
    # without slot_bytes the historical payload * elem_bytes path holds
    sched_plain = CommSchedule(nranks=n, num_slots=2, rounds=(rnd,))
    assert sched_plain.byte_count(4) == 8
