"""Regression tests for the runtime fault-path fixes.

Three production-shaped bugs, each with the scenario that exposed it:

  * a straggler that *recovers* must reclaim its home data shard — the
    old rebalance only ever moved shards away, stranding a transiently
    slow host shard-less with its donor permanently overloaded;
  * ``PreemptionSignal(install_handlers=True)`` must latch BOTH
    SIGTERM (cluster schedulers) and SIGINT (interactive runs), chain a
    previously installed callable handler, and *not* chain the default
    SIGINT handler (which would raise KeyboardInterrupt and abort the
    final checkpoint the latch exists to protect);
  * preemption landing exactly on a periodic checkpoint boundary must
    commit exactly ONE checkpoint for that step, not two (the second
    save doubled checkpoint I/O at the worst possible moment and raced
    the in-flight async write).
"""
import signal

import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint
from repro.runtime.fault import FaultTolerantLoop, PreemptionSignal
from repro.runtime.straggler import StragglerMonitor


# ---------------------------------------------------------------------------
# S1: straggler recovery
# ---------------------------------------------------------------------------


def _feed(mon, host, t, n=16):
    for _ in range(n):
        mon.record(host, t)


def test_recovered_straggler_reclaims_home_shard():
    mon = StragglerMonitor(num_hosts=4, threshold=1.5, window=8)
    for h in range(3):
        _feed(mon, h, 1.0, n=8)
    _feed(mon, 3, 10.0, n=8)
    assign = mon.rebalance()
    assert mon.stragglers() == [3]
    assert assign[3] == []
    donor = next(h for h, s in assign.items() if 3 in s)

    # host 3 recovers: fast samples push its windowed median back under
    # threshold, and the next rebalance hands the shard home
    _feed(mon, 3, 1.0, n=8)
    assert mon.stragglers() == []
    assign = mon.rebalance()
    assert assign[3] == [3]
    assert 3 not in assign[donor]
    assert sorted(s for shards in assign.values()
                  for s in shards) == [0, 1, 2, 3]


def test_recovery_runs_even_with_no_current_stragglers():
    """The reclaim pass must not hide behind the no-stragglers early
    return: by the time the slow host looks healthy again there may be
    nothing flagged, and that is exactly when it needs its shard back."""
    mon = StragglerMonitor(num_hosts=3, threshold=1.5, window=4)
    # shard 2 was evicted to host 0 in some earlier epoch
    mon.assignment = {0: [0, 2], 1: [1], 2: []}
    for h in range(3):
        _feed(mon, h, 1.0, n=4)
    assert mon.stragglers() == []
    assign = mon.rebalance()
    assert assign == {0: [0], 1: [1], 2: [2]}


def test_unknown_host_stays_evicted():
    """No estimate yet != healthy: a host that has not reported step
    times keeps its shard with the donor until it proves itself."""
    mon = StragglerMonitor(num_hosts=3, threshold=1.5, window=4)
    mon.assignment = {0: [0, 2], 1: [1], 2: []}
    _feed(mon, 0, 1.0, n=4)
    _feed(mon, 1, 1.0, n=4)
    # host 2 silent
    assign = mon.rebalance()
    assert assign[2] == [] and 2 in assign[0]


def test_still_slow_host_stays_evicted():
    mon = StragglerMonitor(num_hosts=4, threshold=1.5, window=8)
    for h in range(3):
        _feed(mon, h, 1.0, n=8)
    _feed(mon, 3, 10.0, n=8)
    mon.rebalance()
    _feed(mon, 3, 10.0, n=8)        # still slow
    assign = mon.rebalance()
    assert assign[3] == []


# ---------------------------------------------------------------------------
# S2: preemption signal handlers
# ---------------------------------------------------------------------------


@pytest.fixture
def _restore_signals():
    prev = {sig: signal.getsignal(sig)
            for sig in (signal.SIGTERM, signal.SIGINT)}
    yield
    for sig, h in prev.items():
        signal.signal(sig, h)


@pytest.mark.parametrize("sig", [signal.SIGTERM, signal.SIGINT])
def test_install_handlers_latches_both_signals(_restore_signals, sig):
    ps = PreemptionSignal(install_handlers=True)
    try:
        assert not ps.preempted
        signal.raise_signal(sig)
        assert ps.preempted
    finally:
        ps.uninstall()


def test_prior_callable_handler_is_chained(_restore_signals):
    hits = []
    signal.signal(signal.SIGTERM, lambda s, f: hits.append(s))
    ps = PreemptionSignal(install_handlers=True)
    try:
        signal.raise_signal(signal.SIGTERM)
        assert ps.preempted
        assert hits == [signal.SIGTERM]
    finally:
        ps.uninstall()


def test_default_sigint_handler_is_not_chained(_restore_signals):
    """SIGINT's default handler raises KeyboardInterrupt — chaining it
    would abort before the final checkpoint.  The latch replaces it."""
    signal.signal(signal.SIGINT, signal.default_int_handler)
    ps = PreemptionSignal(install_handlers=True)
    try:
        signal.raise_signal(signal.SIGINT)   # must NOT raise
        assert ps.preempted
    finally:
        ps.uninstall()


def test_install_is_idempotent_and_uninstall_restores(_restore_signals):
    def prior(s, f):
        pass

    signal.signal(signal.SIGTERM, prior)
    ps = PreemptionSignal(install_handlers=True)
    try:
        installed = signal.getsignal(signal.SIGTERM)
        ps.install()                         # second install: no-op
        assert signal.getsignal(signal.SIGTERM) is installed
    finally:
        ps.uninstall()
    assert signal.getsignal(signal.SIGTERM) is prior


# ---------------------------------------------------------------------------
# S3: one committed checkpoint per step
# ---------------------------------------------------------------------------


def _counting_loop(tmp_path, **kw):
    loop = FaultTolerantLoop(tmp_path, **kw)
    counts, metas = {}, {}
    orig = loop.ckpt.save

    def spy(step, tree, meta=None):
        counts[step] = counts.get(step, 0) + 1
        metas[step] = meta
        orig(step, tree, meta=meta)

    loop.ckpt.save = spy
    return loop, counts, metas


def _step_fn(state, step):
    return {"x": state["x"] + 1.0}


def test_preemption_on_ckpt_boundary_saves_once(tmp_path):
    loop, counts, metas = _counting_loop(tmp_path, ckpt_every=3)
    sig = loop.preemption
    state, stopped = loop.run(
        {"x": np.float32(0)}, _step_fn, start_step=0, num_steps=10,
        on_step=lambda step, st: sig.trigger() if step == 3 else None)
    assert stopped == 3
    # the periodic save at step 3 is the one and only commit
    assert counts == {3: 1}, counts
    assert latest_step(tmp_path) == 3
    tree, meta = restore_checkpoint(tmp_path, {"x": np.float32(0)})
    assert meta["next_step"] == 3 and float(tree["x"]) == 3.0


def test_preemption_off_boundary_saves_final_checkpoint(tmp_path):
    loop, counts, metas = _counting_loop(tmp_path, ckpt_every=3)
    sig = loop.preemption
    _, stopped = loop.run(
        {"x": np.float32(0)}, _step_fn, start_step=0, num_steps=10,
        on_step=lambda step, st: sig.trigger() if step == 2 else None)
    assert stopped == 2
    assert counts == {2: 1}
    assert metas[2]["preempted"] is True


def test_final_step_on_ckpt_boundary_saves_once(tmp_path):
    loop, counts, metas = _counting_loop(tmp_path, ckpt_every=3)
    _, done = loop.run({"x": np.float32(0)}, _step_fn,
                       start_step=0, num_steps=6)
    assert done == 6
    assert counts == {3: 1, 6: 1}, counts
    assert latest_step(tmp_path) == 6
