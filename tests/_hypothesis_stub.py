"""Deterministic fallback for the tiny hypothesis surface these tests use.

The real property-based runner comes from the ``dev`` extra
(``pip install -e .[dev]``).  When hypothesis is absent the test modules
fall back to this stub, which draws a fixed, seeded sample of examples —
strictly weaker than hypothesis (no shrinking, no example database) but
it keeps the whole property suite running in minimal environments.

Implemented: ``given`` (keyword strategies only), ``settings``
(max_examples, deadline ignored), ``strategies.sampled_from``,
``strategies.integers`` and ``strategies.booleans``.
"""
from __future__ import annotations

import functools
import inspect
import random

# Keep CI time bounded: the stub is a smoke-sample, not a search.
_MAX_EXAMPLES_CAP = 12


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _sampled_from(items):
    items = list(items)
    assert items
    return _Strategy(lambda rng: items[rng.randrange(len(items))])


def _integers(min_value=0, max_value=1 << 31):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _booleans():
    return _Strategy(lambda rng: bool(rng.randrange(2)))


class _Strategies:
    sampled_from = staticmethod(_sampled_from)
    integers = staticmethod(_integers)
    booleans = staticmethod(_booleans)


st = _Strategies()
strategies = st


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    for name, s in strats.items():
        assert isinstance(s, _Strategy), (name, s)

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_stub_max_examples", 20),
                    _MAX_EXAMPLES_CAP)
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # pytest must not see the strategy parameters as fixtures
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        del wrapper.__wrapped__
        return wrapper
    return deco
