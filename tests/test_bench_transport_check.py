"""Regression tests for ``benchmarks.run --check-transport`` semantics.

The walltime *trend* comparison is non-blocking by design (machine-
dependent), but a missing or malformed baseline file must exit non-zero
— historically ``check_against`` printed a warning and returned, so a
deleted or corrupted ``BENCH_transport.json`` silently disarmed the CI
trend job.
"""
import json
import os
import sys
from pathlib import Path

import pytest

# benchmarks/ is a plain directory (not installed); import like run.py does
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

# bench_transport forces an 8-host-device XLA flag at import (for its
# own CLI use); the main pytest process must keep its device count, so
# snapshot and restore the env around the import
_keep_flags = os.environ.get("XLA_FLAGS")
from benchmarks import bench_transport  # noqa: E402

if _keep_flags is None:
    os.environ.pop("XLA_FLAGS", None)
else:
    os.environ["XLA_FLAGS"] = _keep_flags


GOOD_PALLAS = {
    "launches": {"flat8.allreduce.ring_rs_ag": {
        "rounds": 14, "runs": 3, "launches_per_run": 1, "jit_traces": 1}},
    "epilogue": {"win": True, "modeled_win": 1.2222,
                 "fused_walltime_s": 0.01, "unfused_walltime_s": 0.01},
}
GOOD_FLEET = {
    "heal": {"scoped": True, "cells_total": 16, "cells_affected": 12,
             "cells_retuned": 11, "generation": 1,
             "invalidated": {"plans": 0, "executors": 17}},
    "elastic": {"rederived": 2, "bit_exact": True, "invalidated": 2,
                "generation": 1},
}
GOOD_CHAOS = {
    "campaigns": {c: {"recovered_bitwise": True, "max_attempts": 3,
                      "retries": 2, "walltime_s": 0.01}
                  for c in ("corrupt", "fail", "hang", "mixed")},
    "unrecoverable": {"typed": True, "attempts": 4, "bounded": True},
    "verify_pricing": {"off_s": 0.0, "canary_frac": 0.07,
                       "full_frac": 1.15},
}
GOOD_SERVE = {
    "traffic": {"completed": 40, "submitted": 40, "tenants": 3,
                "bitwise_vs_oracle": True, "tokens_per_step": 2.8,
                "ttft_steps": {"mean": 1.0, "p50": 1.0, "p99": 1.0},
                "kv_transfer": {"plans": 20, "bytes": 84992}},
    "aggregation": {"msgs_win": True,
                    "shared_prefix": {"bytes_win": True, "bitwise": True,
                                      "standard_dcn_bytes": 8192,
                                      "locality_dcn_bytes": 2048}},
    "chaos_under_load": {"completed": 40, "submitted": 40,
                         "degraded_recovered": 2,
                         "recovered_bitwise": True},
}
GOOD_DATA = {"sim_exec": {"speedup": 8.0, "compiled_total_s": 0.1},
             "pallas": GOOD_PALLAS, "fleet": GOOD_FLEET,
             "chaos": GOOD_CHAOS, "serve": GOOD_SERVE}


def test_check_missing_baseline_exits_nonzero(tmp_path):
    with pytest.raises(SystemExit):
        bench_transport.check_against(str(tmp_path / "nope.json"), GOOD_DATA)


def test_check_malformed_baseline_exits_nonzero(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(SystemExit):
        bench_transport.check_against(str(bad), GOOD_DATA)


def test_check_baseline_without_speedup_exits_nonzero(tmp_path):
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"sim_exec": {}}))
    with pytest.raises(SystemExit):
        bench_transport.check_against(str(empty), GOOD_DATA)


def test_check_good_baseline_passes_and_regression_warns(tmp_path, capsys):
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"sim_exec": {"speedup": 8.0}}))
    # within 2x: no exception, no warning
    bench_transport.check_against(str(base), GOOD_DATA)
    assert "::warning" not in capsys.readouterr().err
    # >2x ratio drop: still non-blocking, but the ::warning is printed
    slow = dict(GOOD_DATA,
                sim_exec={"speedup": 3.0, "compiled_total_s": 0.5})
    bench_transport.check_against(str(base), slow)
    assert "::warning" in capsys.readouterr().err


def test_committed_baseline_is_readable():
    """The committed BENCH_transport.json must satisfy the checker's
    schema (otherwise every CI run would now fail the trend step)."""
    committed = Path(__file__).resolve().parents[1] / "BENCH_transport.json"
    bench_transport.check_against(str(committed), GOOD_DATA)


def test_check_lost_overlap_win_exits_nonzero(tmp_path):
    """The makespan section is pure model output (machine-independent),
    so a lost MoE-dispatch overlap win or an empty win count blocks."""
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"sim_exec": {"speedup": 8.0}}))
    lost = dict(GOOD_DATA,
                makespan={"strict_wins": 30,
                          "moe_overlap": {"win": False}})
    with pytest.raises(SystemExit):
        bench_transport.check_against(str(base), lost)
    dry = dict(GOOD_DATA,
               makespan={"strict_wins": 0,
                         "moe_overlap": {"win": True, "best_parts": 4,
                                         "speedup": 1.4}})
    with pytest.raises(SystemExit):
        bench_transport.check_against(str(base), dry)


def test_check_lost_pallas_amortization_exits_nonzero(tmp_path):
    """The pallas section's claims are model-level (machine-
    independent): a launch count above 1/run, a corpus with no
    multi-round schedule, a lost epilogue win, or a missing section all
    block."""
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"sim_exec": {"speedup": 8.0}}))
    import copy

    multi = copy.deepcopy(GOOD_DATA)
    multi["pallas"]["launches"]["flat8.allreduce.ring_rs_ag"][
        "launches_per_run"] = 14          # one launch per round again
    with pytest.raises(SystemExit):
        bench_transport.check_against(str(base), multi)
    flat = copy.deepcopy(GOOD_DATA)
    flat["pallas"]["launches"]["flat8.allreduce.ring_rs_ag"][
        "rounds"] = 1                     # R -> 1 vacuous at R == 1
    with pytest.raises(SystemExit):
        bench_transport.check_against(str(base), flat)
    cold = copy.deepcopy(GOOD_DATA)
    cold["pallas"]["epilogue"]["win"] = False
    with pytest.raises(SystemExit):
        bench_transport.check_against(str(base), cold)
    gone = {k: v for k, v in GOOD_DATA.items() if k != "pallas"}
    with pytest.raises(SystemExit):
        bench_transport.check_against(str(base), gone)


def test_committed_baseline_has_pallas_wins():
    """The committed artifact must record the device-side-transport
    acceptance numbers: every corpus schedule at 1 launch/run with at
    least one genuinely multi-round schedule, and the strict modeled
    epilogue win."""
    committed = Path(__file__).resolve().parents[1] / "BENCH_transport.json"
    with open(committed) as fh:
        data = json.load(fh)
    pal = data["pallas"]
    assert pal["launches"]
    assert all(v["launches_per_run"] == 1 and v["jit_traces"] == 1
               for v in pal["launches"].values())
    assert max(v["rounds"] for v in pal["launches"].values()) > 1
    assert pal["epilogue"]["win"] is True
    assert pal["epilogue"]["modeled_win"] > 1.0


def test_check_lost_fleet_claims_exits_nonzero(tmp_path):
    """The fleet section is deterministic model output: an unscoped
    heal (whole table re-measured), zero evictions, a lost bit-exact
    elastic swap, or a missing section all block."""
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"sim_exec": {"speedup": 8.0}}))
    import copy

    full = copy.deepcopy(GOOD_DATA)
    full["fleet"]["heal"].update(scoped=False, cells_affected=16,
                                 cells_retuned=16)
    with pytest.raises(SystemExit, match="scoped"):
        bench_transport.check_against(str(base), full)
    stale = copy.deepcopy(GOOD_DATA)
    stale["fleet"]["heal"]["invalidated"] = {"plans": 0, "executors": 0}
    with pytest.raises(SystemExit, match="stale executors"):
        bench_transport.check_against(str(base), stale)
    inexact = copy.deepcopy(GOOD_DATA)
    inexact["fleet"]["elastic"]["bit_exact"] = False
    with pytest.raises(SystemExit, match="bit-exact"):
        bench_transport.check_against(str(base), inexact)
    gone = {k: v for k, v in GOOD_DATA.items() if k != "fleet"}
    with pytest.raises(SystemExit, match="fleet"):
        bench_transport.check_against(str(base), gone)


def test_committed_baseline_has_fleet_claims():
    """The committed artifact must record the fleet-tuning acceptance
    numbers: a scoped heal (strict subset of the table re-measured) and
    a bit-exact elastic re-derivation."""
    committed = Path(__file__).resolve().parents[1] / "BENCH_transport.json"
    with open(committed) as fh:
        data = json.load(fh)
    fleet = data["fleet"]
    heal = fleet["heal"]
    assert heal["scoped"] is True
    assert 1 <= heal["cells_retuned"] <= heal["cells_affected"] \
        < heal["cells_total"]
    assert heal["invalidated"]["executors"] >= 1
    assert fleet["elastic"]["rederived"] >= 1
    assert fleet["elastic"]["bit_exact"] is True


def test_check_lost_chaos_claims_exits_nonzero(tmp_path):
    """The chaos section is deterministic (seeded campaigns on the sim
    substrate): a non-bitwise recovery, a missing campaign, an untyped
    or unbounded unrecoverable walk, a broken verify-pricing ordering,
    or a missing section all block."""
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"sim_exec": {"speedup": 8.0}}))
    import copy

    drift = copy.deepcopy(GOOD_DATA)
    drift["chaos"]["campaigns"]["corrupt"]["recovered_bitwise"] = False
    with pytest.raises(SystemExit, match="bitwise"):
        bench_transport.check_against(str(base), drift)
    partial = copy.deepcopy(GOOD_DATA)
    del partial["chaos"]["campaigns"]["hang"]
    with pytest.raises(SystemExit, match="campaigns"):
        bench_transport.check_against(str(base), partial)
    untyped = copy.deepcopy(GOOD_DATA)
    untyped["chaos"]["unrecoverable"]["typed"] = False
    with pytest.raises(SystemExit, match="typed"):
        bench_transport.check_against(str(base), untyped)
    spin = copy.deepcopy(GOOD_DATA)
    spin["chaos"]["unrecoverable"]["bounded"] = False
    with pytest.raises(SystemExit, match="bounded"):
        bench_transport.check_against(str(base), spin)
    free = copy.deepcopy(GOOD_DATA)
    free["chaos"]["verify_pricing"]["canary_frac"] = 0.0
    with pytest.raises(SystemExit, match="pricing"):
        bench_transport.check_against(str(base), free)
    gone = {k: v for k, v in GOOD_DATA.items() if k != "chaos"}
    with pytest.raises(SystemExit, match="chaos"):
        bench_transport.check_against(str(base), gone)


def test_committed_baseline_has_chaos_claims():
    """The committed artifact must record the chaos acceptance numbers:
    all four campaigns recovered bitwise, a typed+bounded unrecoverable
    walk, and the verify-pricing ordering off = 0 < canary < full."""
    committed = Path(__file__).resolve().parents[1] / "BENCH_transport.json"
    with open(committed) as fh:
        data = json.load(fh)
    ch = data["chaos"]
    assert set(ch["campaigns"]) == {"corrupt", "fail", "hang", "mixed"}
    assert all(row["recovered_bitwise"] is True
               for row in ch["campaigns"].values())
    assert ch["unrecoverable"]["typed"] is True
    assert ch["unrecoverable"]["bounded"] is True
    pr = ch["verify_pricing"]
    assert pr["off_s"] == 0.0
    assert 0.0 < pr["canary_frac"] < pr["full_frac"]


def test_check_lost_serve_claims_exits_nonzero(tmp_path):
    """The serve section runs a seeded trace on the sim substrate with
    an in-engine bitwise oracle — every claim is machine-independent: a
    trace that no longer drains, a single-tenant mix, a lost bitwise
    KV-transfer match, a lost shared-prefix dedupe win, a dead
    chaos-under-load recovery, or a missing section all block."""
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"sim_exec": {"speedup": 8.0}}))
    import copy

    stuck = copy.deepcopy(GOOD_DATA)
    stuck["serve"]["traffic"]["completed"] = 39
    with pytest.raises(SystemExit, match="no longer drains"):
        bench_transport.check_against(str(base), stuck)
    mono = copy.deepcopy(GOOD_DATA)
    mono["serve"]["traffic"]["tenants"] = 1
    with pytest.raises(SystemExit, match="multi-tenant"):
        bench_transport.check_against(str(base), mono)
    drift = copy.deepcopy(GOOD_DATA)
    drift["serve"]["traffic"]["bitwise_vs_oracle"] = False
    with pytest.raises(SystemExit, match="gather oracle"):
        bench_transport.check_against(str(base), drift)
    fat = copy.deepcopy(GOOD_DATA)
    fat["serve"]["aggregation"]["shared_prefix"]["bytes_win"] = False
    with pytest.raises(SystemExit):
        bench_transport.check_against(str(base), fat)
    fragile = copy.deepcopy(GOOD_DATA)
    fragile["serve"]["chaos_under_load"]["degraded_recovered"] = 0
    with pytest.raises(SystemExit, match="no longer recovers"):
        bench_transport.check_against(str(base), fragile)
    gone = {k: v for k, v in GOOD_DATA.items() if k != "serve"}
    with pytest.raises(SystemExit, match="serve"):
        bench_transport.check_against(str(base), gone)


def test_committed_baseline_has_serve_claims():
    """The committed artifact must record the serving-path acceptance
    numbers: the multi-tenant Poisson trace drains bit-exact vs the
    gather oracle over >= 1 ragged plan, the shared-prefix locality
    dedupe strictly cuts DCN bytes, and the chaos-under-load trace
    recovers."""
    committed = Path(__file__).resolve().parents[1] / "BENCH_transport.json"
    with open(committed) as fh:
        data = json.load(fh)
    sv = data["serve"]
    tr = sv["traffic"]
    assert tr["completed"] == tr["submitted"] >= 1
    assert tr["tenants"] >= 2
    assert tr["bitwise_vs_oracle"] is True
    assert tr["kv_transfer"]["plans"] >= 1
    assert tr["ttft_steps"]["p99"] >= tr["ttft_steps"]["p50"]
    sp = sv["aggregation"]["shared_prefix"]
    assert sp["bitwise"] is True
    assert sp["locality_dcn_bytes"] < sp["standard_dcn_bytes"]
    cl = sv["chaos_under_load"]
    assert cl["completed"] == cl["submitted"]
    assert cl["degraded_recovered"] >= 1
    assert cl["recovered_bitwise"] is True


def test_committed_baseline_has_makespan_wins():
    """The committed artifact must record the PR 6 acceptance numbers:
    >= 1 strict pipelined win over the corpus and a strict MoE-dispatch
    compute-comm-overlap win."""
    committed = Path(__file__).resolve().parents[1] / "BENCH_transport.json"
    with open(committed) as fh:
        data = json.load(fh)
    mk = data["makespan"]
    assert mk["strict_wins"] >= 1
    assert mk["moe_overlap"]["win"] is True
    assert mk["moe_overlap"]["speedup"] > 1.0
