"""Subprocess check: the 3-level (2 pods x 4x2 torus) conformance sweep
on 16 forced host devices.

Two halves:
  1. executor equivalence — SimTransport and ShardMapTransport are
     bit-exact on every registered schedule (dense families incl. the
     staged builders + partitioned) and both neighborhood plan modes,
     for float32 and bfloat16;
  2. staged-vs-flat — on the device path, every staged dense builder
     produces bit-exact results vs its flat reference on integer-valued
     payloads (exact sums for any reduction order).

This is the ShardMap half of tests/test_hierarchical.py; the
SimTransport half (oracles, modeled time, traffic bounds) runs there
without devices.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
os.environ.setdefault("REPRO_VALIDATE_SCHEDULES", "1")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.algorithms import REGISTRY
from repro.core.plan import CommGraph, build_plan
from repro.core.schedule import NotApplicable
from repro.core.topology import torus_topology
from repro.core.transport import ShardMapTransport, SimTransport

TOPO = torus_topology(2, 4, 2)          # (dcn-2, torus_y-4, torus_x-2)
N, FEAT = TOPO.nranks, 2
AXES = ("pod", "y", "x")
MESH = compat.make_mesh((2, 4, 2), AXES)
DTYPES = {"float32": np.float32, "bfloat16": jnp.bfloat16}
FLAT = {"allgather": "ring", "allreduce": "ring_rs_ag",
        "reduce_scatter": "ring", "alltoall": "pairwise"}

rng = np.random.default_rng(0)
failures = []
checked = 0


def shardmap_run(sched, x):
    tr = ShardMapTransport(N, AXES)
    f = jax.jit(compat.shard_map(
        lambda b: tr.run(sched, b), mesh=MESH,
        in_specs=P(AXES), out_specs=P(AXES), check_vma=False))
    with compat.set_mesh(MESH):
        got = np.asarray(f(x.reshape(N * sched.num_slots, FEAT)))
    return got.reshape(N, sched.num_slots, FEAT)


# -- half 1: executor equivalence on every registered schedule -------------
schedules = []
for coll, algos in REGISTRY.items():
    for name, builder in algos.items():
        try:
            schedules.append((f"{coll}.{name}", builder(TOPO)))
        except NotApplicable:          # e.g. pow2-only on this topo
            continue
graph = CommGraph.random(N, n_local=6, degree=4, rng=rng, dup_frac=0.8)
for aggregate in (False, True):
    plan = build_plan(graph, TOPO, aggregate=aggregate)
    schedules.append((plan.name, plan.schedule))

for dt_name, dtype in DTYPES.items():
    for label, sched in schedules:
        x = rng.normal(size=(N, sched.num_slots, FEAT)).astype(dtype)
        want = SimTransport(N).run(sched, x)
        got = shardmap_run(sched, x)
        checked += 1
        if not np.array_equal(np.asarray(want), got):
            failures.append(("sim-vs-shardmap", label, dt_name))
            print(f"sim-vs-shardmap {dt_name:8s} {label:40s} FAIL")
print(f"sim-vs-shardmap: {len(schedules)} schedules x {len(DTYPES)} dtypes")

# -- half 2: staged == flat reference on the device path -------------------
ints = rng.integers(-8, 8, (N, N, FEAT)).astype(np.float32)
for coll, flat_name in FLAT.items():
    if coll == "allgather":
        buf = np.zeros((N, N, FEAT), np.float32)
        for r in range(N):
            buf[r, r] = ints[r, 0]
    else:
        buf = ints
    outs = {}
    for name in ("staged", flat_name):
        sched = REGISTRY[coll][name](TOPO)
        x = buf
        if sched.num_slots > N:        # separate recv region (pairwise)
            x = np.concatenate(
                [buf, np.zeros((N, sched.num_slots - N, FEAT),
                               np.float32)], axis=1)
        outs[name] = shardmap_run(sched, x)[:, : sched.result_slots]
    checked += 1
    staged_out, flat_out = outs["staged"], outs[flat_name]
    if coll == "reduce_scatter":
        ok = all(np.array_equal(staged_out[r, r], flat_out[r, r])
                 for r in range(N))
    else:
        ok = np.array_equal(staged_out, flat_out)
    if not ok:
        failures.append(("staged-vs-flat", coll, "float32"))
        print(f"staged-vs-flat {coll:16s} FAIL")
print(f"staged-vs-flat: {len(FLAT)} collectives on {N} devices")

if failures:
    raise SystemExit(f"FAILURES: {failures}")
print(f"checked {checked} cases on the 3-level 2x(4x2) torus")
print("ALL OK")
