"""Subprocess check (8 host devices): the distributed train/serve paths.

  1. mpix EP dispatch == dense-dispatch oracle (generous capacity), for
     every alltoall algorithm, flat + pods meshes.
  2. explicit-DP (mpix allreduce, every algorithm) step == single-device
     step (same loss, same params after update).
  3. bucketed + compressed DCN sync run and stay finite.
  4. FSDP-sharded train step == single-device step (xla substrate).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.data import DataPipeline, PipelineConfig
from repro.models import moe as moe_mod
from repro.train.moe_dispatch import EPOptions, make_moe_dispatch
from repro.train.step import TrainOptions, init_train_state, make_train_step
from repro import compat

failures = []


def check(name, ok):
    print(f"{name:58s} {'ok' if ok else 'FAIL'}")
    if not ok:
        failures.append(name)


mesh_flat = compat.make_mesh((2, 4), ("data", "model"))
mesh_pods = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))

# ---------------------------------------------------------------------------
# 1. EP dispatch == dense oracle
# ---------------------------------------------------------------------------
cfg = configs.get_smoke("moonshot-v1-16b-a3b")   # 8 experts, sigmoid+bias
mcfg = cfg.moe
p = moe_mod.init(jax.random.key(0), mcfg, cfg.d_model)
x = (jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model), jnp.float32)
     * 0.3)
want = np.asarray(moe_mod.forward(p, mcfg, x, cfg.mlp_act), np.float32)

for mesh in (mesh_flat, mesh_pods):
    for algo in ("xla", "pairwise", "hierarchical"):
        disp = make_moe_dispatch(
            mesh, EPOptions(alltoall=algo,
                            capacity_factor=float(mcfg.n_experts)),
            cfg.mlp_act)
        with compat.set_mesh(mesh):
            got = np.asarray(jax.jit(lambda pp, xx: disp(pp, mcfg, xx))(
                p, x), np.float32)
        ok = np.allclose(got, want, atol=2e-2, rtol=2e-2)
        check(f"EP dispatch {mesh.axis_names} alltoall={algo}", ok)

# ---------------------------------------------------------------------------
# 2-4. train-step equivalence single-device vs distributed
# ---------------------------------------------------------------------------
cfg = configs.get_smoke("smollm-360m")
pipe = DataPipeline(PipelineConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                   global_batch=8, seed=3))
batch = pipe.batch(0)

mesh1 = compat.make_mesh((1, 1), ("data", "model"))
opts_ref = TrainOptions(dp_mode="fsdp", remat=False, peak_lr=1e-3,
                        warmup_steps=1, total_steps=100)
state0 = init_train_state(jax.random.key(0), cfg, opts_ref)
ref_state, ref_m = jax.jit(make_train_step(cfg, mesh1, opts_ref))(
    jax.device_put(state0), batch)
ref_loss = float(ref_m["loss"])
ref_w = np.asarray(jax.tree.leaves(ref_state["params"])[0], np.float32)

for mesh, algos in ((mesh_flat, ["xla", "ring_rs_ag", "hierarchical"]),
                    (mesh_pods, ["xla", "hierarchical"])):
    d_axes = tuple(a for a in mesh.axis_names if a != "model")
    for algo in algos:
        opts = TrainOptions(dp_mode="explicit", dp_algorithm=algo,
                            remat=False, peak_lr=1e-3, warmup_steps=1,
                            total_steps=100)
        step = make_train_step(cfg, mesh, opts)
        with compat.set_mesh(mesh):
            bsh = jax.device_put(batch, NamedSharding(mesh, P(d_axes)))
            st = jax.device_put(state0)
            new, m = jax.jit(step)(st, bsh)
        w = np.asarray(jax.tree.leaves(new["params"])[0], np.float32)
        ok = (abs(float(m["loss"]) - ref_loss) < 1e-2
              and np.allclose(w, ref_w, atol=1e-2))
        check(f"explicit DP {mesh.axis_names} algo={algo} == 1-dev", ok)

# bucketed sync
opts = TrainOptions(dp_mode="explicit", dp_algorithm="ring_rs_ag",
                    grad_buckets=4, remat=False, peak_lr=1e-3,
                    warmup_steps=1, total_steps=100)
with compat.set_mesh(mesh_flat):
    bsh = jax.device_put(batch, NamedSharding(mesh_flat, P(("data",))))
    new, m = jax.jit(make_train_step(cfg, mesh_flat, opts))(
        jax.device_put(state0), bsh)
w = np.asarray(jax.tree.leaves(new["params"])[0], np.float32)
check("bucketed explicit DP == 1-dev",
      abs(float(m["loss"]) - ref_loss) < 1e-2
      and np.allclose(w, ref_w, atol=1e-2))

# compressed DCN sync (int8 quantization -> looser equivalence)
opts = TrainOptions(dp_mode="explicit", compress_dcn=True, remat=False,
                    peak_lr=1e-3, warmup_steps=1, total_steps=100)
state_c = init_train_state(jax.random.key(0), cfg, opts)
with compat.set_mesh(mesh_pods):
    bsh = jax.device_put(batch,
                         NamedSharding(mesh_pods, P(("pod", "data"))))
    new, m = jax.jit(make_train_step(cfg, mesh_pods, opts))(
        jax.device_put(state_c), bsh)
w = np.asarray(jax.tree.leaves(new["params"])[0], np.float32)
check("compressed DCN sync finite + close",
      np.isfinite(float(m["loss"])) and np.allclose(w, ref_w, atol=5e-2))

# FSDP path on 8 devices
from repro.train.step import jit_train_step
opts = TrainOptions(dp_mode="fsdp", remat=True, peak_lr=1e-3,
                    warmup_steps=1, total_steps=100)
with compat.set_mesh(mesh_flat):
    bspec = jax.tree.map(lambda _: P(("data",)), batch)
    step, sspec = jit_train_step(cfg, mesh_flat, opts,
                                 state0, bspec)
    new, m = step(jax.device_put(state0), batch)
w = np.asarray(jax.tree.leaves(new["params"])[0], np.float32)
check("FSDP 8-dev step == 1-dev", abs(float(m["loss"]) - ref_loss) < 1e-2
      and np.allclose(w, ref_w, atol=1e-2))

if failures:
    raise SystemExit(f"FAILURES: {failures}")
print("ALL OK")
