"""Subprocess check: elastic checkpoint restore — train on a (4,2)
mesh, checkpoint, restart on a (2,4) mesh (different shard decomposition
and per-device batch), and verify the training trajectory is unchanged
vs an uninterrupted run."""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data import DataPipeline, PipelineConfig
from repro.train.sharding import data_axes, param_specs
from repro import compat
from repro.train.step import TrainOptions, init_train_state, \
    make_train_step

cfg = configs.get_smoke("smollm-360m")
opts = TrainOptions(dp_mode="fsdp", remat=False, peak_lr=1e-3,
                    warmup_steps=1, total_steps=100)
pipe = PipelineConfig(vocab_size=cfg.vocab_size, seq_len=16,
                      global_batch=8, seed=11)


def run(mesh, state, steps, start):
    dp = DataPipeline(pipe)
    step_fn = jax.jit(make_train_step(cfg, mesh, opts))
    with compat.set_mesh(mesh):
        state = jax.device_put(state)
        for s in range(start, start + steps):
            b = jax.device_put(
                dp.batch(s),
                NamedSharding(mesh, P(data_axes(mesh))))
            state, m = step_fn(state, b)
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state), \
        float(m["loss"])


mesh_a = compat.make_mesh((4, 2), ("data", "model"))
mesh_b = compat.make_mesh((2, 4), ("data", "model"))

state0 = init_train_state(jax.random.key(0), cfg, opts)

# uninterrupted 6 steps on mesh A
full, loss_full = run(mesh_a, state0, 6, 0)

# 3 steps on mesh A -> checkpoint -> restore -> 3 steps on mesh B
half, _ = run(mesh_a, state0, 3, 0)
with tempfile.TemporaryDirectory() as d:
    save_checkpoint(d, 3, half, num_shards=2)
    restored, _ = restore_checkpoint(d, half)
resumed, loss_res = run(mesh_b, restored, 3, 3)

w_full = np.concatenate([x.ravel() for x in jax.tree.leaves(
    full["params"])]).astype(np.float32)
w_res = np.concatenate([x.ravel() for x in jax.tree.leaves(
    resumed["params"])]).astype(np.float32)
err = np.abs(w_full - w_res).max()
print(f"trajectory match after elastic remesh: max|dw| = {err:.2e}, "
      f"loss {loss_full:.4f} vs {loss_res:.4f}")
assert err < 2e-2, err
# loss reduction order differs across mesh decompositions (bf16 matmuls
# reduced over different shard shapes), so the loss needs slightly more
# headroom than the weights
assert abs(loss_full - loss_res) < 3e-2
print("ALL OK")
