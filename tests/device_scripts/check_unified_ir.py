"""Subprocess check: SimTransport and ShardMapTransport are bit-exact on
the unified IR — every registered schedule (dense families incl. the
staged builders + partitioned chunked shifts) and both neighborhood
plan modes, executed on the same random buffer by both backends, for
every topology in {flat, 2-pod, 2x4 torus, 3-level 2x(2x2)} x dtype in
{float32, bfloat16}.

This is the executor-equivalence half of the unification contract: one
IR, two backends, zero semantic drift.  (Semantic correctness of each
algorithm against its oracle lives in test_algorithms_sim /
test_neighbor_plan; the shard_map API path in check_shardmap_transport.)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("REPRO_VALIDATE_SCHEDULES", "1")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.algorithms import REGISTRY
from repro.core.plan import CommGraph, build_plan
from repro.core.schedule import NotApplicable
from repro.core.topology import Topology, flat_topology, torus_topology
from repro.core.transport import ShardMapTransport, SimTransport

N, FEAT = 8, 2
CASES = {
    "flat":  (flat_topology(N), (N,), ("r",)),
    "pods":  (Topology(N, 4), (2, 4), ("pod", "data")),
    "torus": (torus_topology(1, 2, 4), (2, 4), ("y", "x")),
    # 3-level: DCN over a 2x2 torus (the staged builders' home turf;
    # the full 2x(4x2) sweep runs in check_hierarchical.py)
    "3lvl":  (torus_topology(2, 2, 2), (2, 2, 2), ("pod", "y", "x")),
}
DTYPES = {"float32": np.float32, "bfloat16": jnp.bfloat16}

rng = np.random.default_rng(0)
failures = []
checked = 0


def bit_exact(sched, mesh, axes, dtype) -> bool:
    x = rng.normal(size=(N, sched.num_slots, FEAT)).astype(dtype)
    # the oracle is the UNFUSED rank-by-rank reference loop, so this
    # sweep proves the compiled/fused ppermute lowering (and the
    # vectorized simulator, via test_executor.py) against pre-executor
    # semantics — not merely the two compiled backends against each other
    want = SimTransport(N).run_reference(sched, x)
    assert np.array_equal(want, SimTransport(N).run(sched, x))
    tr = ShardMapTransport(N, axes)
    f = jax.jit(compat.shard_map(
        lambda b: tr.run(sched, b), mesh=mesh,
        in_specs=P(axes), out_specs=P(axes), check_vma=False))
    with compat.set_mesh(mesh):
        got = np.asarray(f(x.reshape(N * sched.num_slots, FEAT)))
    return np.array_equal(want.reshape(got.shape), got)


for case, (topo, mesh_shape, axes) in CASES.items():
    mesh = compat.make_mesh(mesh_shape, axes)
    schedules = []
    for coll, algos in REGISTRY.items():
        for name, builder in algos.items():
            try:
                schedules.append((f"{coll}.{name}", builder(topo)))
            except NotApplicable:      # e.g. pow2-only on this topo
                continue
    graph = CommGraph.random(N, n_local=6, degree=4, rng=rng,
                             dup_frac=0.8)
    for aggregate in (False, True):
        plan = build_plan(graph, topo, aggregate=aggregate)
        schedules.append((plan.name, plan.schedule))
    for dt_name, dtype in DTYPES.items():
        for label, sched in schedules:
            ok = bit_exact(sched, mesh, axes, dtype)
            checked += 1
            if not ok:
                failures.append((case, label, dt_name))
                print(f"{case:5s} {dt_name:8s} {label:40s} FAIL")
    print(f"{case:5s} {len(schedules)} schedules x {len(DTYPES)} dtypes ok")

if failures:
    raise SystemExit(f"FAILURES: {failures}")
print(f"checked {checked} (schedule, topology, dtype) cases")
print("ALL OK")
