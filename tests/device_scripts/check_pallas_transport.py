"""Subprocess check: the device-side PallasTransport (whole schedule as
ONE kernel, core.pallas_lowering) inside real shard_map on 8 host
devices — bit-exact vs ShardMapTransport and the numpy expectation for
every dense collective, the neighbor plan, the pipelined overlap path,
and the fused allreduce->rmsnorm epilogue.

Run via tests/test_shardmap.py (needs its own process: jax device count
is locked at first init)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import api
from repro import compat

N = 8
MESHES = {
    "flat": (compat.make_mesh((8,), ("data",)), ("data",)),
    "pods": (compat.make_mesh((2, 4), ("pod", "data")), ("pod", "data")),
}
# one schedule-backed algorithm per collective keeps the interpret-mode
# kernel lowerings bounded; the full registry sweep is tier-1
# (tests/test_pallas_transport.py) against the same lowering
ALGOS = {
    "allgather": "ring",
    "allreduce": "ring_rs_ag",
    "reduce_scatter": "ring",
    "alltoall": "hierarchical",
}

rng = np.random.default_rng(0)
failures = []


def bits(x):
    return np.asarray(x).view(np.uint8).tobytes()


def run(mesh, axes, fn, x, out_spec=None):
    spec = P(tuple(axes))
    f = jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=spec,
                                 out_specs=out_spec or spec,
                                 check_vma=False))
    with compat.set_mesh(mesh):
        return np.asarray(f(x))


def check_collective(mesh_name, mesh, axes, coll, algo):
    x = rng.normal(size=(N * N, 6)).astype(np.float32)
    outs = {}
    for tr in ("shardmap", "pallas"):
        fn = lambda v, tr=tr: getattr(api, f"mpix_{coll}")(
            v, axes, algorithm=algo, transport=tr)
        out_spec = P(None) if coll in ("allgather", "allreduce") else None
        outs[tr] = run(mesh, axes, fn, x, out_spec=out_spec)
    ok = bits(outs["shardmap"]) == bits(outs["pallas"])
    if coll == "allgather":
        ok = ok and np.allclose(outs["pallas"], x)
    elif coll == "allreduce":
        ok = ok and np.allclose(outs["pallas"],
                                x.reshape(N, N, 6).sum(0), atol=1e-4)
    elif coll == "reduce_scatter":
        ok = ok and np.allclose(outs["pallas"],
                                x.reshape(N, N, 6).sum(0), atol=1e-4)
    elif coll == "alltoall":
        want = x.reshape(N, N, 6).swapaxes(0, 1).reshape(N * N, 6)
        ok = ok and np.allclose(outs["pallas"], want, atol=1e-5)
    print(f"{mesh_name:5s} {coll:15s} {algo:16s} "
          f"{'ok' if ok else 'FAIL'}")
    if not ok:
        failures.append((mesh_name, coll, algo))


def check_overlap(mesh_name, mesh, axes):
    """run_chunked on the pallas transport (grid-pipelined single
    launch, then the consume-fold path) == monolithic alltoall."""
    x = rng.normal(size=(N * N * 2, 6)).astype(np.float32)  # [16,6]/rank

    def fold(v, tr):
        def consume(carry, chunk, i):
            return carry + chunk.sum(0)
        init = jnp.zeros((6,), jnp.float32)
        return api.mpix_alltoall_overlap(
            v, axes, consume, init, chunks=2, algorithm="pairwise",
            transport=tr)

    def mono(v):
        return api.mpix_alltoall(v, axes, algorithm="pairwise").sum(0)

    a = run(mesh, axes, lambda v: fold(v, "shardmap"), x)
    b = run(mesh, axes, lambda v: fold(v, "pallas"), x)
    c = run(mesh, axes, mono, x)
    ok = (np.allclose(a, b, atol=1e-6)
          and np.allclose(b, c, atol=1e-5))
    print(f"{mesh_name:5s} alltoall_overlap chunked          "
          f"{'ok' if ok else 'FAIL'}")
    if not ok:
        failures.append((mesh_name, "alltoall_overlap"))


def check_neighbor(mesh_name, mesh, axes, rpp):
    from repro.core.plan import CommGraph, build_plan
    from repro.core.topology import Topology

    topo = Topology(nranks=N, ranks_per_pod=rpp)
    graph = CommGraph.random(N, n_local=6, degree=5,
                             rng=np.random.default_rng(42), dup_frac=0.8)
    plan = build_plan(graph, topo, aggregate=True)
    x = rng.normal(size=(N * 6, 3)).astype(np.float32)
    fn = lambda v, tr: api.mpix_neighbor_alltoallv(v, axes, plan,
                                                   transport=tr)
    a = run(mesh, axes, lambda v: fn(v, "shardmap"), x)
    b = run(mesh, axes, lambda v: fn(v, "pallas"), x)
    ok = bits(a) == bits(b)
    print(f"{mesh_name:5s} neighbor_alltoallv aggregate      "
          f"{'ok' if ok else 'FAIL'}")
    if not ok:
        failures.append((mesh_name, "neighbor"))


def check_rmsnorm_fused(mesh_name, mesh, axes):
    """mpix_allreduce_rmsnorm: fused epilogue (pallas) vs unfused
    allreduce-then-normalize (shardmap) — same math, float tolerance
    (the fused sum order differs from the ring reduction's)."""
    d = 64
    x = rng.normal(size=(N * 4, d)).astype(np.float32)
    scale = rng.normal(size=(d,)).astype(np.float32)
    fn = lambda v, tr: api.mpix_allreduce_rmsnorm(
        v, axes, jnp.asarray(scale), algorithm="ring_rs_ag", transport=tr)
    fused = run(mesh, axes, lambda v: fn(v, "pallas"), x,
                out_spec=P(None))
    unfused = run(mesh, axes, lambda v: fn(v, "shardmap"), x,
                  out_spec=P(None))
    ok = np.allclose(fused, unfused, atol=1e-4)
    print(f"{mesh_name:5s} allreduce_rmsnorm fused           "
          f"{'ok' if ok else 'FAIL'}")
    if not ok:
        failures.append((mesh_name, "allreduce_rmsnorm"))


for mesh_name, (mesh, axes) in MESHES.items():
    for coll, algo in ALGOS.items():
        check_collective(mesh_name, mesh, axes, coll, algo)
    check_overlap(mesh_name, mesh, axes)
    check_neighbor(mesh_name, mesh, axes, 8 if mesh_name == "flat" else 4)
    check_rmsnorm_fused(mesh_name, mesh, axes)

if failures:
    raise SystemExit(f"FAILURES: {failures}")
print("ALL OK")
