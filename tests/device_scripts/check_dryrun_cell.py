"""Subprocess check: the 512-device multi-pod dry-run machinery works
end-to-end for representative cells (must be its own process: the
forced device count locks at first jax init)."""
from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS first)

import numpy as np

CELLS = [
    ("smollm-360m", "train_4k", False),
    ("smollm-360m", "train_4k", True),      # multi-pod: pod axis shards
    ("rwkv6-3b", "long_500k", False),       # SSM 500k decode
]

for arch, shape, mp in CELLS:
    res = dryrun.analyse(arch, shape, multi_pod=mp, verbose=False,
                         train_overrides={"moe_mode": "mpix_ep"})
    assert res["flops_per_device"] > 0
    assert res["hbm_bytes_per_device"] > 0
    assert res["mem"]["peak_bytes"] > 0
    assert np.isfinite(res["collectives"]["total"])
    mesh = "2x16x16" if mp else "16x16"
    print(f"{arch:14s} {shape:10s} {mesh:8s} ok "
          f"(compile {res['compile_s']}s, "
          f"coll {res['collectives']['total']:.2e} B)")

print("ALL OK")
