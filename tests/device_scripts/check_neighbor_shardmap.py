"""Subprocess check: NeighborPlan's shard_map executor == numpy oracle on
8 host devices, standard + locality-aware, flat + pods meshes."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

from repro.core.plan import CommGraph, build_plan, run_sim, run_shardmap
from repro.core.topology import Topology
from repro import compat

N, N_LOCAL, FEAT = 8, 6, 3
rng = np.random.default_rng(42)
graph = CommGraph.random(N, n_local=N_LOCAL, degree=5, rng=rng,
                         dup_frac=0.8)
values = [rng.normal(size=(N_LOCAL, FEAT)).astype(np.float32)
          for _ in range(N)]

MESHES = {
    "flat": (compat.make_mesh((8,), ("data",)), ("data",), 8),
    "pods": (compat.make_mesh((2, 4), ("pod", "data")), ("pod", "data"), 4),
}

failures = []
for mesh_name, (mesh, axes, rpp) in MESHES.items():
    topo = Topology(nranks=N, ranks_per_pod=rpp)
    for aggregate in (False, True):
        plan = build_plan(graph, topo, aggregate=aggregate)
        want = run_sim(plan, values)

        f = jax.jit(compat.shard_map(
            lambda v: run_shardmap(plan, v, axes),
            mesh=mesh, in_specs=P(tuple(axes)), out_specs=P(tuple(axes)),
            check_vma=False))
        stacked = np.stack(values).reshape((N * N_LOCAL, FEAT))
        with compat.set_mesh(mesh):
            got = np.asarray(f(stacked))
        got = got.reshape(N, -1, FEAT)
        ok = all(np.allclose(got[r, : plan.recv_sizes[r]], want[r],
                             atol=1e-6) for r in range(N))
        print(f"{mesh_name:5s} aggregate={aggregate!s:5s} "
              f"rounds={plan.num_rounds:3d} {'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append((mesh_name, aggregate))

if failures:
    raise SystemExit(f"FAILURES: {failures}")
print("ALL OK")
