"""Subprocess check (8 host devices): the serving path on real shards.

  1. ``jit_decode_step`` shardings: the launcher-path decode step's
     cache output actually lands with the cache specs' NamedShardings,
     and at least one KV leaf is genuinely partitioned (not
     replicated) on the 8-device mesh — the bare-``jax.jit`` bug this
     PR fixed silently replicated everything;
  2. KV-transfer plans are bit-exact vs the gather oracle on the
     *shardmap* and *pallas* transports (the sim/reference sweep runs
     in tests/test_serve_engine.py);
  3. a continuous-batching trace drains with ``transport="shardmap"``
     — the engine's per-batch ragged plans executed by real ppermutes.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax

from repro import compat, configs
from repro.core import kvtransfer
from repro.core.topology import Topology
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine, EngineConfig
from repro.serve.step import ServeOptions, jit_decode_step
from repro.serve.traffic import poisson_workload, run_workload

failures = []

# ---- 1. decode-step cache shardings on the 8-device mesh -----------------
cfg = configs.get_smoke("smollm-360m")
mesh = compat.make_mesh((4, 2), ("data", "model"))
with compat.set_mesh(mesh):
    params = M.init_params(jax.random.key(0), cfg)
    cache = M.init_cache(cfg, 4, 8)
    decode, (pspec, cspec) = jit_decode_step(
        cfg, mesh, ServeOptions(), params, cache)
    tok = jax.numpy.zeros((4, 1), jax.numpy.int32)
    nxt, cache2 = decode(params, cache, tok)
    jax.block_until_ready(nxt)

leaves = jax.tree.leaves(cache2)
specs = jax.tree.leaves(cspec, is_leaf=lambda x: hasattr(x, "_normalized_spec")
                        or type(x).__name__ == "PartitionSpec")
got_sharded = 0
for leaf, spec in zip(leaves, specs):
    sh = leaf.sharding
    want_spec = tuple(spec)
    got_spec = tuple(sh.spec) if hasattr(sh, "spec") else None
    # normalize trailing Nones (jax may trim/extend them)
    strip = lambda t: tuple(x for x in t if x is not None)
    if strip(want_spec) != strip(got_spec or ()):
        failures.append(("cache-sharding", want_spec, got_spec))
    if strip(want_spec):
        got_sharded += 1
        if sh.is_fully_replicated:
            failures.append(("cache-replicated", want_spec))
print(f"decode cache: {len(leaves)} leaves, {got_sharded} partitioned "
      f"({'ok' if not failures else 'FAIL'})")
if got_sharded == 0:
    failures.append(("no-sharded-cache-leaf",))

# ---- 2. transfer plans bit-exact on shardmap + pallas --------------------
rng = np.random.default_rng(0)
topo = Topology(8, 4)
B = 8
pool = rng.normal(size=(8, B, 2, 4)).astype(np.float32)
moves = [kvtransfer.BlockMove(s, (s + j) % B, 4 + (s + j) % 4,
                              (2 * s + j) % B)
         for s in range(4) for j in range(3)]
# dedupe dst rows (the generator above may collide)
seen, clean = set(), []
for m in moves:
    if (m.dst, m.dst_row) not in seen:
        seen.add((m.dst, m.dst_row))
        clean.append(m)
for aggregate in (False, True):
    tp = kvtransfer.build_transfer_plan(
        clean, topo, blocks_per_rank=B, aggregate=aggregate,
        block_bytes=32)
    for transport in ("shardmap", "pallas"):
        res = kvtransfer.run_transfer(tp, pool, transport=transport)
        ok = kvtransfer.verify_bitwise(tp, pool, res)
        print(f"transfer aggregate={aggregate!s:5s} {transport:8s} "
              f"rounds={tp.schedule.num_rounds:3d} "
              f"{'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append(("transfer", aggregate, transport))

# ---- 3. continuous batching on the shardmap transport --------------------
eng = ContinuousBatchingEngine(EngineConfig(transport="shardmap"))
m = run_workload(eng, poisson_workload(0, arrival_rate=8.0, tenants=2,
                                       n_requests=10, max_prompt=32))
ok = (m["completed"] == m["submitted"] == 10
      and m["kv_transfer"]["plans"] >= 1
      and all(p.in_use == 0 for p in eng.pools.values()))
print(f"continuous shardmap: {m['completed']}/{m['submitted']} requests, "
      f"{m['kv_transfer']['plans']} plans {'ok' if ok else 'FAIL'}")
if not ok:
    failures.append(("continuous-shardmap", m))

if failures:
    raise SystemExit(f"FAILURES: {failures}")
print("ALL OK")
