"""Subprocess check: ShardMapTransport (ppermute execution) matches the
numpy semantics on 8 host devices, for every collective x algorithm,
single- and multi-pod, including the full mpix_* API and the xla
substrate path.

Run via tests/test_shardmap.py (needs its own process: jax device count is
locked at first init)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

from repro.core import api
from repro import compat

N = 8
MESHES = {
    "flat": (compat.make_mesh((8,), ("data",)),
             ("data",)),
    "pods": (compat.make_mesh((2, 4), ("pod", "data")),
             ("pod", "data")),
}

ALGOS = {
    "allgather": ["xla", "ring", "bruck", "recursive_doubling",
                  "hierarchical", "staged"],
    "allreduce": ["xla", "ring_rs_ag", "recursive_halving_doubling",
                  "hierarchical", "staged"],
    "reduce_scatter": ["xla", "ring", "recursive_halving", "hierarchical",
                       "staged"],
    "alltoall": ["xla", "pairwise", "bruck", "hierarchical", "staged"],
}

rng = np.random.default_rng(0)
failures = []


def check(mesh_name, mesh, axes, coll, algo):
    spec = P(tuple(axes))
    if coll == "allgather":
        x = rng.normal(size=(N * 4, 6)).astype(np.float32)
        f = jax.jit(compat.shard_map(
            lambda v: api.mpix_allgather(v, axes, algorithm=algo),
            mesh=mesh, in_specs=spec, out_specs=P(None), check_vma=False))
        with compat.set_mesh(mesh):
            got = np.asarray(f(x))
        return np.allclose(got, x)
    if coll == "allreduce":
        x = rng.normal(size=(N * 4, 6)).astype(np.float32)
        f = jax.jit(compat.shard_map(
            lambda v: api.mpix_allreduce(v, axes, algorithm=algo),
            mesh=mesh, in_specs=spec, out_specs=P(None), check_vma=False))
        with compat.set_mesh(mesh):
            got = np.asarray(f(x))
        return np.allclose(got, x.reshape(N, 4, 6).sum(0), atol=1e-4)
    if coll == "reduce_scatter":
        # distinct per-rank contributions: feed a sharded [N*N, 6] whose
        # rank-r shard is that rank's full N-row contribution
        x = rng.normal(size=(N * N, 6)).astype(np.float32)
        f = jax.jit(compat.shard_map(
            lambda v: api.mpix_reduce_scatter(v, axes, algorithm=algo),
            mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False))
        with compat.set_mesh(mesh):
            got = np.asarray(f(x))  # rank r returns reduced row r -> [N, 6]
        want = x.reshape(N, N, 6).sum(0)  # row r fully reduced
        return np.allclose(got, want, atol=1e-4)
    if coll == "alltoall":
        x = rng.normal(size=(N * N, 6)).astype(np.float32)
        f = jax.jit(compat.shard_map(
            lambda v: api.mpix_alltoall(v, axes, algorithm=algo),
            mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False))
        with compat.set_mesh(mesh):
            got = np.asarray(f(x))
        want = x.reshape(N, N, 6).swapaxes(0, 1).reshape(N * N, 6)
        return np.allclose(got, want, atol=1e-5)
    raise ValueError(coll)


for mesh_name, (mesh, axes) in MESHES.items():
    for coll, algos in ALGOS.items():
        for algo in algos:
            ok = check(mesh_name, mesh, axes, coll, algo)
            if not ok:
                failures.append((mesh_name, coll, algo))
            print(f"{mesh_name:5s} {coll:15s} {algo:28s} "
                  f"{'ok' if ok else 'FAIL'}")

if failures:
    raise SystemExit(f"FAILURES: {failures}")
print("ALL OK")
