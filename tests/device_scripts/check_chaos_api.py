"""Subprocess check: the TRACE-TIME recovery ladder on the real mpix_*
shard_map execution paths, with seeded chaos injected through
``api.set_chaos`` (every transport the api constructs is wrapped).

Covered here (needs 8 host devices, own process):
  * transient injected failure + ``resilience="off"`` -> retried on the
    same rung, output bitwise correct, DegradationReport recorded;
  * the same failure WITHOUT resilience -> typed ``TransportError``
    surfaces at trace time (never a silent wrong answer);
  * persistent failure on every schedule-backed substrate -> the ladder
    degrades through the other transport and the refit algorithms to
    the xla-native terminal rung, output still correct;
  * hang campaign + per-attempt deadline -> timeout attempts recorded,
    recovery still bitwise;
  * ``tuner.measure_schedule(deadline_s=)`` -> typed
    ``MeasurementTimeout`` instead of a wedged measurement.

Run via tests/test_chaos.py."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import api, tuner
from repro.core.algorithms import REGISTRY
from repro.core.chaos import FaultPlan
from repro.core.topology import flat_topology
from repro.core.transport import TransportError

N = 4
mesh = compat.make_mesh((N,), ("data",))
rng = np.random.default_rng(0)
x = rng.integers(-8, 8, (N * 4, 3)).astype(np.float32)


def allgather_under(resilience):
    """Fresh trace each call (chaos fires at trace time; jit caching
    would replay the faulted trace's result otherwise)."""
    f = jax.jit(compat.shard_map(
        lambda v: api.mpix_allgather(v, "data", algorithm="ring",
                                     transport="shardmap",
                                     resilience=resilience),
        mesh=mesh, in_specs=P("data"), out_specs=P(None),
        check_vma=False))
    with compat.set_mesh(mesh):
        return np.asarray(f(x))


want = allgather_under(None)           # fault-free oracle
assert want.tobytes() == x.tobytes()   # allgather of the shards == x

# 1. transient fail + armed ladder -> recovered bitwise, report recorded
api.take_degradations()
api.set_chaos(FaultPlan(11, "fail", times=1))
got = allgather_under("off")
api.set_chaos(None)
assert got.tobytes() == want.tobytes(), "transient recovery not bitwise"
reps = api.take_degradations()
assert len(reps) == 1 and reps[0].degraded
assert any(a.outcome == "fault" for a in reps[0].attempts)
assert reps[0].attempts[-1].outcome == "ok"
print("transient fail recovered:", reps[0].summary())

# 2. same fault, no resilience -> typed TransportError at trace time
api.set_chaos(FaultPlan(11, "fail", times=1))
try:
    allgather_under(None)
    raise SystemExit("expected TransportError without resilience")
except TransportError as e:
    print("unarmed fault is typed:", type(e).__name__)
finally:
    api.set_chaos(None)

# 3. persistent fail everywhere -> ladder ends on the xla-native rung
api.take_degradations()
api.set_chaos(FaultPlan(11, "fail", times=None))
got = allgather_under({"verify": "off", "max_retries": 1,
                       "backoff_s": 1e-4})
api.set_chaos(None)
assert got.tobytes() == want.tobytes(), "xla-rung recovery not bitwise"
reps = api.take_degradations()
assert len(reps) == 1 and reps[0].refit_algorithm == "xla"
assert reps[0].recovered_with == "xla"
print("persistent fail degraded to xla:", reps[0].summary())

# 4. hang campaign + deadline -> timeout attempts recorded, recovered
api.take_degradations()
api.set_chaos(FaultPlan(5, "hang", times=1, delay_s=30.0))
got = allgather_under({"verify": "off", "deadline_s": 5.0,
                       "backoff_s": 1e-4})
api.set_chaos(None)
assert got.tobytes() == want.tobytes(), "hang recovery not bitwise"
reps = api.take_degradations()
assert len(reps) == 1
assert any(a.outcome == "timeout" for a in reps[0].attempts)
print("hang hit the deadline then recovered:", reps[0].summary())

# 5. measure_schedule deadline -> typed MeasurementTimeout
topo = flat_topology(N)
sched = REGISTRY["allgather"]["ring"](topo)
t = tuner.measure_schedule(sched, topo, slot_elems=64, repeats=1)
assert t > 0
try:
    tuner.measure_schedule(sched, topo, slot_elems=64, repeats=1,
                           deadline_s=1e-6)
    raise SystemExit("expected MeasurementTimeout")
except tuner.MeasurementTimeout as e:
    print("measurement deadline is typed:", e)

# 6. allreduce path too: transient fail under the armed ladder
def allreduce_under(resilience):
    f = jax.jit(compat.shard_map(
        lambda v: api.mpix_allreduce(v, "data", algorithm="ring_rs_ag",
                                     transport="shardmap",
                                     resilience=resilience),
        mesh=mesh, in_specs=P("data"), out_specs=P(None),
        check_vma=False))
    with compat.set_mesh(mesh):
        return np.asarray(f(x))


want_ar = allreduce_under(None)
api.take_degradations()
api.set_chaos(FaultPlan(2, "fail", times=1))
got_ar = allreduce_under("off")
api.set_chaos(None)
assert got_ar.tobytes() == want_ar.tobytes()
assert len(api.take_degradations()) == 1
print("allreduce transient fail recovered bitwise")

print("ALL OK")
