"""Subprocess check: the persistent-executor cache on the shard_map path.

Proves the MPI-4 persistent-collective property on 8 forced host
devices:

  1. one jit trace per (schedule, shape, dtype) — repeated calls to a
     jitted collective never re-lower the compiled rounds (the
     ``CompiledExec.trace_count`` counter stays at 1), while a new
     dtype or slot shape lowers exactly once more;
  2. the mpix_* API path shares that executor (same cache entry, no
     per-call recompilation);
  3. the fused lowering is bit-exact with the unfused reference on a
     multi-pod staged neighbor plan that actually loses rounds to
     fusion (the alpha-term win is real, not a no-op pass);
  4. flipping REPRO_VALIDATE_SCHEDULES or the schedule fingerprint
     yields a different executor (cache invalidation).

Run via tests/test_shardmap.py (needs its own process: jax device count
is locked at first init).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("REPRO_VALIDATE_SCHEDULES", "1")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import api, executor
from repro.core.algorithms import REGISTRY
from repro.core.plan import CommGraph, build_plan, run_shardmap, run_sim
from repro.core.topology import Topology, flat_topology
from repro.core.transport import ShardMapTransport, SimTransport

N = 8
mesh = compat.make_mesh((N,), ("data",))
topo = flat_topology(N)

# --- 1. one trace per (schedule, shape, dtype) -----------------------------
sched = REGISTRY["allgather"]["ring"](topo)
ex = executor.get_executor(sched)
tr = ShardMapTransport(N, ("data",))
f = jax.jit(compat.shard_map(
    lambda b: tr.run(sched, b), mesh=mesh,
    in_specs=P("data"), out_specs=P("data"), check_vma=False))

x32 = np.random.default_rng(0).normal(
    size=(N * sched.num_slots, 4)).astype(np.float32)
with compat.set_mesh(mesh):
    for _ in range(4):
        jax.block_until_ready(f(x32))
assert ex.trace_count == 1, f"expected 1 trace after 4 calls, got {ex.trace_count}"

with compat.set_mesh(mesh):                       # new dtype: one more trace
    for _ in range(3):
        jax.block_until_ready(f(x32.astype(jnp.bfloat16)))
assert ex.trace_count == 2, ex.trace_count

x_wide = np.random.default_rng(1).normal(
    size=(N * sched.num_slots, 6)).astype(np.float32)
with compat.set_mesh(mesh):                       # new slot shape: one more
    jax.block_until_ready(f(x_wide))
    jax.block_until_ready(f(x_wide))
assert ex.trace_count == 3, ex.trace_count
print(f"trace counts ok: 9 calls -> {ex.trace_count} traces "
      f"(1 per shape/dtype)")

# --- 1b. topology-armed executor: baked where-masks add no retraces --------
# the armed compilation bakes scratch-safe indices AND jnp.where masks
# as device constants (executor._ExecRound.jnp_tables); repeated jitted
# calls of the armed executor must still lower exactly once, and the
# armed executor is a distinct cache entry from the topology-free one
topo2 = Topology(8, 4)
ex_armed = executor.get_executor(sched, topo=topo2)
assert ex_armed is not ex, "topology must key a distinct cache entry"
assert executor.get_executor(sched, topo=topo2) is ex_armed
tr_armed = ShardMapTransport(N, ("data",), topo=topo2)
fa = jax.jit(compat.shard_map(
    lambda b: tr_armed.run(sched, b), mesh=mesh,
    in_specs=P("data"), out_specs=P("data"), check_vma=False))
with compat.set_mesh(mesh):
    for _ in range(5):
        jax.block_until_ready(fa(x32))
assert ex_armed.trace_count == 1, (
    f"baked masks must not retrace: 5 calls -> {ex_armed.trace_count}")
want = SimTransport(N).run_reference(
    sched, x32.reshape(N, sched.num_slots, 4))
with compat.set_mesh(mesh):
    got = np.asarray(fa(x32))
assert np.array_equal(want.reshape(got.shape), got)
# the mask/index device constants are materialized once and reused
tables0 = [r.jnp_tables() for r in ex_armed._rounds]
tables1 = [r.jnp_tables() for r in ex_armed._rounds]
assert all(a is b for ta, tb in zip(tables0, tables1)
           for a, b in zip(ta, tb)), "jnp tables/masks must bake once"
print(f"armed executor: 5 calls -> {ex_armed.trace_count} trace, "
      f"distinct cache entry, masks baked once, bit-exact")

# --- 2. the mpix_* API path shares the executor cache ----------------------
# the api path arms the executor with its own (flat, from the mesh
# axes) topology — one cache entry per geometry, reused across calls
g = jax.jit(compat.shard_map(
    lambda v: api.mpix_allgather(v, "data", algorithm="ring"),
    mesh=mesh, in_specs=P("data"), out_specs=P(None), check_vma=False))
xs = np.random.default_rng(2).normal(size=(N * 4, 3)).astype(np.float32)
with compat.set_mesh(mesh):
    for _ in range(3):
        jax.block_until_ready(g(xs))
stats = executor.cache_stats()
flat_fp = topo.fingerprint()
ring_execs = [e for e in stats["executors"]
              if e["name"] == "allgather.ring" and e["optimize"]
              and e["topology"] == flat_fp]
assert len(ring_execs) == 1, (
    f"api path must reuse one cached flat-armed allgather.ring "
    f"executor, found {len(ring_execs)}")
assert ring_execs[0]["trace_count"] == 1, ring_execs
print(f"api path shares per-geometry executor: cache size "
      f"{stats['size']}, hits {stats['hits']}")

# --- 3. fused lowering bit-exact where fusion cuts rounds ------------------
# a multi-pod staged schedule with serialized per-pod stages (what a
# naive staged builder emits; the registered builders parallel_fuse at
# plan time) must fuse 2*(R-1) -> R-1 rounds and stay bit-exact through
# the real shard_map path
from repro.core.algorithms.staged import serialized_pod_allgather

naive = serialized_pod_allgather(Topology(8, 4))
nex = executor.get_executor(naive)
assert nex.rounds_before == 6 and nex.rounds_after == 3, (
    "staged multi-pod schedule must lose rounds to fusion",
    nex.rounds_before, nex.rounds_after)
rng = np.random.default_rng(0)
xbuf = rng.normal(size=(N, N, 2)).astype(np.float32)
want_naive = SimTransport(N).run_reference(naive, xbuf)
tr_n = ShardMapTransport(N, ("data",))
fn = jax.jit(compat.shard_map(
    lambda b: tr_n.run(naive, b), mesh=mesh,
    in_specs=P("data"), out_specs=P("data"), check_vma=False))
with compat.set_mesh(mesh):
    got_naive = np.asarray(fn(xbuf.reshape(N * N, 2)))
assert np.array_equal(want_naive.reshape(got_naive.shape), got_naive)
print(f"fusion win on staged multi-pod schedule: "
      f"{nex.rounds_before} -> {nex.rounds_after} rounds, bit-exact on "
      f"shard_map")

# real colored neighbor plans: the drain pass may only ever delete
# rounds (never redistribute), must never raise the modeled time, and
# stays bit-exact
mp12 = Topology(12, 3)
graph = CommGraph.random(12, n_local=6, degree=4, rng=rng, dup_frac=0.8)
plan = build_plan(graph, mp12, aggregate=True)
pex = executor.get_executor(plan.schedule)
assert pex.rounds_after <= pex.rounds_before, (
    pex.rounds_before, pex.rounds_after)
assert (pex.compiled_schedule.modeled_time(mp12, 4096)
        <= plan.schedule.modeled_time(mp12, 4096) * 1.0001)
values = [rng.normal(size=(6, 2)).astype(np.float32) for _ in range(12)]
got = run_sim(plan, values)
for r in range(12):
    segs = [values[s][idx] for s, idx in graph.recv_layout(r)]
    want = np.concatenate(segs) if segs else np.zeros((0, 2), np.float32)
    np.testing.assert_allclose(got[r], want)
print(f"colored neighbor plan: {pex.rounds_before} -> "
      f"{pex.rounds_after} rounds, modeled time not raised, bit-exact")

# an 8-rank neighbor plan through the real shard_map path, fused vs
# unfused reference
graph8 = CommGraph.random(N, n_local=5, degree=4, rng=rng, dup_frac=0.8)
plan8 = build_plan(graph8, Topology(8, 4), aggregate=True)
n_local_max = max(graph8.local_sizes)
vals = [rng.normal(size=(n_local_max, 2)).astype(np.float32)
        for _ in range(N)]
want8 = run_sim(plan8, vals)
h = jax.jit(compat.shard_map(
    lambda v: run_shardmap(plan8, v, ("data",)), mesh=mesh,
    in_specs=P("data"), out_specs=P("data"), check_vma=False))
with compat.set_mesh(mesh):
    got8 = np.asarray(h(np.concatenate(vals, axis=0)))
got8 = got8.reshape(N, -1, 2)
for r in range(N):
    np.testing.assert_allclose(got8[r, : plan8.recv_sizes[r]], want8[r])
print("neighbor plan shard_map fused execution ok")

# --- 4. cache invalidation -------------------------------------------------
before = executor.get_executor(sched)
os.environ["REPRO_VALIDATE_SCHEDULES"] = "0"
after = executor.get_executor(sched)
assert after is not before, "validation-flag flip must invalidate"
os.environ["REPRO_VALIDATE_SCHEDULES"] = "1"
assert executor.get_executor(sched) is before
other = REGISTRY["allgather"]["bruck"](topo)
assert other.fingerprint() != sched.fingerprint()
assert executor.get_executor(other) is not before
print("cache invalidation ok (env flag + fingerprint)")

print("ALL OK")
