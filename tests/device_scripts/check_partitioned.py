"""Subprocess check: partitioned-communication primitives (paper §2.3)
and the gpipe pipeline, on 8 host devices.

Paper claim 1 ("with only one partition, MPIPCL is no worse than base
point-to-point") is checked structurally: the 1-partition pipeline is
the monolithic transfer (same single collective in the HLO) and all
partition counts are bit-identical in value.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.algorithms import partitioned as pc
from repro.core import pipeline as pl
from repro import compat

N = 8
mesh = compat.make_mesh((N,), ("data",))
rng = np.random.default_rng(0)
failures = []


def check(name, ok):
    print(f"{name:45s} {'ok' if ok else 'FAIL'}")
    if not ok:
        failures.append(name)


# -- partitioned ppermute: all partition counts == monolithic -------------
x = rng.normal(size=(N * 16, 4)).astype(np.float32)
perm = [(i, (i + 1) % N) for i in range(N)]
outs = {}
for parts in (1, 2, 4, 8):
    f = jax.jit(compat.shard_map(
        lambda v, p=parts: pc.partitioned_ppermute(v, "data", perm, p),
        mesh=mesh, in_specs=P("data"), out_specs=P("data"),
        check_vma=False))
    with compat.set_mesh(mesh):
        outs[parts] = np.asarray(f(x))
want = x.reshape(N, 16, 4)[np.array([(i - 1) % N for i in range(N)])]
check("partitioned_ppermute matches shift", np.allclose(
    outs[1], want.reshape(N * 16, 4)))
for parts in (2, 4, 8):
    check(f"partitions={parts} bit-identical to 1",
          np.array_equal(outs[parts], outs[1]))

# claim 1 structural check: the 1-partition pipeline lowers to the same
# number of collective-permute ops as the monolithic ppermute
def _n_cp(fn):
    with compat.set_mesh(mesh):
        hlo = jax.jit(fn).lower(x).compile().as_text()
    return len(re.findall(r"= \S* ?collective-permute", hlo))


f1 = compat.shard_map(lambda v: pc.partitioned_ppermute(v, "data", perm, 1),
                   mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                   check_vma=False)
f0 = compat.shard_map(lambda v: jax.lax.ppermute(v, "data", perm),
                   mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                   check_vma=False)
check("1-partition == monolithic collective count", _n_cp(f1) == _n_cp(f0))

# -- early-bird consume: running sum over arriving partitions -------------
f = jax.jit(compat.shard_map(
    lambda v: pc.partitioned_ppermute(
        v, "data", perm, 4,
        consume=lambda c, chunk: c + chunk.sum(0),
        init=jnp.zeros((4,), jnp.float32)),
    mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False))
with compat.set_mesh(mesh):
    got = np.asarray(f(x))
check("early-bird consume == sum of received shard",
      np.allclose(got.reshape(N, 4), want.sum(1), atol=1e-4))

# -- allgather_matmul ------------------------------------------------------
xg = rng.normal(size=(N * 8, 16)).astype(np.float32)
w = rng.normal(size=(16, 12)).astype(np.float32)
f = jax.jit(compat.shard_map(
    lambda v, ww: pc.allgather_matmul(v, ww, "data"),
    mesh=mesh, in_specs=(P("data"), P()), out_specs=P(),
    check_vma=False))
with compat.set_mesh(mesh):
    got = np.asarray(f(xg, w))
check("allgather_matmul == all_gather(x) @ w",
      np.allclose(got, xg @ w, atol=1e-4))

# -- matmul_reduce_scatter -------------------------------------------------
xr = rng.normal(size=(N * 4, N * 16)).astype(np.float32)   # m=32, k=128
wr = rng.normal(size=(N * 16, 10)).astype(np.float32)
f = jax.jit(compat.shard_map(
    lambda v, ww: pc.matmul_reduce_scatter(v, ww, "data"),
    mesh=mesh,
    in_specs=(P(None, "data"), P("data")), out_specs=P("data"),
    check_vma=False))
# inside: each rank has x_local [m, k/N] and w_local [k/N, 10]
with compat.set_mesh(mesh):
    got = np.asarray(f(xr, wr))       # [m, 10] scattered over ranks
check("matmul_reduce_scatter == psum_scatter(x @ w)",
      np.allclose(got, xr @ wr, atol=1e-3))

# -- bucketed psum ----------------------------------------------------------
tree = {"a": rng.normal(size=(N, 33)).astype(np.float32),
        "b": rng.normal(size=(N, 5, 7)).astype(np.float32)}
f = jax.jit(compat.shard_map(
    lambda t: pc.bucketed_psum(t, "data", buckets=3),
    mesh=mesh, in_specs=P("data"), out_specs=P(), check_vma=False))
with compat.set_mesh(mesh):
    got = f(tree)
check("bucketed_psum == tree psum",
      np.allclose(got["a"], tree["a"].sum(0, keepdims=True), atol=1e-4)
      and np.allclose(got["b"], tree["b"].sum(0, keepdims=True), atol=1e-4))

# -- gpipe: S stages of affine layers == sequential oracle ----------------
S, M, UB, D = 8, 6, 4, 5
Ws = rng.normal(size=(S, D, D)).astype(np.float32) * 0.3
bs = rng.normal(size=(S, D)).astype(np.float32)
xs = rng.normal(size=(M, UB, D)).astype(np.float32)


def stage_fn(p, h):
    W, b = p
    return jnp.tanh(h @ W + b)


f = jax.jit(compat.shard_map(
    lambda W, b, v: pl.gpipe(stage_fn, (W[0], b[0]), v, "data",
                             return_to_first=True),
    mesh=mesh, in_specs=(P("data"), P("data"), P()),
    out_specs=P(), check_vma=False))
with compat.set_mesh(mesh):
    got = np.asarray(f(Ws, bs, xs))
h = xs
for s in range(S):
    h = np.tanh(h @ Ws[s] + bs[s])
# output lands on stage 0's copy after return_to_first
check("gpipe forward == sequential stages", np.allclose(got, h, atol=1e-4))

# gpipe differentiability: grad of sum(out) wrt input matches oracle


def loss_pipe(v):
    out = compat.shard_map(
        lambda W, b, vv: pl.gpipe(stage_fn, (W[0], b[0]), vv, "data",
                                  return_to_first=True),
        mesh=mesh, in_specs=(P("data"), P("data"), P()),
        out_specs=P(), check_vma=False)(Ws, bs, v)
    return out.sum()


def loss_seq(v):
    h = v
    for s in range(S):
        h = jnp.tanh(h @ Ws[s] + bs[s])
    return h.sum()


with compat.set_mesh(mesh):
    g_pipe = np.asarray(jax.jit(jax.grad(loss_pipe))(xs))
g_seq = np.asarray(jax.grad(loss_seq)(xs))
check("gpipe reverse-mode AD == sequential grad",
      np.allclose(g_pipe, g_seq, atol=1e-4))

if failures:
    raise SystemExit(f"FAILURES: {failures}")
print("ALL OK")
