"""Subprocess check (8 host devices): the pipelined/overlap hot paths.

  1. ShardMapTransport.run_chunked == run (bit-identical reassembly)
     and the early-bird fold sees every chunk.
  2. mpix_alltoall_overlap == mpix_alltoall for every chunk count, xla
     and schedule-backed algorithms (the fold reproduces the monolithic
     output exactly).
  3. MoE dispatch with EPOptions.overlap_chunks in {None, 2, 4, 0/auto}
     is equivalent (pipelined == unpipelined oracle).
  4. Explicit-DP train step with overlap_grad_chunks == the unpipelined
     explicit step (same loss, same updated params, same grad norm).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat, configs
from repro.core import api as mpix
from repro.core.algorithms import REGISTRY
from repro.core.topology import flat_topology
from repro.core.transport import ShardMapTransport
from repro.data import DataPipeline, PipelineConfig
from repro.models import moe as moe_mod
from repro.train.moe_dispatch import EPOptions, make_moe_dispatch
from repro.train.step import TrainOptions, init_train_state, make_train_step

failures = []


def check(name, ok):
    print(f"{name:58s} {'ok' if ok else 'FAIL'}")
    if not ok:
        failures.append(name)


N = 8
mesh1d = compat.make_mesh((N,), ("data",))
rng = np.random.default_rng(0)

# ---------------------------------------------------------------------------
# 1. ShardMapTransport.run_chunked == run
# ---------------------------------------------------------------------------
sched = REGISTRY["alltoall"]["pairwise"](flat_topology(N))
tr = ShardMapTransport(N, "data")
buf = rng.normal(size=(N, sched.num_slots, 8, 3)).astype(np.float32)


def _runner(fn):
    # in_specs=P("data") hands each rank its [num_slots, 8, 3] slice
    f = jax.jit(compat.shard_map(
        fn, mesh=mesh1d, in_specs=P("data"), out_specs=P("data"),
        check_vma=False))
    with compat.set_mesh(mesh1d):
        return np.asarray(f(buf.reshape((N * sched.num_slots, 8, 3))))


whole = _runner(lambda b: tr.run(sched, b))
for chunks in (1, 2, 4):
    got = _runner(lambda b, c=chunks: tr.run_chunked(
        sched, b, chunks=c))
    check(f"shardmap run_chunked chunks={chunks} bit-identical",
          np.array_equal(got, whole))

fold = _runner(lambda b: tr.run_chunked(
    sched, b, chunks=4,
    consume=lambda c, out, i: c + out.sum(axis=1),
    init=jnp.zeros((sched.num_slots, 3), jnp.float32)))
check("shardmap run_chunked early-bird fold == whole sum",
      np.allclose(fold, whole.reshape(N, sched.num_slots, 8, 3)
                  .sum(axis=2).reshape(N * sched.num_slots, 3),
                  atol=1e-4))

# ---------------------------------------------------------------------------
# 2. mpix_alltoall_overlap == mpix_alltoall
# ---------------------------------------------------------------------------
# per-rank input: N destination blocks of 6 rows each
xa = rng.normal(size=(N * N * 6, 5)).astype(np.float32)


def _a2a(algo):
    f = jax.jit(compat.shard_map(
        lambda v: mpix.mpix_alltoall(v, "data", algorithm=algo),
        mesh=mesh1d, in_specs=P("data"), out_specs=P("data"),
        check_vma=False))
    with compat.set_mesh(mesh1d):
        return np.asarray(f(xa))


def _a2a_overlap(algo, chunks):
    rc = 6 // chunks

    def fold(carry, out_c, i):
        # out_c = the alltoall of row slice i of every block:
        # [N*rc, 5] -> rows [i*rc, (i+1)*rc) of each received block
        return jax.lax.dynamic_update_slice_in_dim(
            carry, out_c.reshape(N, rc, 5), i * rc, axis=1)

    f = jax.jit(compat.shard_map(
        lambda v: mpix.mpix_alltoall_overlap(
            v, "data", fold, jnp.zeros((N, 6, 5), jnp.float32),
            chunks=chunks, algorithm=algo).reshape(N * 6, 5),
        mesh=mesh1d, in_specs=P("data"), out_specs=P("data"),
        check_vma=False))
    with compat.set_mesh(mesh1d):
        return np.asarray(f(xa))


for algo in ("xla", "pairwise", "bruck"):
    want = _a2a(algo)
    for chunks in (1, 2, 3, 6):
        got = _a2a_overlap(algo, chunks)
        check(f"alltoall_overlap algo={algo} chunks={chunks}",
              np.array_equal(got, want)
              or np.allclose(got, want, atol=1e-6))

# ---------------------------------------------------------------------------
# 3. MoE dispatch overlap == monolithic
# ---------------------------------------------------------------------------
mesh = compat.make_mesh((2, 4), ("data", "model"))
cfg = configs.get_smoke("moonshot-v1-16b-a3b")
mcfg = cfg.moe
p = moe_mod.init(jax.random.key(0), mcfg, cfg.d_model)
xm = (jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model),
                        jnp.float32) * 0.3)
outs = {}
for ov in (None, 2, 4, 0):
    disp = make_moe_dispatch(
        mesh, EPOptions(alltoall="pairwise",
                        capacity_factor=float(mcfg.n_experts),
                        overlap_chunks=ov),
        cfg.mlp_act)
    with compat.set_mesh(mesh):
        outs[ov] = np.asarray(jax.jit(
            lambda pp, xx: disp(pp, mcfg, xx))(p, xm), np.float32)
for ov in (2, 4, 0):
    check(f"moe dispatch overlap_chunks={ov} == monolithic",
          np.allclose(outs[ov], outs[None], atol=1e-5, rtol=1e-5))

# ---------------------------------------------------------------------------
# 4. explicit-DP step with grad-sync overlap == unpipelined step
# ---------------------------------------------------------------------------
cfg_t = configs.get_smoke("smollm-360m")
pipe = DataPipeline(PipelineConfig(vocab_size=cfg_t.vocab_size,
                                   seq_len=16, global_batch=4))
batch = pipe.batch(0)
base_opts = TrainOptions(dp_mode="explicit", remat=False, peak_lr=1e-3,
                         warmup_steps=1, total_steps=100)
over_opts = TrainOptions(dp_mode="explicit", remat=False, peak_lr=1e-3,
                         warmup_steps=1, total_steps=100,
                         overlap_grad_chunks=3)
state = init_train_state(jax.random.key(0), cfg_t, base_opts)
from jax.sharding import NamedSharding

results = {}
for tag, opts in (("base", base_opts), ("overlap", over_opts)):
    with compat.set_mesh(mesh):
        bsh = jax.device_put(batch, NamedSharding(mesh, P(("data",))))
        new, m = jax.jit(make_train_step(cfg_t, mesh, opts))(
            jax.device_put(state), bsh)
    results[tag] = (float(m["loss"]), float(m["grad_norm"]),
                    np.asarray(jax.tree.leaves(new["params"])[0],
                               np.float32))
l0, g0, w0 = results["base"]
l1, g1, w1 = results["overlap"]
check("overlap step same loss", abs(l0 - l1) < 1e-5)
check("overlap step same grad norm", abs(g0 - g1) < 1e-4 * max(1.0, g0))
check("overlap step same updated params", np.allclose(w0, w1, atol=1e-5))

# ---------------------------------------------------------------------------
# 5. serve prefill with explicit EP overlap == default XLA dispatch
# ---------------------------------------------------------------------------
from repro.models import model as M
from repro.serve.step import ServeOptions, make_prefill_step

params = M.init_params(jax.random.key(2), cfg)
toks = jax.random.randint(jax.random.key(3), (2, 16), 0, cfg.vocab_size)
sbatch = {"tokens": toks}
logits = {}
for tag, sopts in (
        ("default", ServeOptions()),
        ("ep_overlap", ServeOptions(ep_options=EPOptions(
            alltoall="pairwise",
            capacity_factor=float(mcfg.n_experts),
            overlap_chunks=2)))):
    with compat.set_mesh(mesh):
        logits[tag] = np.asarray(jax.jit(
            make_prefill_step(cfg, mesh, sopts))(params, sbatch),
            np.float32)
check("serve prefill EP overlap == default dispatch",
      np.allclose(logits["ep_overlap"], logits["default"],
                  atol=2e-2, rtol=2e-2))

if failures:
    raise SystemExit(f"FAILURES: {failures}")
print("ALL OK")
