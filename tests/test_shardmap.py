"""Multi-device shard_map tests, run in subprocesses (jax locks the host
device count at first init, and the main pytest process must keep seeing
exactly 1 device for the smoke tests)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPTS = Path(__file__).parent / "device_scripts"
SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_script(name: str, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(SCRIPTS / name)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, (
        f"--- stdout ---\n{proc.stdout[-4000:]}\n"
        f"--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.mark.slow
def test_shardmap_transport_all_collectives():
    out = run_script("check_shardmap_transport.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_unified_ir_transports_bit_exact():
    """SimTransport == ShardMapTransport on the unified IR for every
    registered schedule x {flat, 2-pod, 2x4 torus, 3-level} x
    {f32, bf16} (the deeper 2x(4x2) sweep runs from
    test_hierarchical.py via check_hierarchical.py)."""
    out = run_script("check_unified_ir.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_executor_cache_trace_counts_and_fusion():
    """Persistent-executor proof: one jit trace per (schedule, shape,
    dtype) across repeated calls, api-path cache sharing, fused-vs-
    reference bit-exactness where fusion cuts rounds, and cache
    invalidation on env-flag / fingerprint changes."""
    out = run_script("check_executor.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_pallas_transport_device_paths():
    """Device-side single-kernel transport (PallasTransport) inside
    real shard_map: bit-exact vs ShardMapTransport for every dense
    collective + neighbor plan + overlap path, and the fused
    allreduce->rmsnorm epilogue vs its unfused oracle."""
    out = run_script("check_pallas_transport.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_neighbor_plan_shardmap():
    out = run_script("check_neighbor_shardmap.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_partitioned_and_pipeline():
    out = run_script("check_partitioned.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_distributed_train_paths():
    out = run_script("check_train_dist.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_pipelined_overlap_paths():
    """Compute-comm overlap hot paths (PR 6): chunked shard_map
    transport, mpix_alltoall_overlap, MoE dispatch overlap, grad-sync
    overlap in the explicit train step, and the serve prefill EP
    wiring — all equivalent to their unpipelined oracles."""
    out = run_script("check_overlap.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_multi_pod_dryrun_cells():
    out = run_script("check_dryrun_cell.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_elastic_remesh_restore():
    out = run_script("check_elastic.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_serve_device_paths():
    """Continuous-batching serving on real shards: jit_decode_step's
    cache NamedShardings actually land (the bare-jax.jit launcher bug),
    KV-transfer plans bit-exact on shardmap + pallas transports, and a
    full engine trace drained with transport="shardmap"."""
    out = run_script("check_serve.py")
    assert "ALL OK" in out
