"""Runtime x pipelined-executor integration.

A slow link changes the *model* (makespan degrades monotonically, the
straggler monitor flags the host) but never the *math* — execution and
``run_reference`` stay bit-exact on any topology.  A preemption mid-run
resumes from checkpoint to the bit-identical final state even when the
step function executes the committed pipelined schedule.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import executor
from repro.core.algorithms import REGISTRY
from repro.core.schedule import ComputeEvent
from repro.core.topology import (DCN_LINK, ICI_LINK, LinkModel, TopoLevel,
                                 Topology)
from repro.core.transport import SimTransport
from repro.runtime.fault import FaultTolerantLoop, PreemptionSignal
from repro.runtime.straggler import StragglerMonitor


@pytest.fixture(autouse=True)
def _fresh_executor_cache():
    executor.clear_cache()
    yield
    executor.clear_cache()


def _two_pod(dcn_beta_scale: float) -> Topology:
    slow = LinkModel(alpha=DCN_LINK.alpha,
                     beta=DCN_LINK.beta * dcn_beta_scale)
    return Topology.from_levels([
        TopoLevel("dcn", 2, slow, dcn=True),
        TopoLevel("ici", 4, ICI_LINK),
    ])


def _armed(topo, *, splittable=True):
    base = REGISTRY["allgather"]["hierarchical"](topo)
    ev = ComputeEvent("mlp", base.modeled_time(topo, 4096.0),
                      after_round=-1, splittable=splittable, parts=4)
    sched = dataclasses.replace(base, compute_events=(ev,))
    return sched, executor.get_executor(sched, topo=topo)


def test_slow_link_degrades_makespan_monotonically():
    slot = float(1 << 20)
    mks = []
    for scale in (1.0, 4.0, 16.0):
        sched, ex = _armed(_two_pod(scale))
        mks.append(ex.makespan(slot))
        # the chain holds on every topology, slow links included
        ev_s = sum(e.seconds for e in sched.compute_events)
        assert mks[-1] <= (ex.compiled_schedule.modeled_time(
            _two_pod(scale), slot) + ev_s) * (1 + 1e-9)
    assert mks[0] < mks[1] < mks[2], mks


def test_slow_link_never_changes_the_math():
    rng = np.random.default_rng(0)
    base_sched, base_ex = _armed(_two_pod(1.0))
    buf = rng.integers(-8, 8, (8, base_sched.num_slots, 2)
                       ).astype(np.float32)
    tr = SimTransport(8)
    want = tr.run_reference(base_sched, buf)
    for scale in (4.0, 16.0):
        sched, ex = _armed(_two_pod(scale))
        assert np.array_equal(ex.run_sim(buf), want)
        if ex.pipelined_schedule is not None:
            assert np.array_equal(
                tr.run_reference(ex.pipelined_schedule, buf), want)


def test_straggler_monitor_flags_slow_pod_host():
    """Feed the monitor per-host step times derived from the makespan
    model: hosts on the degraded topology run the same pipelined step
    slower, get flagged past the threshold, and their data shard moves
    to a fast host."""
    slot = float(1 << 20)
    _, fast_ex = _armed(_two_pod(1.0))
    _, slow_ex = _armed(_two_pod(16.0))
    t_fast, t_slow = fast_ex.makespan(slot), slow_ex.makespan(slot)
    assert t_slow > 1.5 * t_fast
    mon = StragglerMonitor(num_hosts=4, threshold=1.5)
    for step in range(6):
        for h in range(3):
            mon.record(h, t_fast * (1 + 0.01 * step))
        mon.record(3, t_slow)
    assert mon.stragglers() == [3]
    assign = mon.rebalance()
    assert assign[3] == [] and sorted(
        s for shards in assign.values() for s in shards) == [0, 1, 2, 3]


def test_fault_resume_pipelined_step_bit_exact(tmp_path):
    """Preempt a loop whose step executes the committed pipelined
    schedule; resuming from the checkpoint reproduces the uninterrupted
    run bit-for-bit (exactly-once recovery on the hot path)."""
    topo = _two_pod(1.0)
    sched, ex = _armed(topo)
    assert ex.pipeline_tail_parts >= 2      # the split actually commits
    pipelined = ex.pipelined_schedule
    tr = SimTransport(8)
    rng = np.random.default_rng(1)
    init = {"buf": rng.normal(size=(8, sched.num_slots, 2)
                              ).astype(np.float32)}

    def step_fn(state, step):
        out = tr.run_reference(pipelined, state["buf"])
        return {"buf": (0.5 * out + float(step)).astype(np.float32)}

    # uninterrupted oracle
    ref = dict(init)
    for s in range(6):
        ref = step_fn(ref, s)

    loop = FaultTolerantLoop(tmp_path, ckpt_every=100)
    sig = loop.preemption
    state, stopped = loop.run(
        init, step_fn, start_step=0, num_steps=6,
        on_step=lambda step, st: sig.trigger() if step == 3 else None)
    assert stopped == 3

    loop2 = FaultTolerantLoop(tmp_path, ckpt_every=100,
                              preemption=PreemptionSignal())
    state2, start = loop2.resume_or_init(init)
    assert start == 3
    final, done = loop2.run(state2, step_fn, start_step=start,
                            num_steps=6 - start)
    assert done == 6
    assert np.array_equal(final["buf"], ref["buf"])
