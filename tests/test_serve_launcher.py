"""Regression tests for the serve-launcher bugfix sweep.

Four launcher bugs, each with its own test:
  1. ``--resilience`` was a silent no-op without ``--ep-transport``;
  2. ``--gen 0`` crashed in ``np.stack`` on an empty list;
  3. heal daemons leaked when the decode loop raised;
  4. bare ``jax.jit`` ignored ``jit_decode_step``'s shardings.
Plus the ``--continuous`` path smoke (Poisson arrivals, >=2 tenants).
"""
import pytest

from repro.launch import serve

ARCH = ["--arch", "smollm-360m", "--smoke"]


# ---------------------------------------------------------------------------
# bug 2: argument validation (no more empty-generation crash)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("flags", [
    ["--gen", "0"],
    ["--gen", "-3"],
    ["--prompt-len", "0"],
    ["--batch", "0"],
])
def test_degenerate_sizes_rejected_with_clear_error(flags, capsys):
    with pytest.raises(SystemExit) as ei:
        serve.main(ARCH + flags)
    assert ei.value.code == 2            # argparse error, not a traceback
    err = capsys.readouterr().err
    assert "must be >= 1" in err


@pytest.mark.parametrize("flags", [
    ["--continuous", "--arrival-rate", "0"],
    ["--continuous", "--tenants", "0"],
    ["--continuous", "--requests", "0"],
])
def test_degenerate_continuous_flags_rejected(flags):
    with pytest.raises(SystemExit):
        serve.main(ARCH + flags)


# ---------------------------------------------------------------------------
# bug 1: resilience with nothing to protect fails loudly
# ---------------------------------------------------------------------------


def test_resilience_without_protected_path_fails_loudly():
    with pytest.raises(SystemExit, match="nothing to protect"):
        serve.main(ARCH + ["--resilience", "canary"])
    with pytest.raises(SystemExit, match="nothing to protect"):
        serve.main(ARCH + ["--resilience", "full"])


def test_resilience_armed_by_continuous_kv_transfers():
    """--continuous arms the KV-transfer recovery ladder, so the same
    flag combination is no longer a no-op (every transfer runs through
    ResilientExec and reports)."""
    m = serve.main(ARCH + ["--continuous", "--resilience", "canary",
                           "--requests", "6", "--tenants", "2",
                           "--arrival-rate", "8"])
    assert m["completed"] == m["submitted"] == 6
    assert m["degradations"] == m["kv_transfer"]["plans"] >= 1


# ---------------------------------------------------------------------------
# bug 3: heal daemons stop even when the serve body raises
# ---------------------------------------------------------------------------


class _DaemonSpy:
    def __init__(self):
        self.started = self.stopped = False
        self.reports = []

    def start(self, interval_s):
        self.started = True

    def stop(self):
        self.stopped = True


def test_heal_daemons_stopped_when_decode_raises(monkeypatch):
    import repro.launch.train as train_mod

    spy = _DaemonSpy()
    monkeypatch.setattr(train_mod, "heal_daemons",
                        lambda mesh, every: [spy])

    def boom(*a, **k):
        raise RuntimeError("decode exploded")

    monkeypatch.setattr(serve, "jit_decode_step", boom)
    with pytest.raises(RuntimeError, match="decode exploded"):
        serve.main(ARCH + ["--heal-interval", "0.05",
                           "--prompt-len", "2", "--gen", "1",
                           "--batch", "1"])
    assert spy.started and spy.stopped, (
        "daemons must be stopped in the finally block even when the "
        "serve body raises")


def test_heal_daemons_stopped_on_continuous_failure(monkeypatch):
    import repro.launch.train as train_mod

    spy = _DaemonSpy()
    monkeypatch.setattr(train_mod, "heal_daemons",
                        lambda mesh, every: [spy])
    monkeypatch.setattr(serve, "_run_continuous",
                        lambda args, cfg: (_ for _ in ()).throw(
                            RuntimeError("engine exploded")))
    with pytest.raises(RuntimeError, match="engine exploded"):
        serve.main(ARCH + ["--heal-interval", "0.05", "--continuous",
                           "--requests", "4"])
    assert spy.started and spy.stopped


# ---------------------------------------------------------------------------
# bug 4: launcher routes through jit_decode_step (sharded, not bare jit)
# ---------------------------------------------------------------------------


def test_launcher_uses_jit_decode_step_shardings(monkeypatch):
    from repro.serve.step import jit_decode_step as real

    calls = []

    def spy(cfg, mesh, opts, params, cache):
        out = real(cfg, mesh, opts, params, cache)
        calls.append(out[1])             # (pspec, cspec)
        return out

    monkeypatch.setattr(serve, "jit_decode_step", spy)
    gen = serve.main(ARCH + ["--batch", "1", "--prompt-len", "2",
                             "--gen", "1"])
    assert gen.shape == (1, 1)
    assert len(calls) == 1
    pspec, cspec = calls[0]
    assert pspec is not None and cspec is not None, (
        "the launcher must jit through jit_decode_step so params/cache "
        "carry their NamedShardings (a bare jax.jit replicates them)")


# ---------------------------------------------------------------------------
# the continuous path end to end (tentpole smoke)
# ---------------------------------------------------------------------------


def test_continuous_smoke_multi_tenant():
    m = serve.main(ARCH + ["--continuous", "--arrival-rate", "6",
                           "--tenants", "3", "--requests", "12",
                           "--seed", "5"])
    assert m["completed"] == m["submitted"] == 12
    assert m["kv_transfer"]["plans"] >= 1
    assert m["kv_transfer"]["bytes"] > 0
    assert m["tokens_per_step"] > 0


def test_continuous_is_deterministic():
    args = ARCH + ["--continuous", "--requests", "10", "--seed", "7"]
    a, b = serve.main(args), serve.main(args)
    drop = ("tokens_per_s", "wall_s")
    sa = {k: v for k, v in a.items() if k not in drop}
    sb = {k: v for k, v in b.items() if k not in drop}
    sa["kv_transfer"] = {k: v for k, v in a["kv_transfer"].items()
                         if k != "wall_s"}
    sb["kv_transfer"] = {k: v for k, v in b["kv_transfer"].items()
                         if k != "wall_s"}
    assert sa == sb
